"""Serving example: batched requests through the PTT-scheduled engine,
comparing RWS vs DAM-P when one submesh is interfered.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import tpu_pod_slices
from repro.serve import ServingEngine

cfg = get_config("stablelm-3b").reduced()
topo = tpu_pod_slices(pods=2, slices_per_pod=2)   # 4 schedulable submeshes
SLOW = {0: 4.0}                                    # submesh 0 interfered 4x

for sched in ("RWS", "DAM-P"):
    engine = ServingEngine(cfg, topo, scheduler=sched, max_len=64,
                           slowdown=SLOW)
    rng = np.random.default_rng(0)
    for _ in range(10):
        engine.submit(rng.integers(0, cfg.vocab, size=24), max_new_tokens=4)
    m = engine.run(timeout=300)
    stats = engine.latency_stats()
    pp = m.priority_placement()
    on_slow = sum(v for k, v in pp.items() if k.startswith("(C0"))
    print(f"{sched:6s}: completed={stats['completed']} "
          f"ttft_mean={stats['ttft_ms_mean']:.0f}ms "
          f"p95={stats['ttft_ms_p95']:.0f}ms "
          f"prefills_on_slow_submesh={on_slow*100:.0f}%")
print("\nDAM-P learns the slow submesh from measured wall times and steers "
      "prefills (critical tasks) away from it.  NOTE: this container has a "
      "single physical CPU, so wall-time measurements are noisy at this "
      "scale — see tests/test_runtime_threaded.py and the simulator "
      "benchmarks for the controlled version of this experiment.")
