"""Paper §5.4 (Fig. 10): distributed 2D Heat stencil on a 4-node cluster.
Boundary-exchange (MPI) tasks are HIGH priority; an interfering matmul
kernel occupies 5 cores of node 0.

    PYTHONPATH=src python examples/heat_distributed.py
"""
from repro.core import (corun_socket, haswell_cluster, heat_dag,
                        make_scheduler, matmul_type, simulate)

topo = haswell_cluster(4, 2, 10)
print("distributed 2D Heat, 4 nodes x 20 cores, interferer on node 0\n")
base = None
for name in ("RWS", "RWSM-C", "DA", "DAM-C", "DAM-P"):
    sched = make_scheduler(name, topo, seed=1)
    dag = heat_dag(nodes=4, tiles_per_node=16, iterations=40)
    m = simulate(dag, sched,
                 background=[corun_socket(matmul_type(96), range(0, 5))])
    base = base or m.throughput
    print(f"{name:7s} throughput={m.throughput:8.0f} tasks/s "
          f"({m.throughput/base:.2f}x RWS)")
    base = base if name != "RWS" else m.throughput
print("\npaper: DAM-C +76% vs RWS, +17% vs RWSM-C; moldability helps the "
      "MPI tasks via quieter caches.")
