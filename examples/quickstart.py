"""Quickstart: the public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced Qwen2.5-style model, trains a few steps on the synthetic
stream, then serves a short generation from the trained weights.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticStream
from repro.models import decode_step, init_params
from repro.models.transformer import prefill
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step

cfg = get_config("qwen2.5-14b").reduced()
print(f"model: {cfg.name}  ({cfg.n_params/1e6:.1f}M params)")

params = init_params(cfg, jax.random.PRNGKey(0))
opt_state = init_opt_state(params)
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
step = jax.jit(make_train_step(cfg, opt_cfg))

stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=4))
for i, batch in zip(range(20), stream):
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt_state, metrics = step(params, opt_state, batch)
    if (i + 1) % 5 == 0:
        print(f"step {i+1:3d}  loss {float(metrics['loss']):.4f}  "
              f"lr {float(metrics['lr']):.2e}")

# greedy generation from the trained weights
prompt = jnp.asarray(next(stream)["tokens"][:1, :16])
logits, state = prefill(params, cfg, prompt, max_len=32)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [int(tok[0])]
for _ in range(8):
    logits, state = decode_step(params, cfg, state, tok)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(int(tok[0]))
print("generated token ids:", out)
