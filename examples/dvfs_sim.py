"""Paper §5.2: DVFS square-wave on the Denver cluster (2035/345 MHz, 5s+5s).

    PYTHONPATH=src python examples/dvfs_sim.py
"""
from repro.core import (ALL_SCHEDULERS, copy_type, dvfs_denver,
                        make_scheduler, simulate, synthetic_dag, tx2)

print("copy DAG (10000 tasks), DVFS 2035<->345 MHz on Denver, period 10 s\n")
for P in (2, 4, 6):
    base = None
    row = []
    for name in ALL_SCHEDULERS:
        sched = make_scheduler(name, tx2(), seed=1)
        dag = synthetic_dag(copy_type(1024), parallelism=P, total_tasks=10000)
        m = simulate(dag, sched, speed=dvfs_denver())
        base = base or m.throughput
        row.append(f"{name}={m.throughput:.0f}({m.throughput/base:.2f}x)")
    print(f"P={P}: " + "  ".join(row))
    base = None
print("\npaper: DAM-C ~2.2x RWS on copy averaged over parallelism; DAM-P "
      "wins at low parallelism.")
