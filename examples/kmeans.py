"""Paper §5.4: K-means as a dynamic DAG on the symmetric Haswell platform
with a mid-run interference window on socket 0 (Fig. 9).

    PYTHONPATH=src python examples/kmeans.py
"""
import numpy as np

from repro.core import (corun_socket, haswell, kmeans_dag, make_scheduler,
                        matmul_type, simulate)

topo = haswell(2, 8)
WINDOW = (0.15, 0.60)
print("K-means, 2M points, 24 chunks/iter, interference on socket-0 cores "
      f"during t=[{WINDOW[0]}, {WINDOW[1]}]s\n")
for name in ("RWS", "RWSM-C", "DA", "DAM-C", "DAM-P"):
    sched = make_scheduler(name, topo, seed=1)
    dag = kmeans_dag(n_points=2_000_000, dims=32, k=16, n_chunks=24,
                     iterations=60)
    m = simulate(dag, sched,
                 background=[corun_socket(matmul_type(96), range(0, 5),
                                          t_start=WINDOW[0], t_end=WINDOW[1])])
    red = [k for k in m.per_type_mean_duration()
           if k.startswith("kmeans_reduce")][0]
    its = np.array(m.iteration_times(red))
    print(f"{name:7s} makespan={m.makespan:6.3f}s  iter mean="
          f"{its.mean()*1e3:6.2f}ms  max={its.max()*1e3:6.2f}ms")
print("\npaper: DAM-P shows the flattest iteration times during the "
      "interference window (Fig. 9a).")
