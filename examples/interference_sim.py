"""Paper §5.1 in one script: co-running interference on the TX2 topology.

    PYTHONPATH=src python examples/interference_sim.py

Reproduces the qualitative content of Figures 4-6: all seven schedulers
run the same matmul DAG while a background matmul chain occupies core 0.
"""
from repro.core import (ALL_SCHEDULERS, corun_chain, make_scheduler,
                        matmul_type, simulate, synthetic_dag, tx2)

P, TOTAL = 2, 8000
print(f"matmul DAG, parallelism {P}, {TOTAL} tasks, co-runner on core 0\n")
print(f"{'sched':8s} {'tasks/s':>10s} {'vs RWS':>7s} {'crit@C0':>8s} "
      f"{'top place':>12s}")
base = None
for name in ALL_SCHEDULERS:
    sched = make_scheduler(name, tx2(), seed=1)
    dag = synthetic_dag(matmul_type(64), parallelism=P, total_tasks=TOTAL)
    m = simulate(dag, sched, background=[corun_chain(matmul_type(64), 0)])
    base = base or m.throughput
    pp = m.priority_placement()
    on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
    top = max(pp.items(), key=lambda kv: kv[1])
    print(f"{name:8s} {m.throughput:10.0f} {m.throughput/base:6.2f}x "
          f"{on_c0*100:7.1f}% {top[0]:>9s}:{top[1]*100:.0f}%")
print("\npaper: DAM-C up to 3.5x RWS; dynamic schedulers place ~0-2% of "
      "critical tasks\non the interfered core while FA pins 50% there.")
