"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing, a mid-run restart, and PTT-based straggler detection.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --small    # CI-speed

The model is the full xlstm-125m architecture config (the assigned ~100M
arch).  Halfway through, the run checkpoints and a NEW Trainer restores
from disk and continues — proving restart-exactness on the real loop.  A
synthetic straggler appears on pod 1 at step 60%; the supervisor's
rescale events are printed at the end.
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--small", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

cfg = get_config("xlstm-125m")
if args.small:
    cfg = cfg.reduced()
steps = args.steps or (40 if args.small else 300)
seq, batch = (64, 2) if args.small else (256, 4)

print(f"training {cfg.name}: {cfg.n_params/1e6:.0f}M params, "
      f"{steps} steps, seq {seq}, batch {batch}")

ckpt_dir = tempfile.mkdtemp(prefix="repro_trainlm_")
opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=steps // 10, total_steps=steps)
data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)

straggle_from = int(steps * 0.6)


def pod_time(step, pod):
    return 2.5 if (pod == 1 and step >= straggle_from) else 1.0


# phase 1: train to the halfway checkpoint, then "crash"
half = steps // 2
t1 = Trainer(cfg, opt_cfg, data_cfg,
             TrainerConfig(total_steps=half,
                           checkpoint_every=max(half // 2, 1),
                           log_every=max(steps // 10, 1)),
             ckpt_dir, pod_time_fn=pod_time)
t1.run()
print(f"-- simulated crash after step {t1.step}; restarting from {ckpt_dir}")

# phase 2: a fresh process restores and finishes
t2 = Trainer(cfg, opt_cfg, data_cfg,
             TrainerConfig(total_steps=steps,
                           checkpoint_every=max(steps // 4, 1),
                           log_every=max(steps // 10, 1)),
             ckpt_dir, pod_time_fn=pod_time)
assert t2.try_restore(), "restore failed"
print(f"-- resumed at step {t2.step} (data stream skipped ahead exactly)")
hist = t2.run()

print(f"\nfinal loss: {hist[-1]['loss']:.4f} "
      f"(first: {hist[0]['loss']:.4f})")
print("supervisor events:")
for e in t2.supervisor.events:
    print(f"  step {e.step}: {e.kind} — {e.detail}")
