"""5-point Jacobi stencil Pallas kernel (paper's cache-intensive node and
the compute body of the distributed 2D Heat application).

Halo strategy (TPU-native): rather than overlapping DMA windows, each grid
cell reads its own (bh, bw) tile plus the four *neighbor tiles* via extra
BlockSpecs whose index maps are clamped at the domain edge.  Only one edge
row/column of each neighbor is consumed; masks built from
``broadcasted_iota`` zero the contribution at true domain boundaries
(Dirichlet).  Tiles are (256, 256) f32 = 256 KiB -> 5 tiles ≈ 1.25 MiB in
VMEM, comfortably double-bufferable.

Batch dimension is grid-mapped with one row of tiles per image.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(c_ref, l_ref, r_ref, u_ref, d_ref, o_ref, *, bh, bw):
    i = pl.program_id(1)      # tile-row
    j = pl.program_id(2)      # tile-col
    ni = pl.num_programs(1)
    nj = pl.num_programs(2)
    c = c_ref[0]

    # columns from the left/right neighbor tiles (zero at domain edges)
    left_col = jnp.where(j > 0, l_ref[0, :, -1], 0.0)
    right_col = jnp.where(j < nj - 1, r_ref[0, :, 0], 0.0)
    up_row = jnp.where(i > 0, u_ref[0, -1, :], 0.0)
    down_row = jnp.where(i < ni - 1, d_ref[0, 0, :], 0.0)

    shift_l = jnp.concatenate([left_col[:, None], c[:, :-1]], axis=1)
    shift_r = jnp.concatenate([c[:, 1:], right_col[:, None]], axis=1)
    shift_u = jnp.concatenate([up_row[None, :], c[:-1, :]], axis=0)
    shift_d = jnp.concatenate([c[1:, :], down_row[None, :]], axis=0)

    o_ref[0] = (0.25 * (shift_l + shift_r + shift_u + shift_d)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "bw", "interpret"))
def stencil_pallas(u: jax.Array, *, bh: int = 256, bw: int = 256,
                   interpret: bool = False) -> jax.Array:
    b, h, w = u.shape
    bh, bw = min(bh, h), min(bw, w)
    if h % bh or w % bw:
        raise ValueError(f"shape ({h},{w}) not divisible by ({bh},{bw})")
    ni, nj = h // bh, w // bw

    def center(bi, i, j):
        return (bi, i, j)

    def left(bi, i, j):
        return (bi, i, jnp.maximum(j - 1, 0))

    def right(bi, i, j):
        return (bi, i, jnp.minimum(j + 1, nj - 1))

    def up(bi, i, j):
        return (bi, jnp.maximum(i - 1, 0), j)

    def down(bi, i, j):
        return (bi, jnp.minimum(i + 1, ni - 1), j)

    spec = lambda index_map: pl.BlockSpec((1, bh, bw), index_map)
    return pl.pallas_call(
        functools.partial(_stencil_kernel, bh=bh, bw=bw),
        grid=(b, ni, nj),
        in_specs=[spec(center), spec(left), spec(right), spec(up), spec(down)],
        out_specs=spec(center),
        out_shape=jax.ShapeDtypeStruct((b, h, w), u.dtype),
        interpret=interpret,
    )(u, u, u, u, u)
