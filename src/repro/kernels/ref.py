"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels_*.py`` and the default execution path on CPU
(see ops.py).  No pallas imports here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation (MXU semantics)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def copy_ref(x: jax.Array) -> jax.Array:
    """Streaming identity (the paper's memory-intensive node)."""
    return x + jnp.zeros((), x.dtype)     # defeat trivial aliasing


def stencil_ref(u: jax.Array) -> jax.Array:
    """One Jacobi step of the 5-point 2D heat stencil with zero (Dirichlet)
    boundary: u'[i,j] = 0.25*(u[i-1,j]+u[i+1,j]+u[i,j-1]+u[i,j+1])."""
    up = jnp.pad(u, ((0, 0), (1, 1), (1, 1)))
    return 0.25 * (up[:, :-2, 1:-1] + up[:, 2:, 1:-1]
                   + up[:, 1:-1, :-2] + up[:, 1:-1, 2:]).astype(u.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None) -> jax.Array:
    """GQA attention oracle.

    q: [B, Hq, S, D]; k/v: [B, Hkv, T, D] with Hq % Hkv == 0.
    Softmax in f32; causal mask aligns the *ends* of q and kv windows
    (standard convention for prefill where T >= S).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    t = k.shape[2]
    if causal:
        q_pos = jnp.arange(s)[:, None] + (t - s)
        k_pos = jnp.arange(t)[None, :]
        logits = jnp.where(k_pos <= q_pos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True, scale: float | None = None,
                          q_chunk: int = 512) -> jax.Array:
    """Memory-bounded XLA attention: lax.scan over q chunks with full K/V
    per chunk (peak O(bq*T) instead of O(S*T)).  Same semantics as
    attention_ref; this is what the CPU/dry-run path lowers for long
    sequences (the Pallas flash kernel covers the TPU path).

    GQA is expressed by grouping the query (no KV repeat — a 5x f32 KV
    materialization).  Sharding is pinned ONCE outside the chunk loop:
    q sequence-sharded over the model axis, K/V replicated — every chunk
    iteration is then fully local.  Left free, GSPMD shards the d=128
    *contraction* and all-reduces 1.3 GB of logits per chunk per layer —
    4.1 TB/step measured on qwen2.5-14b prefill_32k (EXPERIMENTS.md §Perf
    cell 3); pinning *inside* the loop instead reshards the stacked output
    buffer per chunk (also measured, far worse)."""
    from ..parallel.sharding import constrain
    b, hq, s, dm = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else dm ** -0.5
    while s % q_chunk:
        q_chunk //= 2
    n_chunks = s // q_chunk
    offset = t - s
    kf = constrain(k, ("dp", None, None, None))   # stays bf16: f32 accum via
    vf = constrain(v, ("dp", None, None, None))   # preferred_element_type
    qc = q.reshape(b, hkv, group, n_chunks, q_chunk, dm).transpose(
        3, 0, 1, 2, 4, 5)                                     # [C,B,Hkv,G,s,D]
    qc = constrain(qc, (None, "dp", None, None, "model", None))

    def chunk(i, q_i):
        logits = jnp.einsum("bhgsd,bhtd->bhgst", q_i, kf,
                            preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = i * q_chunk + jnp.arange(q_chunk)[:, None] + offset
            k_pos = jnp.arange(t)[None, :]
            logits = jnp.where(k_pos <= q_pos, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgst,bhtd->bhgsd", w, vf,
                          preferred_element_type=jnp.float32)

    out = jax.lax.map(lambda iq: chunk(iq[0], iq[1]),
                      (jnp.arange(n_chunks), qc))             # [C,B,Hkv,G,s,D]
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, dm)
    return out.astype(q.dtype)


def ssd_ref(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Mamba-2 SSD (scalar-A state space) oracle via a plain scan.

    x: [B, S, H, D]   token inputs per head
    a: [B, S, H]      log-decay (a <= 0; state multiplier is exp(a))
    b: [B, S, N]      input projection  (shared across heads, Mamba-2 style)
    c: [B, S, N]      output projection
    returns y: [B, S, H, D] with
      h_t = exp(a_t) * h_{t-1} + b_t ⊗ x_t      (h: [H, D, N])
      y_t = h_t @ c_t
    """
    bs, s, h, d = x.shape
    n = b.shape[-1]

    def step(hprev, inp):
        xt, at, bt, ct = inp
        hnew = jnp.exp(at)[:, None, None] * hprev + \
            xt[:, :, None] * bt[None, None, :]
        yt = jnp.einsum("hdn,n->hd", hnew, ct)
        return hnew, yt

    def per_batch(xb, ab, bb, cb):
        h0 = jnp.zeros((h, d, n), jnp.float32)
        _, yb = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        ab.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return yb

    y = jax.vmap(per_batch)(x, a, b, c)
    return y.astype(x.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *, scale: float | None = None) -> jax.Array:
    """Single-token decode attention oracle.

    q: [B, Hq, D]; k/v_cache: [B, T, Hkv, D]; lengths: [B] (valid prefix).

    GQA is expressed by *grouping the query* [B, Hkv, G, D] rather than
    repeating the cache — repeating a sequence-sharded cache makes GSPMD
    re-shard it by head (a full-cache replication every decode step).  The
    logits are pinned sequence-sharded; softmax over the sharded T lowers
    to cheap per-(b,h) all-reduces.
    """
    from ..parallel.sharding import constrain
    bsz, hq, d = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(bsz, hkv, group, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg,
                        k_cache.astype(jnp.float32)) * scale
    logits = constrain(logits, ("dp", None, None, "model"))
    mask = jnp.arange(t)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(bsz, hq, d).astype(q.dtype)
