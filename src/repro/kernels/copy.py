"""Tiled streaming-copy Pallas kernel (the paper's memory-intensive node).

Pure HBM->VMEM->HBM stream: each grid cell moves one (bm, bn) tile.  The
tile shape (512, 1024) x f32 = 2 MiB saturates the DMA pipeline while
keeping double-buffered usage at 8 MiB of the ~16 MiB VMEM.  This kernel
exists to give the runtime's PTT a pure bandwidth-bound task type whose
performance reacts to memory interference, mirroring the paper's Copy DAG.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def copy_pallas(x: jax.Array, *, bm: int = 512, bn: int = 1024,
                interpret: bool = False) -> jax.Array:
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by ({bm},{bn})")
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x)
