"""Fused causal GQA flash attention (Pallas, TPU target).

Online-softmax formulation: grid (B, Hq, S/bq, T/bk) with the KV dimension
innermost and sequential; running row-max m, normalizer l and the f32
accumulator live in VMEM scratch and persist across KV steps.  GQA is
expressed in the K/V BlockSpec index maps (query head h reads KV head
h // group), so no repeated KV materialization ever exists in HBM or VMEM.

Causal masking aligns the ends of the q and kv windows (T >= S: the last
query row attends to all T keys).  Fully-masked KV blocks are skipped via
``pl.when`` on block-level bounds, saving ~half the work for square causal
attention.

Default blocks (bq, bk) = (256, 256): at D=128 f32, VMEM holds
q (128 KiB) + k + v (2x128 KiB) + acc (128 KiB) + s/p (256 KiB) ≈ 0.8 MiB,
leaving the pipeline room to double-buffer K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, bq, bk, s_len, t_len):
    i = pl.program_id(2)           # q block
    j = pl.program_id(3)           # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    offset = t_len - s_len
    # block-level skip: the first key of this block is beyond the last
    # query position of this q block -> entire block masked out.
    q_pos_max = i * bq + (bq - 1) + offset
    live = jnp.logical_or(jnp.logical_not(causal), j * bk <= q_pos_max)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _flush():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    bq = min(bq, s)
    bk = min(bk, t)
    if s % bq or t % bk:
        raise ValueError(f"S={s}/T={t} not divisible by blocks ({bq},{bk})")
    scale_v = scale if scale is not None else d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale_v, causal=causal, bq=bq, bk=bk,
        s_len=s, t_len=t)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
