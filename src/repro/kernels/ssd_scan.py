"""Mamba-2 SSD chunked scan (Pallas, TPU target).

The SSD recurrence  h_t = exp(a_t) h_{t-1} + B_t ⊗ x_t,  y_t = h_t C_t
is evaluated chunk-wise (Dao & Gu, arXiv:2405.21060): within a chunk of L
tokens the contribution is a lower-triangular "attention-like" matmul
(MXU-friendly); across chunks a [D, N] state is carried in VMEM scratch
along the sequential chunk grid dimension.

Grid (B, H, S/L); per-chunk work is three small matmuls:
  G   = tril(exp(Acum_t - Acum_u) * (C_t · B_u))   [L, L]
  y   = G @ x  +  exp(Acum) * (C @ h_prevᵀ)        [L, D]
  h'  = exp(A_total) h_prev + (w ⊙ x)ᵀ @ B          [D, N]
With L=128, D=64, N=128 the VMEM footprint is well under 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # [L, D]
    a = a_ref[0, 0].astype(jnp.float32)          # [L]
    bmat = b_ref[0].astype(jnp.float32)          # [L, N]
    cmat = c_ref[0].astype(jnp.float32)          # [L, N]

    acum = jnp.cumsum(a)                         # [L] inclusive log-decay
    a_total = acum[-1]

    # intra-chunk: y_intra[t] = sum_{u<=t} exp(acum_t - acum_u) (C_t·B_u) x_u
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    decay = jnp.exp(acum[:, None] - acum[None, :])
    l_idx = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    g = jnp.where(u_idx <= l_idx, cb * decay, 0.0)
    y = jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, D]

    # inter-chunk carry: y_carry[t] = exp(acum_t) * (C_t · h_prev)
    h_prev = h_ref[...]                           # [D, N]
    y += jnp.exp(acum)[:, None] * jax.lax.dot_general(
        cmat, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [L, D]

    # state update: h' = exp(a_total) h_prev + sum_u exp(a_total-acum_u) x_u B_u
    w = jnp.exp(a_total - acum)                   # [L]
    h_ref[...] = jnp.exp(a_total) * h_prev + jax.lax.dot_general(
        x * w[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [D, N]

    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
                    chunk: int = 128, interpret: bool = False) -> jax.Array:
    """x: [B,S,H,D], a: [B,S,H], b,c: [B,S,N] -> y: [B,S,H,D] (see ref.ssd_ref)."""
    bs, s, h, d = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    nc = s // chunk

    xt = jnp.swapaxes(x, 1, 2)                    # [B, H, S, D]
    at = jnp.swapaxes(a, 1, 2)                    # [B, H, S]

    yt = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bs, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct(xt.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, at, b, c)
    return jnp.swapaxes(yt, 1, 2)
