"""Pallas TPU kernels for the framework's compute hot-spots.

<name>.py      — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
ops.py         — jit'd public wrappers with CPU(XLA)/TPU(Pallas) dispatch
ref.py         — pure-jnp oracles used for validation and the CPU path

Kernels: matmul / copy / stencil (the paper's three synthetic node types,
also used as real payloads by the threaded runtime), flash_attention
(LM backbone), ssd_scan (Mamba-2 hybrid archs).
"""
from . import ops, ref
from .copy import copy_pallas
from .flash_attention import flash_attention_pallas
from .matmul import matmul_pallas
from .ssd_scan import ssd_scan_pallas
from .stencil import stencil_pallas

__all__ = ["ops", "ref", "copy_pallas", "flash_attention_pallas",
           "matmul_pallas", "ssd_scan_pallas", "stencil_pallas"]
