"""Blocked MXU matmul Pallas kernel (the paper's compute-intensive node).

Grid (M/bm, N/bn, K/bk); A and B tiles stream HBM->VMEM per BlockSpec; the
f32 accumulator lives in VMEM scratch and is flushed to the output tile on
the last K step.  Block sizes default to 128x128x128 — one MXU-aligned tile
per dimension (multiples of 128 keep the systolic array fully fed); at
(128,128,128)xf32 the VMEM working set is 3 tiles * 64 KiB + 64 KiB
accumulator, far under the ~16 MiB per-core VMEM budget, leaving room for
double buffering by the pipeline emitter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = False) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch {k} vs {k2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k})x({k},{n}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                  pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
