"""Public kernel entry points.

Each op dispatches to the Pallas kernel on TPU (or when
``REPRO_FORCE_PALLAS_INTERPRET=1`` forces the interpreter for validation)
and to the pure-jnp reference (XLA) otherwise.  Model code and the task
runtime call *these*, never the kernels directly, so the same program runs
on this CPU-only container and on a real pod.
"""
from __future__ import annotations

import os

import jax

from . import ref
from .copy import copy_pallas
from .flash_attention import flash_attention_pallas
from .matmul import matmul_pallas
from .ssd_scan import ssd_scan_pallas
from .stencil import stencil_pallas


def _use_pallas() -> tuple[bool, bool]:
    """(use_pallas, interpret)."""
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return True, True
    platform = jax.default_backend()
    return platform == "tpu", False


def matmul(a: jax.Array, b: jax.Array, **blocks) -> jax.Array:
    use, interp = _use_pallas()
    if use and a.ndim == 2 and not (a.shape[0] % 128 or a.shape[1] % 128
                                    or b.shape[1] % 128):
        return matmul_pallas(a, b, interpret=interp, **blocks)
    return ref.matmul_ref(a, b)


def copy(x: jax.Array, **blocks) -> jax.Array:
    use, interp = _use_pallas()
    if use and x.ndim == 2 and not (x.shape[0] % 8 or x.shape[1] % 128):
        bm = min(blocks.pop("bm", 512), x.shape[0])
        bn = min(blocks.pop("bn", 1024), x.shape[1])
        return copy_pallas(x, bm=bm, bn=bn, interpret=interp, **blocks)
    return ref.copy_ref(x)


def stencil(u: jax.Array, **blocks) -> jax.Array:
    use, interp = _use_pallas()
    if use and u.ndim == 3 and not (u.shape[1] % 8 or u.shape[2] % 128):
        bh = min(blocks.pop("bh", 256), u.shape[1])
        bw = min(blocks.pop("bw", 256), u.shape[2])
        return stencil_pallas(u, bh=bh, bw=bw, interpret=interp, **blocks)
    return ref.stencil_ref(u)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    force_chunked: bool = False, **blocks) -> jax.Array:
    use, interp = _use_pallas()
    s, t, d = q.shape[2], k.shape[2], q.shape[3]
    shapes_ok = s >= 8 and t >= 128 and d % 8 == 0 and s % 8 == 0 and t % 128 == 0
    if use and shapes_ok:
        bq = min(blocks.pop("bq", 256), s)
        bk = min(blocks.pop("bk", 256), t)
        if s % bq == 0 and t % bk == 0:
            return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                          bq=bq, bk=bk, interpret=interp)
    if force_chunked or s * t > (1 << 26):
        # XLA path for truly long sequences (>=32k): bound peak memory at
        # O(bq x T).  At train lengths (4k) the plain path is strictly
        # better under SPMD: the lax.map over q chunks emitted per-chunk KV
        # all-gathers x layers x microbatches (measured: +45% collective
        # bytes on granite-8b train_4k — see EXPERIMENTS.md §Perf).
        return ref.attention_chunked_ref(q, k, v, causal=causal, scale=scale)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale)


def ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
             **blocks) -> jax.Array:
    use, interp = _use_pallas()
    s = x.shape[1]
    if use and s % 128 == 0:
        chunk = min(blocks.pop("chunk", 128), s)
        return ssd_scan_pallas(x, a, b, c, chunk=chunk, interpret=interp)
    return ref.ssd_ref(x, a, b, c)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None) -> jax.Array:
    """Single-token decode: a GEMV chain — XLA already emits the optimal
    fused loop on TPU, so there is no Pallas variant (documented decision)."""
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)
