"""Version compatibility for the Pallas TPU API surface.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` before jax 0.5;
the kernels target the new name but must run on the container's pinned
jax.  Import ``CompilerParams`` from here instead of from
``jax.experimental.pallas.tpu``.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
