from .train_step import (make_decode_step, make_forward_step, make_grad_step,
                         make_prefill_step, make_train_step)

__all__ = ["make_decode_step", "make_forward_step", "make_grad_step",
           "make_prefill_step", "make_train_step"]
