"""Fault-tolerant training loop.

Integrates every substrate: synthetic data stream (exact skip-ahead),
AdamW, async checkpointing, heartbeat failure detection, and the paper's
technique as the straggler layer — a PodMonitor (PTT over pods, 1:4
weighted) observing measured step times and emitting rebalance/drain
plans.  On this container the "pods" are simulated via an injectable
per-pod slowdown schedule, but every code path (detection, plan, restart,
resume) is the real one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from ..checkpoint import Checkpointer
from ..configs.base import ModelConfig
from ..data import DataConfig, SyntheticStream
from ..models import init_params
from ..optim import (AdamWConfig, init_error_feedback,
                     init_opt_state)
from ..runtime import HeartbeatMonitor, PodMonitor, Supervisor
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    seed: int = 0
    remat: bool = False
    grad_compression: str = "none"       # none | int8
    n_pods: int = 2                       # monitored pods (simulated here)
    straggler_check_every: int = 5


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 ckpt_dir: str, *,
                 pod_time_fn: Optional[Callable[[int, int], float]] = None):
        """``pod_time_fn(step, pod) -> seconds`` injects simulated per-pod
        step times for the straggler monitor (None = measure wall time)."""
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.ckpt = Checkpointer(ckpt_dir)
        self.stream = SyntheticStream(data_cfg)
        self.pod_time_fn = pod_time_fn

        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=tcfg.remat))
        self.params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
        self.opt_state = init_opt_state(self.params)
        self.error_fb = (init_error_feedback(self.params)
                         if tcfg.grad_compression != "none" else None)
        self.step = 0

        self.supervisor = Supervisor(
            heartbeat=HeartbeatMonitor(list(range(tcfg.n_pods)), timeout=30.0),
            pods=PodMonitor(tcfg.n_pods))
        self.history: list[dict] = []

    # -- checkpoint glue --------------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        self.ckpt.save_async(self.step, self._state_tree(),
                             extra={"data": self.stream.state()})

    def try_restore(self) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        tree, manifest = self.ckpt.restore(self._state_tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = manifest["step"]
        self.stream.skip_to(manifest["extra"]["data"]["step"])
        return True

    # -- main loop ----------------------------------------------------------------
    def run(self) -> list[dict]:
        tcfg = self.tcfg
        while self.step < tcfg.total_steps:
            batch = next(self.stream)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            wall = time.perf_counter() - t0
            self.step += 1

            # feed the straggler monitor (paper's PTT over pods)
            for pod in range(tcfg.n_pods):
                t = (self.pod_time_fn(self.step, pod)
                     if self.pod_time_fn else wall)
                self.supervisor.pods.observe(pod, t)
                self.supervisor.heartbeat.beat(pod)

            if self.step % tcfg.straggler_check_every == 0:
                plan = self.supervisor.elastic_plan(self.step)
                if plan is not None and plan.kind != "none":
                    metrics["rescale"] = plan.kind
            if self.step % tcfg.checkpoint_every == 0:
                self.save()
            rec = {"step": self.step, "wall_s": wall, **metrics}
            self.history.append(rec)
            if self.step % tcfg.log_every == 0:
                print(f"[train] step {self.step:5d} loss={metrics['loss']:.4f} "
                      f"lr={metrics['lr']:.2e} gnorm={metrics['grad_norm']:.2f} "
                      f"({wall*1e3:.0f} ms)")
        self.ckpt.wait()
        return self.history
