"""The jit-able train/prefill/decode step functions.

These are what the dry-run lowers and what the trainer executes; all
sharding decisions live in parallel/sharding.py, all math in models/.
"""
from __future__ import annotations

from typing import Any

import jax

from ..configs.base import ModelConfig
from ..models import decode_step as _decode_step
from ..models import loss_and_metrics
from ..models.transformer import forward as _forward
from ..models.transformer import prefill as _prefill
from ..optim import AdamWConfig, apply_updates

PyTree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    remat: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return loss_and_metrics(p, cfg, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, info = apply_updates(params, grads, opt_state,
                                                  opt_cfg)
        return new_params, new_opt, {**metrics, **info, "total_loss": loss}

    return train_step


def make_grad_step(cfg: ModelConfig, *, remat: bool = True):
    """Gradient-only step for grad-accum / compression paths."""

    def grad_step(params, batch):
        def loss_fn(p):
            return loss_and_metrics(p, cfg, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return grads, {**metrics, "total_loss": loss}

    return grad_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """(params, batch) -> (last-token logits, decode state)."""

    def prefill_step(params, batch):
        return _prefill(params, cfg, batch["tokens"], max_len,
                        batch.get("frontend"))

    return prefill_step


def make_forward_step(cfg: ModelConfig):
    """Inference forward (logits only) — the compute body of prefill."""

    def forward_step(params, batch):
        logits, _ = _forward(params, cfg, batch["tokens"],
                             batch.get("frontend"))
        return logits

    return forward_step


def make_decode_step(cfg: ModelConfig):
    """(params, state, tokens[B]) -> (logits [B,V], state)."""

    def serve_step(params, state, tokens):
        return _decode_step(params, cfg, state, tokens)

    return serve_step
