"""Sharding rules: leaf-name-driven PartitionSpecs for params, optimizer
state, batches and decode state.

Mesh axes:
  single-pod:  ("data", "model") = (16, 16)          — 256 chips
  multi-pod:   ("pod", "data", "model") = (2, 16, 16) — 512 chips

Parallelism mapping:
  DP  — batch over ("pod", "data") (hierarchical all-reduce: ICI inside a
        pod, DCN across pods).
  TP  — Megatron column/row sharding over "model": wq/wk/wv/w_gate/w_up
        column-sharded, wo/w_down row-sharded; vocab-sharded embedding and
        lm_head.
  EP  — expert stacks [E, ...] sharded over "model" (dispatch all-to-all
        stays inside the pod's ICI domain).
  SP  — long-context decode KV caches sharded over "model" on the
        *sequence* dim; softmax over the sharded dim lowers to cheap
        per-(b,h) all-reduces.
  ZeRO-1 — optimizer moments additionally sharded over "data" on the
        first replicated dim that divides.

Every spec is *sanitized* against real dim sizes: an axis that does not
divide the dim is dropped (replicated) rather than failing, so the same
rules serve the full configs, the reduced smoke configs, and any mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# base spec per leaf name, for the *unstacked* (per-layer) shape
_RULES: dict[str, tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("model", None),
    "lm_head": (None, "model"),
    "final_norm": (None,),
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "wo": ("model", None),
    # FFN
    "w_gate": (None, "model"), "w_up": (None, "model"),
    "w_down": ("model", None),
    # MoE (leading E axis = expert parallelism)
    "router": (None, None),
    "experts_gate": ("model", None, None),
    "experts_up": ("model", None, None),
    "experts_down": ("model", None, None),
    # Mamba-2
    "wx": (None, "model"), "wz": (None, "model"),
    "wb": (None, None), "wc": (None, None), "wdt": (None, "model"),
    "conv_w": (None, "model"), "dt_bias": ("model",), "a_log": ("model",),
    "norm_z": ("model",), "w_out": ("model", None),
    # mLSTM
    "w_x": (None, "model"), "w_gate_proj": (None, "model"),
    "w_if": (None, None), "norm_h": ("model",),
    # sLSTM
    "w_i": (None, "model"), "w_f": (None, "model"),
    "w_z": (None, "model"), "w_o": (None, "model"),
    "r_gates": (None, "model"),
    "w_up_a": (None, "model"), "w_up_b": (None, "model"),
    # norms
    "ln1": (None,), "ln2": (None,),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def sanitize(spec: tuple, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't divide their dim; trim/pad rank."""
    spec = tuple(spec)[:len(shape)] + (None,) * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _is_stacked(path) -> bool:
    return any(getattr(p, "key", None) == "stacks" for p in path)


def param_specs(params_shape: PyTree, mesh: Mesh, *,
                fsdp: bool = False) -> PyTree:
    """PartitionSpec tree for a params(-shaped) tree.  Stacked leaves (under
    "stacks") get a leading None for the layer axis.

    ``fsdp``: additionally shard each leaf over "data" on its first free
    dim (ZeRO-3 / FSDP) — required when bf16 params / TP don't fit HBM
    (e.g. the 70B VLM backbone).  XLA then all-gathers each layer's weights
    just-in-time; we never shard params over "pod" (DCN gathers per layer
    would be ruinous)."""
    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        # (§Perf cell-2 note: replicating sLSTM weights to avoid its
        # per-timestep all-reduces was tried and REFUTED — it trades tiny
        # ARs for 16x redundant per-device work; TP-sharded sLSTM stays.)
        base = _RULES.get(name)
        if base is None:
            base = (None,) * len(shape)
        elif _is_stacked(path):
            base = (None,) + tuple(base)
        spec = tuple(sanitize(base, shape, mesh))
        if fsdp:
            axes = list(spec) + [None] * (len(shape) - len(spec))
            for i, (dim, ax) in enumerate(zip(shape, axes)):
                if ax is None and dim > 1 and dim % _axis_size(mesh, "data") == 0:
                    axes[i] = "data"
                    break
            spec = tuple(axes)
        return sanitize(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_moment_specs(params_shape: PyTree, mesh: Mesh) -> PyTree:
    """ZeRO-1: like param specs but with "data" folded into the first
    still-replicated dim that divides — optimizer memory scales 1/DP."""
    base = param_specs(params_shape, mesh)

    def zero1(path, leaf, spec):
        shape = tuple(leaf.shape)
        axes = list(spec)
        axes += [None] * (len(shape) - len(axes))
        for i, (dim, ax) in enumerate(zip(shape, axes)):
            if ax is None and dim % _axis_size(mesh, "data") == 0 and dim > 1:
                axes[i] = "data"
                break
        return sanitize(tuple(axes), shape, mesh)

    return jax.tree_util.tree_map_with_path(zero1, params_shape, base)


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_specs(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Token batches: batch dim over DP axes, rest replicated."""
    dp = dp_axes(mesh)

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        return sanitize((dp,) + (None,) * (len(shape) - 1), shape, mesh)

    return jax.tree.map(spec_for, batch_shape)


def decode_state_specs(state_shape: PyTree, mesh: Mesh) -> PyTree:
    """Decode caches/states.  Leaves live under stacked layer groups with a
    leading L axis: [L, B, ...].  KV caches [L, B, T, Hkv, D] shard B over
    DP and T (sequence) over "model" (SP for long context); recurrent
    states [L, B, H, ...] shard B over DP and H over "model"."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if name in ("k", "v") and len(shape) == 5:      # [L,B,T,Hkv,D]
            return sanitize((None, dp, "model", None, None), shape, mesh)
        if name == "length":
            return sanitize((None, dp), shape, mesh)
        if name in ("ssm", "C") and len(shape) == 5:    # [L,B,H,D,N]
            return sanitize((None, dp, "model", None, None), shape, mesh)
        if name == "conv" and len(shape) == 4:          # [L,B,W-1,Di]
            return sanitize((None, dp, None, "model"), shape, mesh)
        if name == "n" and len(shape) == 4:             # [L,B,H,N]
            return sanitize((None, dp, "model", None), shape, mesh)
        if len(shape) == 3:                             # slstm [L,B,d]
            return sanitize((None, dp, "model"), shape, mesh)
        return sanitize((None, dp) + (None,) * (len(shape) - 2), shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, state_shape)


def to_named(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# In-model sharding constraints.
#
# Model code must not depend on a concrete mesh, but long-context decode
# needs activation pins (e.g. "keep the KV cache sequence-sharded") or GSPMD
# picks catastrophic reshards.  ``sharding_ctx(mesh)`` is entered by the
# launcher/dry-run around tracing; ``constrain(x, axes)`` then applies a
# sanitized with_sharding_constraint, and is a no-op outside the context
# (CPU unit tests, single-device runs).  The sentinel "dp" expands to the
# mesh's data-parallel axes.
# ---------------------------------------------------------------------------

import contextlib

_ACTIVE_MESH: list[Mesh] = []


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh):
    _ACTIVE_MESH.append(mesh)
    try:
        yield
    finally:
        _ACTIVE_MESH.pop()


def constrain(x: jax.Array, axes: tuple) -> jax.Array:
    if not _ACTIVE_MESH:
        return x
    mesh = _ACTIVE_MESH[-1]
    resolved = tuple(dp_axes(mesh) if a == "dp" else a for a in axes)
    spec = sanitize(resolved, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def pin_stack_cotangent(tree: PyTree, *, stacked: bool = True) -> PyTree:
    """Identity on the forward pass; on the backward pass constrains the
    weight-gradient cotangent to the ZeRO sharding (param spec + "data" on
    the first free dim).

    Why: the scan-over-layers backward accumulates the xs-cotangent (the
    stacked weight grads) at the sharding of the *gathered* per-layer
    weights — for FSDP'd params that is a model-only-sharded full-size
    buffer (tens of GB for a 70B model).  Applied to the per-layer slice
    *inside* the scan body, the constraint scatters each layer's gradient
    before it is accumulated — the ZeRO-3 backward (per-layer
    reduce-scatter); the loop buffer then carries only the scattered
    shard."""
    if not _ACTIVE_MESH:
        return tree
    mesh = _ACTIVE_MESH[-1]

    def leaf_spec(path, leaf):
        name = _leaf_name(path)
        base = _RULES.get(name, (None,) * (len(leaf.shape) - (1 if stacked else 0)))
        axes = ([None] if stacked else []) + list(base)
        axes += [None] * (len(leaf.shape) - len(axes))
        for i, (dim, ax) in enumerate(zip(leaf.shape, axes)):
            if ax is None and dim > 1 and dim % _axis_size(mesh, "data") == 0:
                axes[i] = "data"
                break
        return sanitize(tuple(axes), tuple(leaf.shape), mesh)

    specs = jax.tree_util.tree_map_with_path(leaf_spec, tree)

    @jax.custom_vjp
    def _pin(t):
        return t

    def _fwd(t):
        return t, None

    def _bwd(_, ct):
        return (jax.tree.map(
            lambda c, s: jax.lax.with_sharding_constraint(c, s), ct, specs),)

    _pin.defvjp(_fwd, _bwd)
    return _pin(tree)
