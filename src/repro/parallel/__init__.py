from .sharding import (batch_specs, constrain, decode_state_specs, dp_axes,
                       opt_moment_specs, param_specs, sanitize, sharding_ctx,
                       to_named)

__all__ = ["batch_specs", "constrain", "decode_state_specs", "dp_axes",
           "opt_moment_specs", "param_specs", "sanitize", "sharding_ctx",
           "to_named"]
