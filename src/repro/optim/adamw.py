"""AdamW with decoupled weight decay, warmup+cosine schedule, global-norm
clipping, and mixed-precision support (bf16 params keep fp32 moments and an
fp32 master copy).

Implemented by hand (no optax in the container) as pure pytree functions —
the moments' sharding comes from parallel.sharding.opt_moment_specs
(ZeRO-1 over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> dict:
    """Moments in fp32; fp32 master copy only when params are low-precision."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if any(p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params: PyTree, grads: PyTree, state: dict,
                  cfg: AdamWConfig) -> tuple[PyTree, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, info)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    masters = state.get("master", params)

    def upd(p32, m, v):
        mh = m / b1c
        vh = v / b2c
        return p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * p32)

    new_master = jax.tree.map(
        lambda p, m, v: upd(p.astype(jnp.float32), m, v), masters, new_m, new_v)
    new_params = jax.tree.map(lambda p, nm: nm.astype(p.dtype),
                              params, new_master)

    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    info = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, info
