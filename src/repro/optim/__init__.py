from .adamw import AdamWConfig, apply_updates, global_norm, init_opt_state, \
    schedule
from .compression import (compress_int8, compress_topk, init_error_feedback,
                          wire_bytes)

__all__ = ["AdamWConfig", "apply_updates", "global_norm", "init_opt_state",
           "schedule", "compress_int8", "compress_topk",
           "init_error_feedback", "wire_bytes"]
