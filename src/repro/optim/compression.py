"""Gradient compression for cross-pod (DCN) all-reduce.

Two schemes, both with error feedback (the residual of the lossy encode is
carried into the next step — required for convergence, 1-bit Adam lineage):

* int8 uniform quantization, per-leaf scale (32x smaller than f32 wire
  format at 8 bits + one scale; 4x vs bf16);
* top-k magnitude sparsification (keep fraction ``k``; indices+values).

On this container the compress->decompress round trip is exercised in-place
(no multi-host wire), which is exactly the lossy path a DCN all-gather of
quantized shards would see; tests assert the error-feedback invariant
(compressed + residual == original).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8(grads: PyTree, error: PyTree) -> tuple[PyTree, PyTree]:
    """Returns (decompressed grads as seen after the wire, new error)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize_int8(x)
        d = _dequantize_int8(q, s)
        return d, x - d

    pairs = jax.tree.map(one, grads, error)
    out = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return out, new_err


def compress_topk(grads: PyTree, error: PyTree, *, frac: float = 0.05
                  ) -> tuple[PyTree, PyTree]:
    """Keep the top ``frac`` fraction of entries by magnitude per leaf."""
    def one(g, e):
        x = (g.astype(jnp.float32) + e).reshape(-1)
        k = max(1, int(x.size * frac))
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        kept = jnp.zeros_like(x).at[idx].set(x[idx])
        d = kept.reshape(g.shape)
        return d, (x - kept).reshape(g.shape)

    pairs = jax.tree.map(one, grads, error)
    out = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return out, new_err


def wire_bytes(grads: PyTree, scheme: str, frac: float = 0.05) -> int:
    """Bytes a DCN all-gather would move per replica for this scheme."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    if scheme == "int8":
        return n + 4 * len(jax.tree.leaves(grads))
    if scheme == "topk":
        return int(n * frac) * 8            # 4B value + 4B index
    return n * 4                             # f32 baseline
