"""xLSTM-125M [ssm] — mLSTM blocks with sLSTM every 4th layer.
[arXiv:2405.04517; unverified]

sub_quadratic: pure recurrent state, O(1) per decode step — runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                   # xLSTM blocks carry their own up-projections
    vocab=50304,
    slstm_every=4,            # sLSTM at layers 3, 7, 11 (xLSTM mixed ratio)
    mlstm_proj_factor=2.0,
    sub_quadratic=True,
)
