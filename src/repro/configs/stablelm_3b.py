"""StableLM-3B [dense] — kv=32 means full MHA.
[hf:stabilityai/stablelm-*; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    act="swiglu",
    rope_theta=10000.0,
    rms_eps=1e-5,
)
