"""Zamba2-1.2B [hybrid] — Mamba-2 backbone + ONE weight-shared attention+FFN
block applied periodically.  [arXiv:2411.15242; hf]

sub_quadratic: the SSM state is O(1) in sequence length and the shared
attention applications are sparse, so this arch runs the long_500k shape.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,              # Mamba-2 layers
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,                # shared block's FFN
    vocab=32000,
    act="swiglu",
    ssm_state=64,
    mamba_head_dim=128,       # d_inner = 32*128 = 4096 = 2x expansion
    shared_attn_every=6,      # shared attn+FFN block after every 6 mamba layers
    rope_theta=10000.0,
    sub_quadratic=True,
)
