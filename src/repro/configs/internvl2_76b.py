"""InternVL2-76B [vlm] — InternViT frontend (STUB: precomputed patch
embeddings via input_specs) + 76B LM backbone. [arXiv:2404.16821; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    act="swiglu",
    rope_theta=500000.0,
    rms_eps=1e-5,
    frontend="vision",
    frontend_len=256,         # patch-embedding prefix length
)
