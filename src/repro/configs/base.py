"""Model configuration schema + input-shape suite.

Every assigned architecture is a ``ModelConfig`` instance (one file per
arch in this package).  ``reduced()`` derives the small same-family smoke
variant used by CPU tests; the full config is only ever lowered abstractly
by the dry-run.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "swiglu"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_shared_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # hybrid (Zamba2-style: Mamba-2 backbone + one shared attn+FFN block)
    ssm_state: int = 0
    mamba_head_dim: int = 64
    shared_attn_every: int = 0
    # xLSTM
    slstm_every: int = 0        # sLSTM at layers i % k == k-1; 0 = none
    mlstm_proj_factor: float = 2.0
    # modality frontend stub (precomputed embeddings via input_specs)
    frontend: str = "none"      # none | vision | audio
    frontend_len: int = 0
    # capabilities
    sub_quadratic: bool = False  # can run long_500k
    dtype: str = "float32"
    # Megatron-style sequence parallelism on the residual stream.  Pays a
    # structural price (weight-grad partial-sum all-reduces in the scan
    # backward) in exchange for 1/TP activation memory — worth it only for
    # archs whose activations/params are HBM-critical; the launcher sets it
    # alongside FSDP (see launch/dryrun.py §Perf iteration 3).
    seq_parallel: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":        # xLSTM
            n_sl = sum(1 for i in range(l)
                       if self.slstm_every and i % self.slstm_every == self.slstm_every - 1)
            n_ml = l - n_sl
            di = int(d * self.mlstm_proj_factor)
            ml = n_ml * (d * 2 * di + 3 * di * di + di * 2 * self.n_heads + di * d)
            sl = n_sl * (4 * d * d + int(d * 4 / 3) * d * 3)
            return emb + ml + sl
        if self.family == "hybrid":
            d_inner = self.n_heads * self.mamba_head_dim
            per_mamba = d * (2 * d_inner + 2 * self.ssm_state + self.n_heads) \
                + d_inner * d
            n_shared = l // max(self.shared_attn_every, 1) if self.shared_attn_every else 0
            shared = d * (self.n_heads + 2 * self.n_kv_heads) * hd + \
                self.n_heads * hd * d + 3 * d * self.d_ff
            return emb + l * per_mamba + (shared if n_shared else 0)
        attn = l * d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.n_experts:
            ff = l * (self.n_experts * 3 * d * self.d_ff
                      + (3 * d * self.moe_shared_ff if self.moe_shared_ff else 0)
                      + d * self.n_experts)
        else:
            gated = self.act in ("swiglu", "geglu")
            ff = l * (3 if gated else 2) * d * self.d_ff
        return emb + attn + ff

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.n_params
        d, l = self.d_model, self.n_layers
        inactive = l * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.n_params - inactive

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 5),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if not self.n_experts else 64,
            vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_shared_ff=128 if self.moe_shared_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            mamba_head_dim=32 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            frontend_len=16 if self.frontend != "none" else 0,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid cell; reason if not."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k-token decode is O(S) cache "
                       "per step with no sub-quadratic variant for this "
                       "config (skip noted in DESIGN.md §Arch-applicability)")
    return True, ""
