"""MusicGen-large [audio] — decoder-only transformer over EnCodec tokens;
text-conditioning frontend is a STUB (precomputed conditioning embeddings
via input_specs). [arXiv:2306.05284; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,               # EnCodec codebook
    act="gelu",
    rope_theta=10000.0,
    frontend="audio",
    frontend_len=64,          # conditioning prefix
)
