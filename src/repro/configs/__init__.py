"""Architecture registry + abstract input specs for every (arch, shape).

``input_specs(cfg, shape, mesh=None)`` returns ShapeDtypeStructs for every
input of the lowered step — the dry-run lowers against these without
allocating anything (weak-type-correct, shardable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import SHAPES, InputShape, ModelConfig, shape_applicable
from .granite_8b import CONFIG as _granite
from .internvl2_76b import CONFIG as _internvl
from .moonshot_v1_16b_a3b import CONFIG as _moonshot
from .musicgen_large import CONFIG as _musicgen
from .nemotron_4_15b import CONFIG as _nemotron
from .qwen2_5_14b import CONFIG as _qwen25
from .qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from .stablelm_3b import CONFIG as _stablelm
from .xlstm_125m import CONFIG as _xlstm
from .zamba2_1_2b import CONFIG as _zamba2

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        _qwen25, _granite, _nemotron, _stablelm, _zamba2, _moonshot,
        _qwen3moe, _internvl, _xlstm, _musicgen,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract train-step batch: tokens + labels (+ frontend stub)."""
    b = shape.global_batch
    s = shape.seq_len
    specs = {}
    if cfg.frontend != "none":
        s_text = s - cfg.frontend_len
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        s_text = s
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract decode-step inputs: current token ids (the state/cache specs
    come from eval_shape of init_decode_state)."""
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}


__all__ = ["ARCHS", "SHAPES", "InputShape", "ModelConfig", "get_config",
           "shape_applicable", "train_batch_specs", "decode_specs"]
