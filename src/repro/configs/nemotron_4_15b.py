"""Nemotron-4-15B [dense] — GQA + squared-ReLU FFN (ungated).
[arXiv:2402.16819; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    act="sq_relu",            # Primer-style squared ReLU, no gate
    rope_theta=10000.0,
    rms_eps=1e-5,
)
