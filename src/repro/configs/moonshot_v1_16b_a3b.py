"""Moonshot/Moonlight-16B-A3B [moe] — 64 experts top-6 + shared expert.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                # per-expert FFN width
    vocab=163840,
    act="swiglu",
    n_experts=64,
    top_k=6,
    moe_shared_ff=2816,       # DeepSeek-style shared expert (2x expert width)
    rope_theta=50000.0,
    rms_eps=1e-5,
)
