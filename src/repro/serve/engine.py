"""Serving engine scheduled by the paper's technique.

Mapping (DESIGN.md §2): requests are a *dynamic DAG* — a prefill task
(HIGH priority: it releases the request's entire decode chain, exactly
like the paper's critical tasks releasing the next DAG layer) followed by
decode tasks (LOW, moldable).  Execution places are submeshes of the
serving fleet; the PTT (one per task type = per prompt-length bucket)
learns each place's current speed from *measured* dispatch wall times, so
an interfered or throttled submesh is steered around within ~3 requests
(the paper's 1:4 hysteresis).

On this container, "submeshes" are CPU worker slots driven by the
threaded runtime; on a real fleet each place maps to a pjit program
compiled for that submesh shape (the compile cache keyed by place width).
The scheduler logic is byte-identical in both cases — both engines drive
the same :class:`~..core.lifecycle.SchedulingKernel` (DESIGN.md §3); that
is the point.

Two submission modes:

* **batch** — ``submit()`` everything, then ``run()`` (the original
  closed-loop shape, still used by the smoke tests);
* **open loop** — ``run_open_loop(prompts, rate_rps=...)`` starts the
  runtime first and submits continuously with seeded Poisson
  inter-arrival gaps, the serving-benchmark shape: queueing delay under
  interference shows up in the TTFT tail instead of being hidden by
  batch submission.  Per-request latency percentiles land in
  ``RunMetrics.request_latency_stats()``.

Graceful degradation (``deadline_s`` on :meth:`ServingEngine.submit`):
requests carry an optional deadline.  Admission control rejects a request
outright when even a PTT-best-case estimate (own chain + current backlog)
misses the deadline — the fleet never queues work that cannot finish in
time.  Once admitted, queued LOW decode tasks whose deadline has already
passed are *shed* (dropped, request finalized truncated) instead of
executed, so an overloaded fleet degrades output length rather than
collapsing every latency tail.  ``rejected`` / ``shed`` /
``deadline_miss`` counters land in the same latency stats.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import (Priority, RequestRecord, Task, TaskType, ThreadedRuntime,
                    Topology, make_scheduler)
from ..core.dag import DAG
from ..core.preemption import PreemptionModel
from ..models import decode_step, init_params
from ..models.transformer import prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # [S] int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    deadline_s: float = 0.0        # 0 = no deadline
    rejected: bool = False         # refused at admission, nothing ran
    shed: bool = False             # decode chain truncated past deadline


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """PTT-scheduled engine running a real (reduced) model on CPU."""

    def __init__(self, cfg: ModelConfig, topology: Topology, *,
                 scheduler: str = "DAM-P", seed: int = 0,
                 max_len: int = 256,
                 slowdown: Optional[dict[int, float]] = None,
                 preemption: Optional[PreemptionModel] = None,
                 faults=None, recovery=None, supervisor=None):
        self.cfg = cfg
        self.max_len = max_len
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.sched = make_scheduler(scheduler, topology, seed=seed)
        self.runtime = ThreadedRuntime(self.sched, slowdown=slowdown,
                                       preemption=preemption, faults=faults,
                                       recovery=recovery,
                                       supervisor=supervisor)
        self._prefill = jax.jit(
            lambda p, t: prefill(p, cfg, t, max_len),
            static_argnames=())
        self._decode = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
        self.requests: dict[int, Request] = {}
        self._rid = 0

    # -- task payloads ---------------------------------------------------------
    def _run_prefill(self, req: Request) -> tuple:
        toks = jnp.asarray(req.prompt)[None, :]
        logits, state = self._prefill(self.params, toks)
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        return state, nxt

    def _run_decode(self, req: Request, state, tok: int) -> tuple:
        logits, state = self._decode(self.params, state,
                                     jnp.asarray([tok], jnp.int32))
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        return state, nxt

    # -- graceful degradation ----------------------------------------------------
    def _ptt_floor(self, task_type: TaskType) -> float:
        """Best-case per-task seconds for ``task_type``: the smallest
        positive PTT expectation across the topology's places, falling
        back to the type's best serial-time prior while the table is
        still unexplored."""
        tbl = self.sched.ptt.for_type(task_type.name)
        seen = [tbl.get(p) for p in self.sched.topology.places()]
        seen = [v for v in seen if v > 0.0]
        return min(seen) if seen else min(task_type.serial_time.values())

    def _admission_estimate(self, pre_type: TaskType, dec_type: TaskType,
                            max_new_tokens: int) -> float:
        """Optimistic completion-time estimate used by deadline admission:
        the request's own prefill + decode chain at PTT-best speed, plus
        queueing delay approximated by the current backlog at decode-floor
        cost each.  Optimistic by construction — a reject means even the
        best case misses, so nothing that could finish is refused."""
        dec_floor = self._ptt_floor(dec_type)
        own = self._ptt_floor(pre_type) + max(max_new_tokens - 1, 0) * dec_floor
        return own + self.runtime.outstanding * dec_floor

    # -- request -> dynamic DAG --------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 8,
               deadline_s: float = 0.0) -> Request:
        self._rid += 1
        req = Request(self._rid, prompt.astype(np.int32), max_new_tokens,
                      t_submit=time.perf_counter(), deadline_s=deadline_s)
        self.requests[req.rid] = req

        pre_type = TaskType(
            f"prefill_{_bucket(len(prompt))}",
            serial_time={p.kind: 1e-3 for p in self.sched.topology.partitions})
        dec_type = TaskType(
            "decode",
            serial_time={p.kind: 1e-4 for p in self.sched.topology.partitions})

        if deadline_s > 0.0 and self._admission_estimate(
                pre_type, dec_type, max_new_tokens) > deadline_s:
            # deadline-aware admission: refuse rather than burn fleet time
            # on a request that cannot finish in time (nothing is queued)
            req.rejected = True
            req.t_first_token = req.t_done = req.t_submit
            return req

        ctx: dict = {}

        def prefill_payload(width: int, _req=req):
            ctx["state"], ctx["tok"] = self._run_prefill(_req)

        def make_decode_task(step_idx: int) -> Task:
            def decode_payload(width: int, _req=req):
                # load shedding: queued LOW decode work whose deadline has
                # already passed is dropped instead of executed — the
                # request finalizes truncated and the fleet time goes to
                # requests that can still meet theirs
                if (_req.deadline_s > 0.0 and time.perf_counter()
                        > _req.t_submit + _req.deadline_s):
                    _req.shed = True
                    return
                ctx["state"], ctx["tok"] = self._run_decode(
                    _req, ctx["state"], ctx["tok"])

            t = Task(dec_type, priority=Priority.LOW, payload=decode_payload)

            def on_commit(_task, _i=step_idx, _req=req):
                if not _req.shed and _i + 1 < _req.max_new_tokens - 1:
                    return [make_decode_task(_i + 1)]
                _req.t_done = time.perf_counter()
                return []

            t.on_commit = on_commit
            return t

        pre_task = Task(pre_type, priority=Priority.HIGH,
                        payload=prefill_payload)

        def pre_commit(_task, _req=req):
            # first token leaves the engine at prefill *commit* — after
            # any injected slowdown, when a real client would see it
            _req.t_first_token = time.perf_counter()
            if _req.max_new_tokens <= 1:
                _req.t_done = time.perf_counter()
                return []
            return [make_decode_task(0)]

        pre_task.on_commit = pre_commit
        self.runtime.submit(DAG([pre_task], 1 + max_new_tokens))
        return req

    def run(self, timeout: float = 120.0):
        m = self.runtime.run(timeout=timeout)
        self._finalize_requests()
        return m

    def run_open_loop(self, prompts: Sequence[np.ndarray], *,
                      rate_rps: float, max_new_tokens: int = 8,
                      arrival_seed: int = 0, deadline_s: float = 0.0,
                      timeout: float = 300.0):
        """Open-loop serving: start the runtime, then submit one request
        per prompt with Poisson inter-arrival gaps (seeded ``expovariate``
        at ``rate_rps`` requests/s) while earlier requests execute.
        ``deadline_s`` > 0 puts every request under that deadline
        (admission rejection + decode shedding).  Returns the
        :class:`RunMetrics` with per-request latency records attached."""
        arrivals = random.Random(f"serve-arrival:{arrival_seed}")
        self.runtime.start()
        for i, prompt in enumerate(prompts):
            if i:
                time.sleep(arrivals.expovariate(rate_rps))
            self.submit(np.asarray(prompt), max_new_tokens=max_new_tokens,
                        deadline_s=deadline_s)
        m = self.runtime.drain(timeout=timeout)
        self._finalize_requests()
        return m

    # -- metrics ----------------------------------------------------------------
    def _finalize_requests(self) -> None:
        """Fold completed requests into the runtime metrics as
        :class:`RequestRecord` rows (feeds p50/p95/p99 TTFT / e2e)."""
        metrics = self.runtime.metrics
        seen = {r.rid for r in metrics.request_records}
        for r in self.requests.values():
            if (r.t_done > 0 or r.rejected) and r.rid not in seen:
                metrics.record_request(RequestRecord(
                    rid=r.rid, t_submit=r.t_submit,
                    t_first_token=r.t_first_token, t_done=r.t_done,
                    deadline_s=r.deadline_s, rejected=r.rejected,
                    shed=r.shed))

    def latency_stats(self) -> dict:
        """Flat-key view over ``RunMetrics.request_latency_stats()`` (one
        stat path — the engine only reshapes keys for the CLI callers)."""
        self._finalize_requests()
        stats = self.runtime.metrics.request_latency_stats()
        if not stats:
            return {}
        out = {
            "completed": stats["completed"],
            "rejected": stats["rejected"],
            "shed": stats["shed"],
            "deadline_miss": stats["deadline_miss"],
        }
        if "ttft_ms" in stats:      # at least one request actually ran
            out.update({
                "ttft_ms_mean": stats["ttft_ms"]["mean"],
                "ttft_ms_p50": stats["ttft_ms"]["p50"],
                "ttft_ms_p95": stats["ttft_ms"]["p95"],
                "ttft_ms_p99": stats["ttft_ms"]["p99"],
                "e2e_ms_mean": stats["e2e_ms"]["mean"],
                "e2e_ms_p99": stats["e2e_ms"]["p99"],
            })
        return out
