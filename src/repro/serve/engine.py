"""Batched serving engine scheduled by the paper's technique.

Mapping (DESIGN.md §2): requests are a *dynamic DAG* — a prefill task
(HIGH priority: it releases the request's entire decode chain, exactly
like the paper's critical tasks releasing the next DAG layer) followed by
decode tasks (LOW, moldable).  Execution places are submeshes of the
serving fleet; the PTT (one per task type = per prompt-length bucket)
learns each place's current speed from *measured* dispatch wall times, so
an interfered or throttled submesh is steered around within ~3 requests
(the paper's 1:4 hysteresis).

On this container, "submeshes" are CPU worker slots driven by the
threaded runtime; on a real fleet each place maps to a pjit program
compiled for that submesh shape (the compile cache keyed by place width).
The scheduler logic is byte-identical in both cases — that is the point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import (Priority, Task, TaskType, ThreadedRuntime, Topology,
                    make_scheduler)
from ..core.dag import DAG
from ..models import decode_step, init_params
from ..models.transformer import prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # [S] int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """PTT-scheduled engine running a real (reduced) model on CPU."""

    def __init__(self, cfg: ModelConfig, topology: Topology, *,
                 scheduler: str = "DAM-P", seed: int = 0,
                 max_len: int = 256,
                 slowdown: Optional[dict[int, float]] = None):
        self.cfg = cfg
        self.max_len = max_len
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.sched = make_scheduler(scheduler, topology, seed=seed)
        self.runtime = ThreadedRuntime(self.sched, slowdown=slowdown)
        self._prefill = jax.jit(
            lambda p, t: prefill(p, cfg, t, max_len),
            static_argnames=())
        self._decode = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
        self.requests: dict[int, Request] = {}
        self._rid = 0

    # -- task payloads ---------------------------------------------------------
    def _run_prefill(self, req: Request) -> tuple:
        toks = jnp.asarray(req.prompt)[None, :]
        logits, state = self._prefill(self.params, toks)
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        req.t_first_token = time.perf_counter()
        return state, nxt

    def _run_decode(self, req: Request, state, tok: int) -> tuple:
        logits, state = self._decode(self.params, state,
                                     jnp.asarray([tok], jnp.int32))
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        return state, nxt

    # -- request -> dynamic DAG --------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 8) -> Request:
        self._rid += 1
        req = Request(self._rid, prompt.astype(np.int32), max_new_tokens,
                      t_submit=time.perf_counter())
        self.requests[req.rid] = req

        pre_type = TaskType(
            f"prefill_{_bucket(len(prompt))}",
            serial_time={p.kind: 1e-3 for p in self.sched.topology.partitions})
        dec_type = TaskType(
            "decode",
            serial_time={p.kind: 1e-4 for p in self.sched.topology.partitions})

        ctx: dict = {}

        def prefill_payload(width: int, _req=req):
            ctx["state"], ctx["tok"] = self._run_prefill(_req)

        def make_decode_task(step_idx: int) -> Task:
            def decode_payload(width: int, _req=req):
                ctx["state"], ctx["tok"] = self._run_decode(
                    _req, ctx["state"], ctx["tok"])

            t = Task(dec_type, priority=Priority.LOW, payload=decode_payload)

            def on_commit(_task, _i=step_idx, _req=req):
                if _i + 1 < _req.max_new_tokens - 1:
                    return [make_decode_task(_i + 1)]
                _req.t_done = time.perf_counter()
                return []

            t.on_commit = on_commit
            return t

        pre_task = Task(pre_type, priority=Priority.HIGH,
                        payload=prefill_payload)

        def pre_commit(_task, _req=req):
            if _req.max_new_tokens <= 1:
                _req.t_done = time.perf_counter()
                return []
            return [make_decode_task(0)]

        pre_task.on_commit = pre_commit
        self.runtime.submit(DAG([pre_task], 1 + max_new_tokens))
        return req

    def run(self, timeout: float = 120.0):
        return self.runtime.run(timeout=timeout)

    # -- metrics ----------------------------------------------------------------
    def latency_stats(self) -> dict:
        done = [r for r in self.requests.values() if r.t_done > 0]
        if not done:
            return {}
        ttft = [r.t_first_token - r.t_submit for r in done]
        e2e = [r.t_done - r.t_submit for r in done]
        return {
            "completed": len(done),
            "ttft_ms_mean": float(np.mean(ttft)) * 1e3,
            "ttft_ms_p95": float(np.percentile(ttft, 95)) * 1e3,
            "e2e_ms_mean": float(np.mean(e2e)) * 1e3,
        }
