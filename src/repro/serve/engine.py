"""Serving engine scheduled by the paper's technique.

Mapping (DESIGN.md §2): requests are a *dynamic DAG* — a prefill task
(HIGH priority: it releases the request's entire decode chain, exactly
like the paper's critical tasks releasing the next DAG layer) followed by
decode tasks (LOW, moldable).  Execution places are submeshes of the
serving fleet; the PTT (one per task type = per prompt-length bucket)
learns each place's current speed from *measured* dispatch wall times, so
an interfered or throttled submesh is steered around within ~3 requests
(the paper's 1:4 hysteresis).

On this container, "submeshes" are CPU worker slots driven by the
threaded runtime; on a real fleet each place maps to a pjit program
compiled for that submesh shape (the compile cache keyed by place width).
The scheduler logic is byte-identical in both cases — both engines drive
the same :class:`~..core.lifecycle.SchedulingKernel` (DESIGN.md §3); that
is the point.  ``cfg=None`` selects **synthetic-payload mode**: request
payloads are calibrated sleeps (``prefill_s`` / ``decode_s``) instead of
jitted model dispatches, which is what the overload benchmark uses to
push the fleet past saturation without paying model-compile time.

Two submission modes:

* **batch** — ``submit()`` everything, then ``run()`` (the original
  closed-loop shape, still used by the smoke tests);
* **open loop** — ``run_open_loop(prompts, rate_rps=...)`` starts the
  runtime first and submits continuously with seeded Poisson
  inter-arrival gaps, the serving-benchmark shape: queueing delay under
  interference shows up in the TTFT tail instead of being hidden by
  batch submission.  Per-request latency percentiles land in
  ``RunMetrics.request_latency_stats()``.

Robustness under load (this is the serving half of the load-aware
kernel, DESIGN.md §2):

* **Warm start** — ``warm_start=True`` (default) primes the PTT for each
  new task type via :meth:`SchedulingKernel.prime_ptt` before its first
  request is placed, so a cold table never herds early arrivals onto one
  unexplored place.  :meth:`prime` does it explicitly.
* **Load-aware admission** — ``_admission_estimate`` is per-place: the
  best over places of (outstanding estimated work *at that place* +
  the prefill estimate there), plus the decode chain at the fleet-best
  decode estimate.  A request is rejected (``reject_cause="deadline"``)
  only when even that estimate misses its deadline.
* **Backpressure** — ``max_pending`` bounds the number of admitted
  in-flight requests; past it, admission refuses immediately
  (``reject_cause="backpressure"``) instead of growing an unbounded
  queue.
* **Brownout ladder** — pass a :class:`~.overload.BrownoutConfig` to
  attach an :class:`~.overload.OverloadController` driven by the
  kernel's backlog signal (outstanding estimated seconds per live core),
  updated at every admission and completion.  Under sustained saturation
  it degrades LOW-tier traffic in order of destroyed value: rung 1
  clamps ``max_new_tokens`` to ``min_tokens``, rung 2 sheds queued LOW
  decode chains (``shed_cause="brownout"``), rung 3 rejects LOW
  admissions outright.  Each rung has hysteresis; every transition lands
  in ``RunMetrics.brownout_transitions`` and is counted by
  ``request_latency_stats()``.  HIGH-tier requests (``tier="high"``)
  are exempt from all three rungs.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Optional, Sequence

import numpy as np

from ..configs.base import ModelConfig
from ..core import (BatchingConfig, Priority, RequestRecord, Task, TaskType,
                    ThreadedRuntime, Topology, make_scheduler)
from ..core.dag import DAG
from ..core.preemption import PreemptionModel
from .batching import BatchSlot, DecodeBatcher
from .overload import BrownoutConfig, OverloadController


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # [S] int32
    max_new_tokens: int
    tier: str = "low"              # "high" is exempt from the brownout ladder
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    deadline_s: float = 0.0        # 0 = no deadline
    rejected: bool = False         # refused at admission, nothing ran
    shed: bool = False             # decode chain truncated
    reject_cause: str = ""         # "deadline" | "backpressure"
    shed_cause: str = ""           # "deadline" | "brownout"
    tokens_clamped: bool = False   # brownout rung 1 shrank max_new_tokens


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """PTT-scheduled engine: a real (reduced) model on CPU when ``cfg``
    is given, calibrated-sleep payloads when ``cfg is None``."""

    def __init__(self, cfg: Optional[ModelConfig], topology: Topology, *,
                 scheduler: str = "DAM-P", seed: int = 0,
                 max_len: int = 256,
                 slowdown: Optional[dict[int, float]] = None,
                 preemption: Optional[PreemptionModel] = None,
                 faults=None, recovery=None, supervisor=None,
                 queue_penalty: float = 1.0, warm_start: bool = True,
                 max_pending: Optional[int] = None,
                 brownout: Optional[BrownoutConfig] = None,
                 sharding=None,
                 batching: Optional[BatchingConfig] = None,
                 prefill_s: float = 8e-3, decode_s: float = 2e-3):
        self.cfg = cfg
        self.max_len = max_len
        self.prefill_s = prefill_s
        self.decode_s = decode_s
        # continuous batching: max_batch=1 is the unbatched path by
        # definition — normalize to None so every batching branch is dead
        if batching is not None and not batching.enabled:
            batching = None
        self.batching = batching
        self.batcher = DecodeBatcher(batching) if batching is not None \
            else None
        if cfg is not None:
            # real-model mode: jitted dispatches (deferred imports keep
            # synthetic engines from touching jax at all)
            import jax
            from ..models import decode_step, init_params
            from ..models.transformer import prefill
            self.params = init_params(cfg, jax.random.PRNGKey(seed))
            self._prefill = jax.jit(
                lambda p, t: prefill(p, cfg, t, max_len),
                static_argnames=())
            self._decode = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
        self.sched = make_scheduler(scheduler, topology, seed=seed,
                                    queue_penalty=queue_penalty,
                                    track_load=True)
        self.runtime = ThreadedRuntime(self.sched, slowdown=slowdown,
                                       preemption=preemption, faults=faults,
                                       recovery=recovery,
                                       supervisor=supervisor,
                                       sharding=sharding, batching=batching)
        self.warm_start = warm_start
        self.max_pending = max_pending
        self.controller = (OverloadController(brownout)
                           if brownout is not None else None)
        self.tokens_clamped = 0
        self.requests: dict[int, Request] = {}
        self._rid = 0
        self._pending = 0              # admitted, not yet finalized
        self._admit_lock = threading.Lock()
        self._primed: set[str] = set()
        # hoisted task types: one shared decode TaskType per engine and
        # one prefill TaskType per prompt-length bucket — per-request
        # construction built a fresh (value-equal) type object per submit
        # and defeated TaskType's batched-variant cache
        self._dec_type: Optional[TaskType] = None
        self._pre_types: dict[int, TaskType] = {}
        # batch-delay flusher (batched mode only): pumps the batcher so a
        # partial batch never waits past its delay window
        self._flush_stop = threading.Event()
        self._flush_thread: Optional[threading.Thread] = None

    # -- task payloads ---------------------------------------------------------
    def _run_prefill(self, req: Request) -> tuple:
        if self.cfg is None:
            time.sleep(self.prefill_s)
            req.out_tokens.append(0)
            return None, 0
        import jax.numpy as jnp
        toks = jnp.asarray(req.prompt)[None, :]
        logits, state = self._prefill(self.params, toks)
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        return state, nxt

    def _run_decode(self, req: Request, state, tok: int) -> tuple:
        if self.cfg is None:
            time.sleep(self.decode_s)
            req.out_tokens.append(0)
            return None, 0
        import jax.numpy as jnp
        logits, state = self._decode(self.params, state,
                                     jnp.asarray([tok], jnp.int32))
        nxt = int(jnp.argmax(logits[0]))
        req.out_tokens.append(nxt)
        return state, nxt

    # -- PTT warmup --------------------------------------------------------------
    def prime(self, *task_types: TaskType) -> int:
        """Explicitly seed the PTT for ``task_types`` (every unexplored
        place gets its cost-model prior — see
        :meth:`SchedulingKernel.prime_ptt`).  Returns entries primed."""
        n = 0
        for tt in task_types:
            n += self.runtime.kernel.prime_ptt(tt)
            self._primed.add(tt.name)
        return n

    def _maybe_prime(self, *task_types: TaskType) -> None:
        if not self.warm_start:
            return
        for tt in task_types:
            if tt.name not in self._primed:
                self.prime(tt)

    # -- graceful degradation ----------------------------------------------------
    def _best_estimate(self, task_type: TaskType) -> float:
        """Fleet-best per-task seconds for ``task_type`` (PTT entry or
        cost-model prior, whichever the kernel's estimator resolves)."""
        kernel = self.runtime.kernel
        return min(kernel.estimate_seconds(task_type, p)
                   for p in self.sched.topology.places())

    def _admission_estimate(self, pre_type: TaskType, dec_type: TaskType,
                            max_new_tokens: int) -> float:
        """Per-place, load-aware completion-time estimate for deadline
        admission: the best over places of (outstanding estimated work
        already at that place + the prefill estimate there), plus the
        request's decode chain.

        The chain is priced at the *batched* service rate when continuous
        batching is on — ``per_tok * (1 + member_cost*(b-1)) / b`` per
        token at fill ``b = max_batch``, plus one ``delay_s`` of batch
        fill — and carries the kernel's fleet-wide backlog signal once:
        the old estimate assumed every decode step lands on an idle
        fleet-best place, which under-estimated exactly when admission
        control matters (a loaded fleet) and admitted deadline-doomed
        requests."""
        kernel = self.runtime.kernel
        places = self.sched.topology.places()
        if kernel.track_load:
            load = kernel.place_load()
            start = min(load[i] + kernel.estimate_seconds(pre_type, p)
                        for i, p in enumerate(places))
            backlog = kernel.backlog_signal()
        else:
            start = self._best_estimate(pre_type)
            backlog = 0.0
        per_tok = self._best_estimate(dec_type)
        b = self.batching
        if b is not None:
            per_tok *= (1.0 + b.member_cost * (b.max_batch - 1)) / b.max_batch
            start += b.delay_s
        chain = max(max_new_tokens - 1, 0) * per_tok
        return start + chain + backlog

    def _elapsed(self) -> float:
        t0 = self.runtime.t0
        return 0.0 if t0 is None else time.perf_counter() - t0

    def _update_controller(self) -> int:
        """Fold the kernel's backlog signal into the brownout controller
        (called at every admission and completion)."""
        if self.controller is None:
            return 0
        signal = (self.runtime.kernel.backlog_signal()
                  if self.runtime.kernel.track_load else 0.0)
        with self._admit_lock:
            return self.controller.update(signal, self._elapsed())

    def _request_done(self, req: Request) -> None:
        req.t_done = time.perf_counter()
        with self._admit_lock:
            self._pending -= 1
        self._update_controller()

    # -- request -> dynamic DAG --------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 8,
               deadline_s: float = 0.0, tier: str = "low") -> Request:
        self._rid += 1
        req = Request(self._rid, np.asarray(prompt).astype(np.int32),
                      max_new_tokens, tier=tier,
                      t_submit=time.perf_counter(), deadline_s=deadline_s)
        self.requests[req.rid] = req

        def _reject(cause: str) -> Request:
            req.rejected = True
            req.reject_cause = cause
            req.t_first_token = req.t_done = req.t_submit
            return req

        # backpressure: a bounded pending queue, never unbounded growth —
        # past the bound the fleet refuses immediately rather than
        # queueing work it will finish long past anyone's patience
        if self.max_pending is not None and self._pending >= self.max_pending:
            return _reject("backpressure")

        self._update_controller()
        ctl = self.controller
        if ctl is not None and tier != "high":
            if ctl.reject_low:          # rung 3: refuse LOW at admission
                return _reject("backpressure")
            if ctl.shrink_low and max_new_tokens > ctl.config.min_tokens:
                # rung 1+: degrade LOW output length before dropping work
                req.max_new_tokens = max_new_tokens = ctl.config.min_tokens
                req.tokens_clamped = True
                self.tokens_clamped += 1

        pre_type = self._prefill_type(len(prompt))
        dec_type = self._decode_type()
        self._maybe_prime(pre_type, dec_type)

        if deadline_s > 0.0 and self._admission_estimate(
                pre_type, dec_type, max_new_tokens) > deadline_s:
            # deadline-aware admission: refuse rather than burn fleet time
            # on a request that cannot finish in time (nothing is queued)
            return _reject("deadline")

        with self._admit_lock:
            self._pending += 1
        # per-request step state bound to tasks via ``Task.args`` — no
        # per-token payload closures; payloads/commits are bound methods
        ctx: dict = {"step": 0}
        pre_task = Task(pre_type, priority=Priority.HIGH,
                        payload=self._prefill_payload, args=(req, ctx))
        pre_task.on_commit = self._prefill_commit
        self.runtime.submit(DAG([pre_task], 1 + max_new_tokens))
        return req

    # -- hoisted task types ------------------------------------------------------
    def _decode_type(self) -> TaskType:
        tt = self._dec_type
        if tt is None:
            kinds = {p.kind for p in self.sched.topology.partitions}
            dec_s = self.decode_s if self.cfg is None else 1e-4
            tt = self._dec_type = TaskType(
                "decode", serial_time={k: dec_s for k in kinds})
        return tt

    def _prefill_type(self, prompt_len: int) -> TaskType:
        b = _bucket(prompt_len)
        tt = self._pre_types.get(b)
        if tt is None:
            kinds = {p.kind for p in self.sched.topology.partitions}
            pre_s = self.prefill_s if self.cfg is None else 1e-3
            tt = self._pre_types[b] = TaskType(
                f"prefill_{b}", serial_time={k: pre_s for k in kinds})
        return tt

    # -- unbatched decode chain --------------------------------------------------
    def _prefill_payload(self, width: int, req: Request, ctx: dict) -> None:
        ctx["state"], ctx["tok"] = self._run_prefill(req)

    def _prefill_commit(self, task: Task) -> list[Task]:
        # first token leaves the engine at prefill *commit* — after any
        # injected slowdown, when a real client would see it
        req, ctx = task.args
        req.t_first_token = time.perf_counter()
        if req.max_new_tokens <= 1:
            self._request_done(req)
            return []
        if self.batcher is not None:
            # continuous batching: the ready decode step parks in the
            # batcher (outside the WSQs — HIGH prefills are never queued
            # behind batch fill) and dispatches when a trigger fires
            return self._groups_to_tasks(
                self.batcher.add(req, ctx, time.perf_counter()))
        return [self._make_decode_task(req, ctx)]

    def _make_decode_task(self, req: Request, ctx: dict) -> Task:
        t = Task(self._decode_type(), priority=Priority.LOW,
                 payload=self._decode_payload, args=(req, ctx))
        t.on_commit = self._decode_commit
        return t

    def _shed_check(self, req: Request) -> bool:
        """Load shedding: queued LOW decode work is dropped instead of
        executed — the request finalizes truncated and the fleet time
        goes to requests that still matter — when its deadline already
        passed, or the brownout ladder is at its shed rung and the
        request is LOW tier.  Returns True when ``req`` was shed."""
        if req.shed:
            return True
        if (req.deadline_s > 0.0 and time.perf_counter()
                > req.t_submit + req.deadline_s):
            req.shed = True
            req.shed_cause = "deadline"
            return True
        ctl = self.controller
        if ctl is not None and ctl.shed_low and req.tier != "high":
            req.shed = True
            req.shed_cause = "brownout"
            return True
        return False

    def _decode_payload(self, width: int, req: Request, ctx: dict) -> None:
        if self._shed_check(req):
            return
        ctx["state"], ctx["tok"] = self._run_decode(
            req, ctx["state"], ctx["tok"])

    def _decode_commit(self, task: Task) -> list[Task]:
        req, ctx = task.args
        ctx["step"] += 1
        if not req.shed and ctx["step"] < req.max_new_tokens - 1:
            return [self._make_decode_task(req, ctx)]
        self._request_done(req)
        return []

    # -- batched decode path (continuous batching) -------------------------------
    def _groups_to_tasks(self, groups: list[list[BatchSlot]]) -> list[Task]:
        return [self._make_batch_task(g) for g in groups]

    def _make_batch_task(self, slots: list[BatchSlot]) -> Task:
        """One fused moldable LOW dispatch over ``slots``: typed via
        :meth:`TaskType.batched` so the placement search, run charge and
        PTT observation all see the batch-size bucket."""
        btype = self._decode_type().batched(len(slots),
                                            self.batching.member_cost)
        t = Task(btype, priority=Priority.LOW, payload=self._batch_payload,
                 args=(tuple(slots),))
        t.on_commit = self._batch_commit
        return t

    def _batch_payload(self, width: int, slots: tuple) -> None:
        # membership re-check at dispatch: rung-2 shedding (and passed
        # deadlines) remove members, never the dispatch — survivors ride
        live = [s for s in slots if not self._shed_check(s.req)]
        if not live:
            return
        if self.cfg is None:
            # batched decode is memory-bound: one fused step costs the
            # base time plus member_cost per extra live member
            time.sleep(self.decode_s *
                       (1.0 + self.batching.member_cost * (len(live) - 1)))
            for s in live:
                s.req.out_tokens.append(0)
        else:
            for s in live:
                s.ctx["state"], s.ctx["tok"] = self._run_decode(
                    s.req, s.ctx["state"], s.ctx["tok"])

    def _batch_commit(self, task: Task) -> list[Task]:
        """Commit of a fused dispatch: finalize shed/finished members,
        re-park survivors' next steps in the batcher, and return any
        newly due dispatches (they wake as zero-dep successors)."""
        (slots,) = task.args
        now = time.perf_counter()
        ready: list[Task] = []
        for s in slots:
            req = s.req
            if not req.shed:
                s.ctx["step"] += 1
            if req.shed or s.ctx["step"] >= req.max_new_tokens - 1:
                self._request_done(req)
            else:
                ready.extend(self._groups_to_tasks(
                    self.batcher.readd(s, now)))
        return ready

    def _pump_batcher(self, drain: bool = False) -> None:
        """Flush due (or, on drain, all) pending batches into the
        runtime — the timer half of the delay window."""
        groups = self.batcher.poll(time.perf_counter(), drain=drain)
        for g in groups:
            self.runtime.submit(DAG([self._make_batch_task(g)], len(g)))

    def _flusher(self) -> None:
        period = max(self.batching.delay_s / 2.0, 1e-4)
        while not self._flush_stop.wait(timeout=period):
            self._pump_batcher()

    def _start_flusher(self) -> None:
        if self._flush_thread is None:
            self._flush_stop.clear()
            self._flush_thread = threading.Thread(target=self._flusher,
                                                  daemon=True)
            self._flush_thread.start()

    def _drain_batched(self, timeout: float):
        """Batched-mode drain: pump the batcher until every admitted
        request finalizes (slots parked in the batcher are invisible to
        the runtime's outstanding count — ``runtime.drain`` alone could
        return with requests still waiting on formation), then drain the
        runtime itself."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._admit_lock:
                if self._pending == 0:
                    break
            self._pump_batcher(drain=True)
            time.sleep(2e-3)
        self._flush_stop.set()
        m = self.runtime.drain(
            timeout=max(deadline - time.monotonic(), 1.0))
        if self._flush_thread is not None:
            self._flush_thread.join(timeout=5.0)
            self._flush_thread = None
        return m

    def run(self, timeout: float = 120.0):
        if self.batcher is not None:
            self.runtime.start()
            self._start_flusher()
            m = self._drain_batched(timeout)
        else:
            m = self.runtime.run(timeout=timeout)
        self._finalize_requests()
        return m

    def run_open_loop(self, prompts: Sequence[np.ndarray], *,
                      rate_rps: float, max_new_tokens: int = 8,
                      arrival_seed: int = 0, deadline_s: float = 0.0,
                      tier: str = "low", timeout: float = 300.0):
        """Open-loop serving: start the runtime, then submit one request
        per prompt with Poisson inter-arrival gaps (seeded ``expovariate``
        at ``rate_rps`` requests/s) while earlier requests execute.
        ``deadline_s`` > 0 puts every request under that deadline
        (admission rejection + decode shedding).  Returns the
        :class:`RunMetrics` with per-request latency records attached."""
        arrivals = random.Random(f"serve-arrival:{arrival_seed}")
        self.runtime.start()
        if self.batcher is not None:
            self._start_flusher()
        for i, prompt in enumerate(prompts):
            if i:
                time.sleep(arrivals.expovariate(rate_rps))
            self.submit(np.asarray(prompt), max_new_tokens=max_new_tokens,
                        deadline_s=deadline_s, tier=tier)
        if self.batcher is not None:
            m = self._drain_batched(timeout)
        else:
            m = self.runtime.drain(timeout=timeout)
        self._finalize_requests()
        return m

    # -- metrics ----------------------------------------------------------------
    def _finalize_requests(self) -> None:
        """Fold completed requests into the runtime metrics as
        :class:`RequestRecord` rows (feeds p50/p95/p99 TTFT / e2e) and
        copy the brownout controller's transition log across."""
        metrics = self.runtime.metrics
        seen = {r.rid for r in metrics.request_records}
        for r in self.requests.values():
            if (r.t_done > 0 or r.rejected) and r.rid not in seen:
                metrics.record_request(RequestRecord(
                    rid=r.rid, t_submit=r.t_submit,
                    t_first_token=r.t_first_token, t_done=r.t_done,
                    deadline_s=r.deadline_s, rejected=r.rejected,
                    shed=r.shed, reject_cause=r.reject_cause,
                    shed_cause=r.shed_cause))
        if self.controller is not None:
            metrics.brownout_transitions = list(self.controller.transitions)

    def latency_stats(self) -> dict:
        """Flat-key view over ``RunMetrics.request_latency_stats()`` (one
        stat path — the engine only reshapes keys for the CLI callers)."""
        self._finalize_requests()
        stats = self.runtime.metrics.request_latency_stats()
        if not stats:
            return {}
        out = {
            "completed": stats["completed"],
            "rejected": stats["rejected"],
            "rejected_deadline": stats["rejected_deadline"],
            "rejected_backpressure": stats["rejected_backpressure"],
            "shed": stats["shed"],
            "shed_deadline": stats["shed_deadline"],
            "shed_brownout": stats["shed_brownout"],
            "deadline_miss": stats["deadline_miss"],
            "tokens_clamped": self.tokens_clamped,
        }
        if "brownout" in stats:
            out["brownout_transitions"] = stats["brownout"]["transitions"]
            out["brownout_max_rung"] = stats["brownout"]["max_rung"]
        if "ttft_ms" in stats:      # at least one request actually ran
            out.update({
                "ttft_ms_mean": stats["ttft_ms"]["mean"],
                "ttft_ms_p50": stats["ttft_ms"]["p50"],
                "ttft_ms_p95": stats["ttft_ms"]["p95"],
                "ttft_ms_p99": stats["ttft_ms"]["p99"],
                "e2e_ms_mean": stats["e2e_ms"]["mean"],
                "e2e_ms_p99": stats["e2e_ms"]["p99"],
            })
        return out
