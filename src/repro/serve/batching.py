"""Continuous batching for the decode path (Orca-style iteration-level
scheduling, DESIGN.md §"Continuous batching").

The serving engine's decode chain used to dispatch one LOW task per
token per request: every token paid the full wake → place → dequeue →
commit round-trip, and under load the fleet's throughput knee sat at the
per-token dispatch cost.  Batched decode is memory-bound — one fused
dispatch over ``n`` ready requests costs roughly ``base * (1 +
member_cost * (n-1))``, not ``n * base`` — so coalescing ready decode
steps into one moldable dispatch multiplies sustainable throughput
without touching per-request semantics.

:class:`DecodeBatcher` is the engine-level half: a holding pen for
*ready* decode steps (one slot per admitted request between its previous
commit and its next dispatch).  Batch formation is the pure function
:func:`form_batches` — deterministic given (pending, now, config) — with
four triggers, checked oldest-first:

* **quorum** — ``max_batch`` slots are waiting: flush a full batch;
* **criticality** — a ``tier="high"`` request never waits on batch fill:
  its arrival flushes the whole pending set immediately (the HIGH-flush
  latency bound: a critical decode step waits at most one in-flight
  dispatch, never the delay window);
* **deadline** — a member whose deadline slack has fallen to
  ``flush_slack_s`` flushes the pending set (late tokens destroy the
  request's remaining value);
* **age** — the oldest slot has waited ``delay_s``: a partial batch
  dispatches rather than idling the fleet (the batch-delay window).

While slots sit here they are *outside* the work-stealing queues, so
HIGH prefills — which share the fleet — are never queued behind decode
fill: holding back LOW decode work is precisely what yields the cores to
the critical path.  Shed/brownout state is **not** checked at formation:
membership is re-validated inside the dispatch (payload) and at commit,
so rung-2 shedding removes members, never whole dispatches.

The queue-level half (tasks carrying ``Task.batch_key`` coalesced at the
dequeue boundary) lives in :meth:`~repro.core.queues.WorkQueues.
coalesce_batch` / :meth:`~repro.core.lifecycle.SchedulingKernel.
form_dispatch`; both halves share :class:`~repro.core.queues.
BatchingConfig`.
"""
from __future__ import annotations

import dataclasses
import threading

from ..core.queues import BatchingConfig


@dataclasses.dataclass
class BatchSlot:
    """One request's ready decode step, parked until dispatch.

    ``req`` carries tier / deadline / shed state (duck-typed:
    :class:`~.engine.Request` in production, any object with ``tier``,
    ``deadline_s``, ``t_submit`` in tests); ``ctx`` is the request's
    mutable step state (decoder state, last token, step counter) bound to
    the dispatch via ``Task.args``; ``t_enq`` is when this step became
    ready (the age trigger's clock — re-stamped on every re-add)."""

    req: object
    ctx: dict
    t_enq: float


def form_batches(pending: list[BatchSlot], now: float, cfg: BatchingConfig,
                 drain: bool = False) -> tuple[list[list[BatchSlot]],
                                               list[BatchSlot]]:
    """Deterministic batch formation: split ``pending`` (oldest first)
    into flushed groups and the remainder that keeps waiting.  Pure —
    same inputs, same split — which is what makes formation testable and
    the threaded engine's behavior explainable."""
    groups: list[list[BatchSlot]] = []
    rest = list(pending)
    while len(rest) >= cfg.max_batch:               # quorum
        groups.append(rest[:cfg.max_batch])
        rest = rest[cfg.max_batch:]
    if rest:
        flush = drain
        if not flush:
            # criticality: a HIGH-tier member never waits on fill
            flush = any(getattr(s.req, "tier", "low") == "high"
                        for s in rest)
        if not flush:
            # deadline slack collapsed on some member
            flush = any(
                s.req.deadline_s > 0.0
                and (s.req.t_submit + s.req.deadline_s - now)
                <= cfg.flush_slack_s
                for s in rest)
        if not flush:                               # age (delay window)
            flush = now - rest[0].t_enq >= cfg.delay_s
        if flush:
            groups.append(rest)
            rest = []
    return groups, rest


class DecodeBatcher:
    """Thread-safe holding pen over :func:`form_batches`.  ``add`` /
    ``readd`` / ``poll`` each return the list of slot groups that became
    due, for the caller to turn into fused dispatch tasks; slots that did
    not flush keep waiting for the next trigger."""

    def __init__(self, cfg: BatchingConfig):
        if not cfg.enabled:
            raise ValueError("DecodeBatcher requires max_batch > 1 "
                             "(max_batch=1 is the unbatched path)")
        self.cfg = cfg
        self._pending: list[BatchSlot] = []
        self._lock = threading.Lock()
        # telemetry: dispatches formed, members coalesced into them
        self.batches_formed = 0
        self.members_dispatched = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def _form(self, now: float, drain: bool) -> list[list[BatchSlot]]:
        groups, self._pending = form_batches(self._pending, now, self.cfg,
                                             drain)
        self.batches_formed += len(groups)
        self.members_dispatched += sum(len(g) for g in groups)
        return groups

    def add(self, req, ctx: dict, now: float) -> list[list[BatchSlot]]:
        """Park a newly ready decode step; return any groups now due."""
        with self._lock:
            self._pending.append(BatchSlot(req, ctx, now))
            return self._form(now, drain=False)

    def readd(self, slot: BatchSlot, now: float) -> list[list[BatchSlot]]:
        """Re-park a surviving member after its dispatch committed (its
        age clock restarts — the delay window bounds *per-step* wait)."""
        with self._lock:
            slot.t_enq = now
            self._pending.append(slot)
            return self._form(now, drain=False)

    def poll(self, now: float, drain: bool = False) -> list[list[BatchSlot]]:
        """Timer pump: flush whatever the age/deadline triggers make due
        (``drain=True`` flushes everything — end of submission)."""
        with self._lock:
            if not self._pending:
                return []
            return self._form(now, drain)
