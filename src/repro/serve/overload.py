"""Brownout ladder for the serving engine's overload response.

Under sustained saturation a serving fleet has three levers, ordered by
how much value each destroys: shrink LOW-priority outputs (cheap — the
request still completes, just shorter), shed queued LOW decode work
(the request finalizes truncated), and finally reject at admission
(the request never runs).  The :class:`OverloadController` walks those
rungs as a *ladder* driven by one scalar load signal — backlog seconds
per live core, from ``SchedulingKernel.backlog_signal()`` — with
per-rung hysteresis so a noisy signal near a threshold does not flap
the fleet between policies.

Rungs::

    0  normal        no intervention
    1  shrink        LOW requests' max_new_tokens clamped to min_tokens
    2  shed          queued LOW decode chains dropped at payload time
    3  reject        non-HIGH admissions refused outright

The controller climbs one rung whenever the signal is at or above that
rung's ``enter`` threshold and descends whenever it falls below the
``exit`` threshold of the rung it is on.  ``exit[i] < enter[i]`` is
enforced so every rung has a hysteresis band.  Transitions are recorded
as ``(t, from_rung, to_rung)`` tuples for ``request_latency_stats()``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    """Thresholds for the three-rung ladder, in units of the load signal
    (backlog seconds per live core).  ``enter[i]`` raises the controller
    onto rung ``i+1``; ``exit[i]`` lowers it back off.  Both triples must
    be strictly increasing and ``0 < exit[i] < enter[i]`` (hysteresis).

    ``min_tokens`` is the rung-1 clamp: LOW requests admitted while the
    controller sits at rung >= 1 have ``max_new_tokens`` reduced to this
    floor (never below 1)."""
    enter: tuple[float, float, float] = (0.5, 1.5, 4.0)
    exit: tuple[float, float, float] = (0.25, 0.75, 2.0)
    min_tokens: int = 1

    def __post_init__(self) -> None:
        if len(self.enter) != 3 or len(self.exit) != 3:
            raise ValueError("enter/exit must be triples (one per rung)")
        for i in range(3):
            if not (0.0 < self.exit[i] < self.enter[i]):
                raise ValueError(
                    f"rung {i + 1}: need 0 < exit ({self.exit[i]}) < "
                    f"enter ({self.enter[i]}) for hysteresis")
        for i in range(2):
            if self.enter[i] >= self.enter[i + 1]:
                raise ValueError("enter thresholds must be increasing")
            if self.exit[i] >= self.exit[i + 1]:
                raise ValueError("exit thresholds must be increasing")
        if self.min_tokens < 1:
            raise ValueError("min_tokens must be >= 1")


class OverloadController:
    """Hysteresis state machine over :class:`BrownoutConfig`'s rungs.

    ``update(signal, now)`` moves at most as far as the signal justifies
    (it can cross several rungs in one call during a step change) and
    appends one transition tuple per rung crossed in a single update —
    i.e. a jump from 0 to 2 records ``(now, 0, 2)``.  Monotone signal
    ramps therefore produce monotone non-decreasing ``to`` rungs until
    the ramp reverses."""

    def __init__(self, config: BrownoutConfig | None = None) -> None:
        self.config = config or BrownoutConfig()
        self.rung = 0
        self.transitions: list[tuple[float, int, int]] = []

    def update(self, signal: float, now: float) -> int:
        """Fold one load-signal observation in; returns the new rung."""
        cfg = self.config
        start = self.rung
        r = start
        while r < 3 and signal >= cfg.enter[r]:
            r += 1
        if r == start:                      # not climbing: try descending
            while r > 0 and signal < cfg.exit[r - 1]:
                r -= 1
        if r != start:
            self.transitions.append((now, start, r))
            self.rung = r
        return r

    # -- policy queries (read by the serving engine) ------------------------
    @property
    def shrink_low(self) -> bool:
        """Rung >= 1: clamp LOW max_new_tokens to ``config.min_tokens``."""
        return self.rung >= 1

    @property
    def shed_low(self) -> bool:
        """Rung >= 2: drop queued LOW decode chains at payload time."""
        return self.rung >= 2

    @property
    def reject_low(self) -> bool:
        """Rung >= 3: refuse non-HIGH admissions outright."""
        return self.rung >= 3

    def summary(self) -> dict:
        return {
            "rung": self.rung,
            "transitions": len(self.transitions),
            "max_rung": max((to for _, _, to in self.transitions),
                            default=self.rung),
        }
