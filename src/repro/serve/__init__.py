from .batching import BatchSlot, DecodeBatcher, form_batches
from .engine import Request, ServingEngine
from .overload import BrownoutConfig, OverloadController

__all__ = ["BatchSlot", "BrownoutConfig", "DecodeBatcher",
           "OverloadController", "Request", "ServingEngine", "form_batches"]
