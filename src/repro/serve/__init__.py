from .engine import Request, ServingEngine
from .overload import BrownoutConfig, OverloadController

__all__ = ["BrownoutConfig", "OverloadController", "Request",
           "ServingEngine"]
