"""Deterministic synthetic token pipeline with exact restart semantics.

Design goals (what a real fleet needs, scaled to this container):
  * stateless addressing — batch contents are a pure function of
    (seed, step, host_index), so skip-ahead restart after a failure is
    exact and free (no stream replay);
  * per-host sharding — each host generates only its slice of the global
    batch (``host_index``/``num_hosts``);
  * background prefetch — a double-buffered thread keeps the accelerator
    fed (overlap of input pipeline with compute).

Token statistics are Zipf-like (power-law over the vocab) so losses and
router load-balance behave like text rather than uniform noise.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    num_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide by num_hosts")
        return self.global_batch // self.num_hosts


class SyntheticStream:
    """Iterator of {"tokens","labels"} int32 [host_batch, seq_len]."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        # precompute the Zipf CDF once
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** -cfg.zipf_a
        self._cdf = np.cumsum(w / w.sum())

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        u = rng.random((cfg.host_batch, cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, cfg.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def skip_to(self, step: int) -> None:
        """Exact restart: next batch will be ``batch_at(step)``."""
        self.step = step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}


class Prefetcher:
    """Double-buffered background prefetch over any dict iterator."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
