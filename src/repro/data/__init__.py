from .pipeline import DataConfig, Prefetcher, SyntheticStream

__all__ = ["DataConfig", "Prefetcher", "SyntheticStream"]
