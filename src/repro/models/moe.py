"""Mixture-of-Experts FFN: top-k routing with capacity, GShard-style
dispatch/combine einsums, optional DeepSeek-style shared expert.

Expert weights are stacked on a leading E axis — that axis is sharded over
the ``model`` mesh axis (expert parallelism); the dispatch einsum then
lowers to an all-to-all over the EP groups.  Capacity-based routing keeps
every tensor shape static (required for pjit) and bounds the all-to-all
volume; dropped tokens fall through the residual (standard practice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear


def init_moe(key: jax.Array, d_model: int, n_experts: int, expert_ff: int,
             shared_ff: int = 0, act: str = "swiglu", dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    scale_in = d_model ** -0.5
    scale_out = expert_ff ** -0.5
    p = {
        "router": init_linear(ks[0], (d_model, n_experts), dtype,
                              scale=d_model ** -0.5),
        # stacked experts: [E, d, ff] / [E, ff, d]
        "experts_gate": (jax.random.normal(ks[1], (n_experts, d_model, expert_ff))
                         * scale_in).astype(dtype),
        "experts_up": (jax.random.normal(ks[2], (n_experts, d_model, expert_ff))
                       * scale_in).astype(dtype),
        "experts_down": (jax.random.normal(ks[3], (n_experts, expert_ff, d_model))
                         * scale_out).astype(dtype),
    }
    if shared_ff > 0:
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init_linear(kg, (d_model, shared_ff), dtype),
            "w_up": init_linear(ku, (d_model, shared_ff), dtype),
            "w_down": init_linear(kd, (shared_ff, d_model), dtype),
        }
    return p


def moe_block(params: dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25,
              group_size: int = 2048) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss).

    GShard grouped dispatch: tokens are split into groups of ~2048 with
    per-group capacity C = factor*S_g*K/E, so the dispatch/combine one-hots
    are [G, S_g, E, C] — bounded per-group memory regardless of the global
    token count (the ungrouped [N, E, C] formulation is O(N^2) and melts at
    1M tokens).  G shards over DP, E over the model axis (EP); the dispatch
    einsum is the EP all-to-all.

    Returns the Switch-style load-balance aux loss E * sum_e f_e * p_e.
    """
    bsz, s, d = x.shape
    n_experts = params["router"].shape[-1]
    n_tokens = bsz * s
    sg = min(group_size, n_tokens)
    if n_tokens % sg:
        sg = n_tokens           # degenerate small case: one group
    n_groups = n_tokens // sg
    xg = x.reshape(n_groups, sg, d)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [G,S,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [G,S,K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (over all tokens)
    me = probs.mean(axis=(0, 1))                               # [E]
    oh_all = jax.nn.one_hot(expert_idx, n_experts)             # [G,S,K,E]
    ce = oh_all.sum(2).mean(axis=(0, 1)) / top_k
    aux = n_experts * jnp.sum(me * ce)

    capacity = max(1, int(capacity_factor * sg * top_k / n_experts))
    # position of each (s,k) within its expert's per-group queue:
    # flatten (s,k) in order, cumulative count per expert
    oh_flat = oh_all.reshape(n_groups, sg * top_k, n_experts)
    pos_flat = jnp.cumsum(oh_flat, axis=1) - 1                 # [G,S*K,E]
    pos = jnp.einsum("gne,gne->gn", pos_flat,
                     oh_flat).reshape(n_groups, sg, top_k)     # [G,S,K]
    pos = pos.astype(jnp.int32)
    keep = pos < capacity

    gate_kept = jnp.where(keep, gate_vals, 0.0)
    oh_e = oh_all.astype(x.dtype)                              # [G,S,K,E]
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                          dtype=x.dtype)[..., :capacity]       # [G,S,K,C]
    dispatch = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)       # [G,S,E,C]
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)     # [G,E,C,d]

    # expert FFN (SwiGLU), batched over E — E axis is EP-sharded
    g = jnp.einsum("gecd,edf->gecf", expert_in, params["experts_gate"])
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["experts_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["experts_down"])

    combine = jnp.einsum("gske,gskc,gsk->gsec", oh_e, oh_c,
                         gate_kept.astype(x.dtype))            # [G,S,E,C]
    y = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    if "shared" in params:
        sp = params["shared"]
        sh = jax.nn.silu(xg @ sp["w_gate"]) * (xg @ sp["w_up"])
        y = y + sh @ sp["w_down"]
    return y.reshape(bsz, s, d), aux.astype(jnp.float32)
