"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential).

mLSTM is formulated in its chunk-parallel form — mathematically a gated
linear recurrence over a matrix state C: [H, D, N], which we evaluate with
the same SSD machinery as Mamba-2 for the q/k/v analogy:
    C_t = f_t C_{t-1} + i_t v_t k_tᵀ,   h_t = C_t q_t / max(|n_t q_t|, 1)
The normalizer n_t q_t is computed exactly in the parallel path as a second
D=1 SSD scan over the input gate, so training, prefill and decode agree to
numerical precision.

sLSTM keeps per-head scalar state with exponential gating and runs as a
lax.scan (it is inherently sequential — the paper's reason to mix block
types 7:1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import init_linear, rms_norm


# -- mLSTM -------------------------------------------------------------------

def init_mlstm(key: jax.Array, d_model: int, n_heads: int, proj_factor: float = 2.0,
               dtype=jnp.float32) -> dict:
    d_inner = int(d_model * proj_factor)
    ks = jax.random.split(key, 8)
    return {
        "w_x": init_linear(ks[0], (d_model, d_inner), dtype),
        "w_gate_proj": init_linear(ks[6], (d_model, d_inner), dtype),
        "wq": init_linear(ks[1], (d_inner, d_inner), dtype),
        "wk": init_linear(ks[2], (d_inner, d_inner), dtype),
        "wv": init_linear(ks[3], (d_inner, d_inner), dtype),
        "w_if": init_linear(ks[4], (d_inner, 2 * n_heads), dtype),   # i/f gates
        "norm_h": jnp.ones((d_inner,), dtype),
        "w_down": init_linear(ks[5], (d_inner, d_model), dtype),
    }


def mlstm_block(params: dict, x: jax.Array, *, n_heads: int,
                return_state: bool = False):
    """Parallel (training) path via the SSD kernel: per-head scalar forget
    gate = decay a_t, input gate folds into v.  With ``return_state`` also
    returns the exact (C, n) decode state after the last token."""
    bsz, s, _ = x.shape
    xi = x @ params["w_x"]
    gate = x @ params["w_gate_proj"]
    d_inner = xi.shape[-1]
    head_dim = d_inner // n_heads

    q = (xi @ params["wq"]).reshape(bsz, s, n_heads, head_dim)
    k = (xi @ params["wk"]).reshape(bsz, s, n_heads, head_dim) * head_dim ** -0.5
    v = (xi @ params["wv"]).reshape(bsz, s, n_heads, head_dim)
    gates = xi @ params["w_if"]
    i_gate = jax.nn.sigmoid(gates[..., :n_heads])          # [B,S,H]
    f_gate = jax.nn.sigmoid(gates[..., n_heads:])          # [B,S,H]

    # gated linear recurrence == SSD with a = log f, input i*v, B=k, C=q.
    # ssd_scan shares B/C across heads; we run it per head via vmap over H
    # by folding H into the batch dim (B*H, S, 1 head).
    a = jnp.log(f_gate + 1e-6)
    xv = (v * i_gate[..., None])                           # [B,S,H,D]
    # fold heads into batch: x' [B*H, S, 1, D]; b/c per-head -> [B*H, S, N]
    def fold(t):  # [B,S,H,...] -> [B*H,S,...]
        t = jnp.moveaxis(t, 2, 1)                          # [B,H,S,...]
        return t.reshape((bsz * n_heads,) + t.shape[2:])
    y = ops.ssd_scan(fold(xv)[:, :, None, :], fold(a)[..., None],
                     fold(k), fold(q))                     # [B*H,S,1,D]
    y = y.reshape(bsz, n_heads, s, head_dim).swapaxes(1, 2)  # [B,S,H,D]
    # normalizer n_t·q_t as a D=1 SSD scan over the input gate
    den = ops.ssd_scan(fold(i_gate[..., None])[:, :, None, :],
                       fold(a)[..., None], fold(k), fold(q))  # [B*H,S,1,1]
    den = den.reshape(bsz, n_heads, s, 1).swapaxes(1, 2)      # [B,S,H,1]
    y = y / jnp.maximum(jnp.abs(den), 1.0)
    h = y.reshape(bsz, s, d_inner)
    h = rms_norm(h, params["norm_h"]) * jax.nn.silu(gate)
    out = h @ params["w_down"]
    if not return_state:
        return out
    # exact final state: C_T = sum_u exp(acum_T-acum_u) (i_u v_u)(x)k_u
    acum = jnp.cumsum(a.astype(jnp.float32), axis=1)       # [B,S,H]
    w = jnp.exp(acum[:, -1:, :] - acum)                    # [B,S,H]
    c_fin = jnp.einsum("bshd,bsh,bshn->bhdn", xv.astype(jnp.float32), w,
                       k.astype(jnp.float32))
    n_fin = jnp.einsum("bsh,bsh,bshn->bhn", i_gate.astype(jnp.float32), w,
                       k.astype(jnp.float32))
    return out, {"C": c_fin.astype(x.dtype), "n": n_fin.astype(x.dtype)}


def mlstm_decode(params: dict, x: jax.Array, state: dict, *,
                 n_heads: int) -> tuple[jax.Array, dict]:
    """Exact recurrence with normalizer.  state: {"C":[B,H,D,N], "n":[B,H,N]}."""
    bsz = x.shape[0]
    xi = x[:, 0] @ params["w_x"]
    gate = x[:, 0] @ params["w_gate_proj"]
    d_inner = xi.shape[-1]
    head_dim = d_inner // n_heads

    q = (xi @ params["wq"]).reshape(bsz, n_heads, head_dim)
    k = (xi @ params["wk"]).reshape(bsz, n_heads, head_dim) * head_dim ** -0.5
    v = (xi @ params["wv"]).reshape(bsz, n_heads, head_dim)
    gates = xi @ params["w_if"]
    i_g = jax.nn.sigmoid(gates[..., :n_heads])[..., None]   # [B,H,1]
    f_g = jax.nn.sigmoid(gates[..., n_heads:])[..., None]

    c_st = f_g[..., None] * state["C"] + i_g[..., None] * v[..., None] * k[:, :, None, :]
    n_st = f_g * state["n"] + i_g * k
    num = jnp.einsum("bhdn,bhn->bhd", c_st, q)
    den = jnp.abs(jnp.einsum("bhn,bhn->bh", n_st, q))[..., None]
    h = num / jnp.maximum(den, 1.0)
    h = h.reshape(bsz, d_inner)
    h = rms_norm(h, params["norm_h"]) * jax.nn.silu(gate)
    return (h @ params["w_down"])[:, None, :], {"C": c_st, "n": n_st}


def init_mlstm_state(batch: int, n_heads: int, head_dim: int,
                     dtype=jnp.float32) -> dict:
    return {
        "C": jnp.zeros((batch, n_heads, head_dim, head_dim), dtype),
        "n": jnp.zeros((batch, n_heads, head_dim), dtype),
    }


# -- sLSTM -------------------------------------------------------------------

def init_slstm(key: jax.Array, d_model: int, n_heads: int, proj_factor: float = 4 / 3,
               dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d_up = int(d_model * proj_factor)
    return {
        # 4 gates (i, f, z, o) from x — separate leaves for clean TP sharding
        "w_i": init_linear(ks[0], (d_model, d_model), dtype),
        "w_f": init_linear(ks[1], (d_model, d_model), dtype),
        "w_z": init_linear(ks[2], (d_model, d_model), dtype),
        "w_o": init_linear(ks[3], (d_model, d_model), dtype),
        # recurrent per-head block-diagonal approximated by per-dim weight
        "r_gates": (jax.random.normal(ks[4], (4, d_model)) * 0.1).astype(dtype),
        "norm_h": jnp.ones((d_model,), dtype),
        "w_up_a": init_linear(ks[5], (d_model, d_up), dtype),
        "w_up_b": init_linear(ks[6], (d_model, d_up), dtype),
        "w_down": init_linear(ks[7], (d_up, d_model), dtype),
    }


def _slstm_cell(params, carry, xt):
    """One sLSTM step with exponential gating + stabilizer state m."""
    h_prev, c_prev, n_prev, m_prev = carry
    pre_i = xt @ params["w_i"] + params["r_gates"][0] * h_prev
    pre_f = xt @ params["w_f"] + params["r_gates"][1] * h_prev
    pre_z = xt @ params["w_z"] + params["r_gates"][2] * h_prev
    pre_o = xt @ params["w_o"] + params["r_gates"][3] * h_prev

    m_new = jnp.maximum(pre_f + m_prev, pre_i)             # stabilizer
    i_g = jnp.exp(pre_i - m_new)
    f_g = jnp.exp(pre_f + m_prev - m_new)
    z = jnp.tanh(pre_z)
    o = jax.nn.sigmoid(pre_o)
    c_new = f_g * c_prev + i_g * z
    n_new = f_g * n_prev + i_g
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_block(params: dict, x: jax.Array, *, n_heads: int,
                return_state: bool = False):
    bsz, s, d = x.shape

    def step(carry, xt):
        new = _slstm_cell(params, carry, xt)
        return new, new[0]

    init = tuple(jnp.zeros((bsz, d), x.dtype) for _ in range(4))
    final, hs = jax.lax.scan(step, init, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                  # [B,S,d]
    h = rms_norm(h, params["norm_h"])
    h = jax.nn.gelu(h @ params["w_up_a"]) * (h @ params["w_up_b"])
    out = h @ params["w_down"]
    if not return_state:
        return out
    return out, {"h": final[0], "c": final[1], "n": final[2], "m": final[3]}


def slstm_decode(params: dict, x: jax.Array, state: dict, *,
                 n_heads: int) -> tuple[jax.Array, dict]:
    carry = (state["h"], state["c"], state["n"], state["m"])
    new = _slstm_cell(params, carry, x[:, 0])
    h = rms_norm(new[0], params["norm_h"])
    h = jax.nn.gelu(h @ params["w_up_a"]) * (h @ params["w_up_b"])
    out = (h @ params["w_down"])[:, None, :]
    return out, {"h": new[0], "c": new[1], "n": new[2], "m": new[3]}


def init_slstm_state(batch: int, d_model: int, dtype=jnp.float32) -> dict:
    z = lambda: jnp.zeros((batch, d_model), dtype)
    return {"h": z(), "c": z(), "n": z(), "m": z()}
