"""Shared building blocks: norms, rotary embeddings, FFNs, initializers.

All models are pure-pytree functional JAX: params are nested dicts of
arrays, every layer is ``fn(params, x, cfg) -> y``.  Leaf *names* carry the
sharding semantics (see parallel/sharding.py): e.g. any leaf named ``wq``
is column-sharded over the model axis, ``wo`` row-sharded, etc.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# -- rotary ------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- FFN ---------------------------------------------------------------------

def ffn(params: dict, x: jax.Array, act: str) -> jax.Array:
    """Gated (SwiGLU/GeGLU) or plain (gelu / squared-ReLU) FFN by leaf set."""
    if "w_gate" in params:
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        if act == "swiglu":
            h = jax.nn.silu(g) * u
        elif act == "geglu":
            h = jax.nn.gelu(g) * u
        else:
            raise ValueError(f"gated ffn with act={act!r}")
        return h @ params["w_down"]
    h = x @ params["w_up"]
    if act == "sq_relu":                  # Primer / Nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(f"ungated ffn with act={act!r}")
    return h @ params["w_down"]


def init_ffn(key: jax.Array, d_model: int, d_ff: int, act: str,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d_model, d_ff)) * scale_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (d_ff, d_model)) * scale_out).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d_model)) * scale_out).astype(dtype),
    }


def init_linear(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32,
                scale: float | None = None) -> jax.Array:
    scale = shape[0] ** -0.5 if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)
