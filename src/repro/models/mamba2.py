"""Mamba-2 block (SSD) for the hybrid/ssm architectures.

Train/prefill path uses the chunked SSD kernel (kernels/ssd_scan.py);
decode keeps a per-layer recurrent state {ssm: [B,H,D,N], conv: [B,W-1,Di]}
— constant memory in sequence length, which is why the hybrid/ssm archs
are the ones that run the long_500k shape.

Simplifications vs the full Mamba-2 (documented): scalar per-head decay
a_t = -softplus(dt) (no learned A matrix beyond the scalar), B/C shared
across heads (as in Mamba-2's multi-value attention analogy), short causal
conv of width 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import init_linear, rms_norm

CONV_W = 4


def init_mamba2(key: jax.Array, d_model: int, n_heads: int, head_dim: int,
                ssm_state: int, dtype=jnp.float32) -> dict:
    """Projections are separate leaves (not one fused w_in) so tensor
    parallelism can column-shard x/z/dt over the model axis while B/C stay
    replicated (they are shared across heads)."""
    d_inner = n_heads * head_dim
    ks = jax.random.split(key, 7)
    return {
        "wx": init_linear(ks[0], (d_model, d_inner), dtype),
        "wz": init_linear(ks[1], (d_model, d_inner), dtype),
        "wb": init_linear(ks[2], (d_model, ssm_state), dtype),
        "wc": init_linear(ks[3], (d_model, ssm_state), dtype),
        "wdt": init_linear(ks[4], (d_model, n_heads), dtype),
        "conv_w": (jax.random.normal(ks[5], (CONV_W, d_inner)) * 0.2).astype(dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "a_log": jnp.zeros((n_heads,), dtype),          # A = -exp(a_log)
        "norm_z": jnp.ones((d_inner,), dtype),
        "w_out": init_linear(ks[6], (d_inner, d_model), dtype),
    }


def _split_proj(params, x, n_heads, head_dim, ssm_state):
    xs = x @ params["wx"]
    z = x @ params["wz"]
    b = x @ params["wb"]
    c = x @ params["wc"]
    dt = x @ params["wdt"]
    return xs, z, b, c, dt


def _decay(params, dt):
    """a_t = dt * A with dt = softplus(dt_raw + bias), A = -exp(a_log)."""
    dt_pos = jax.nn.softplus(dt + params["dt_bias"])
    return -dt_pos * jnp.exp(params["a_log"])            # [.., H], <= 0


def mamba2_block(params: dict, x: jax.Array, *, n_heads: int, head_dim: int,
                 ssm_state: int, return_state: bool = False):
    """Full-sequence path.  x: [B, S, d] -> [B, S, d].  With
    ``return_state`` also returns the decode state after the last token
    (closed-form final SSM state + conv tail) for prefill."""
    bsz, s, _ = x.shape
    d_inner = n_heads * head_dim
    xs_raw, z, b, c, dt = _split_proj(params, x, n_heads, head_dim, ssm_state)

    # causal depthwise conv width 4 along S
    pad = jnp.pad(xs_raw, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s, :] * params["conv_w"][i] for i in range(CONV_W))
    xs = jax.nn.silu(conv)

    a = _decay(params, dt)                               # [B,S,H]
    xh = xs.reshape(bsz, s, n_heads, head_dim)
    y = ops.ssd_scan(xh, a, b, c)                        # [B,S,H,D]
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_z"])   # gated output norm
    out = y @ params["w_out"]
    if not return_state:
        return out
    # closed-form final state: h_T = sum_u exp(Acum_T - Acum_u) x_u (x) B_u
    acum = jnp.cumsum(a.astype(jnp.float32), axis=1)     # [B,S,H]
    w = jnp.exp(acum[:, -1:, :] - acum)                  # [B,S,H]
    h_final = jnp.einsum("bshd,bsh,bsn->bhdn",
                         xh.astype(jnp.float32), w, b.astype(jnp.float32))
    conv_tail = pad[:, s:s + CONV_W - 1, :]              # last W-1 raw inputs
    state = {"ssm": h_final.astype(x.dtype), "conv": conv_tail}
    return out, state


def mamba2_decode(params: dict, x: jax.Array, state: dict, *, n_heads: int,
                  head_dim: int, ssm_state: int) -> tuple[jax.Array, dict]:
    """One-token step.  x: [B,1,d]; state: {"ssm":[B,H,D,N], "conv":[B,W-1,Di]}."""
    bsz = x.shape[0]
    d_inner = n_heads * head_dim
    xs, z, b, c, dt = _split_proj(params, x[:, 0], n_heads, head_dim, ssm_state)

    # rolling conv buffer
    window = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)  # [B,W,Di]
    conv = jnp.einsum("bwd,wd->bd", window, params["conv_w"])
    new_conv = window[:, 1:, :]
    xs = jax.nn.silu(conv)

    a = _decay(params, dt)                               # [B,H]
    xh = xs.reshape(bsz, n_heads, head_dim)
    h = state["ssm"]                                      # [B,H,D,N]
    h = jnp.exp(a)[..., None, None] * h + \
        xh[..., None] * b[:, None, None, :]
    y = jnp.einsum("bhdn,bn->bhd", h, c).reshape(bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_z"])
    return (y @ params["w_out"])[:, None, :], {"ssm": h, "conv": new_conv}


def init_mamba2_state(batch: int, n_heads: int, head_dim: int, ssm_state: int,
                      dtype=jnp.float32) -> dict:
    return {
        "ssm": jnp.zeros((batch, n_heads, head_dim, ssm_state), dtype),
        "conv": jnp.zeros((batch, CONV_W - 1, n_heads * head_dim), dtype),
    }
