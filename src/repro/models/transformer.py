"""Unified decoder LM covering all assigned architecture families.

A model is (init_params, forward, loss_and_metrics, init_decode_state,
decode_step) driven entirely by ModelConfig.  Layer stacks are *scanned*
(stacked leaf arrays with a leading layer axis) — essential to keep
dry-run compile times tractable at 48-80 layers and to keep the HLO small
enough to parse for collective bytes.

Families:
  dense / vlm / audio — pre-norm GQA attention + FFN (SwiGLU / squared-ReLU
      / GELU), optional QKV bias, RoPE.  vlm/audio prepend stub frontend
      embeddings (precomputed patch/frame vectors from input_specs).
  moe   — attention + top-k capacity-routed MoE FFN (+ optional shared
      expert), aux load-balance loss.
  hybrid (zamba2) — Mamba-2 backbone; ONE weight-shared attention+FFN block
      applied every ``shared_attn_every`` layers (each application keeps its
      own KV cache at decode).
  ssm (xlstm) — mLSTM blocks with sLSTM every ``slstm_every``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import constrain
from .attention import (attention_block, attention_decode, init_attention,
                        init_kv_cache)
from .layers import ffn, init_ffn, init_linear, rms_norm
from .mamba2 import (init_mamba2, init_mamba2_state, mamba2_block,
                     mamba2_decode)
from .moe import init_moe, moe_block
from .xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                    init_slstm_state, mlstm_block, mlstm_decode, slstm_block,
                    slstm_decode)

Params = dict
PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> list[str]:
    """Block type per layer index."""
    if cfg.family in ("dense", "vlm", "audio"):
        return ["attn"] * cfg.n_layers
    if cfg.family == "moe":
        return ["attn_moe"] * cfg.n_layers
    if cfg.family == "hybrid":
        plan = []
        for i in range(cfg.n_layers):
            plan.append("mamba2")
            if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
                plan.append("shared_attn")
        return plan
    if cfg.family == "ssm":
        k = cfg.slstm_every
        return ["slstm" if (k and i % k == k - 1) else "mlstm"
                for i in range(cfg.n_layers)]
    raise ValueError(f"unknown family {cfg.family}")


def _segments(plan: list[str]) -> list[tuple[str, int]]:
    """Run-length encode the plan into (type, count) scan segments."""
    segs: list[tuple[str, int]] = []
    for t in plan:
        if segs and segs[-1][0] == t:
            segs[-1] = (t, segs[-1][1] + 1)
        else:
            segs.append((t, 1))
    return segs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_one_layer(key, cfg: ModelConfig, kind: str) -> Params:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    if kind in ("attn", "attn_moe", "shared_attn"):
        p = {
            "ln1": jnp.ones((d,), dt),
            "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads, hd,
                                   cfg.qkv_bias, dt),
            "ln2": jnp.ones((d,), dt),
        }
        if kind == "attn_moe":
            p["moe"] = init_moe(ks[1], d, cfg.n_experts, cfg.d_ff,
                                cfg.moe_shared_ff, cfg.act, dt)
        else:
            p["ffn"] = init_ffn(ks[1], d, cfg.d_ff, cfg.act, dt)
        return p
    if kind == "mamba2":
        return {
            "ln1": jnp.ones((d,), dt),
            "mamba": init_mamba2(ks[0], d, cfg.n_heads, cfg.mamba_head_dim,
                                 cfg.ssm_state, dt),
        }
    if kind == "mlstm":
        return {"ln1": jnp.ones((d,), dt),
                "mlstm": init_mlstm(ks[0], d, cfg.n_heads,
                                    cfg.mlstm_proj_factor, dt)}
    if kind == "slstm":
        return {"ln1": jnp.ones((d,), dt),
                "slstm": init_slstm(ks[0], d, cfg.n_heads, dtype=dt)}
    raise ValueError(kind)


def _stack(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    plan = layer_plan(cfg)
    segs = _segments(plan)
    k_embed, k_head, k_shared, k_layers = jax.random.split(key, 4)

    params: Params = {
        "embed": init_linear(k_embed, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, (cfg.d_model, cfg.vocab), dt)

    # one stacked tree per block *type* (segments slice into it)
    counts: dict[str, int] = {}
    for t, c in segs:
        if t != "shared_attn":
            counts[t] = counts.get(t, 0) + c
    keys = jax.random.split(k_layers, max(sum(counts.values()), 1))
    ki = iter(keys)
    stacks: dict[str, list[Params]] = {t: [] for t in counts}
    for t, c in segs:
        if t == "shared_attn":
            continue
        for _ in range(c):
            stacks[t].append(_init_one_layer(next(ki), cfg, t))
    params["stacks"] = {t: _stack(v) for t, v in stacks.items()}
    if any(t == "shared_attn" for t, _ in segs):
        params["shared_attn"] = _init_one_layer(k_shared, cfg, "shared_attn")
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _sp_gather(h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Megatron-SP boundary (only when cfg.seq_parallel): the residual
    stream is sequence-sharded over the model axis; projections are
    weight-sharded over the SAME axis, so the activation must be
    explicitly all-gathered (33 MB bf16) before the column-parallel
    matmuls.  Without this pin GSPMD resolves the conflict by gathering
    the *weights* — full f32 matrices, every layer, every pass: measured
    2.0 TB/step of all-reduce on granite-8b train_4k (EXPERIMENTS.md
    §Perf iteration 2)."""
    if not cfg.seq_parallel:
        return h
    return constrain(h, ("dp", None, None))


def _block_fwd(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
               positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_moe", "shared_attn"):
        h = _sp_gather(rms_norm(x, p["ln1"], cfg.rms_eps), cfg)
        x = x + attention_block(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            positions=positions)
        h = _sp_gather(rms_norm(x, p["ln2"], cfg.rms_eps), cfg)
        if kind == "attn_moe":
            y, aux = moe_block(p["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = x + ffn(p["ffn"], h, cfg.act)
        return x, aux
    if kind == "mamba2":
        h = _sp_gather(rms_norm(x, p["ln1"], cfg.rms_eps), cfg)
        return x + mamba2_block(p["mamba"], h, n_heads=cfg.n_heads,
                                head_dim=cfg.mamba_head_dim,
                                ssm_state=cfg.ssm_state), aux
    if kind == "mlstm":
        h = _sp_gather(rms_norm(x, p["ln1"], cfg.rms_eps), cfg)
        return x + mlstm_block(p["mlstm"], h, n_heads=cfg.n_heads), aux
    if kind == "slstm":
        h = _sp_gather(rms_norm(x, p["ln1"], cfg.rms_eps), cfg)
        return x + slstm_block(p["slstm"], h, n_heads=cfg.n_heads), aux
    raise ValueError(kind)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend: Optional[jax.Array] = None,
            remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens: [B, S_text] -> (logits [B, S_text, V], aux_loss).

    vlm/audio: ``frontend`` [B, P, d] embeddings are prepended; logits are
    returned only for the text positions.
    """
    x = params["embed"][tokens]                      # [B, S, d]
    prefix = 0
    if frontend is not None:
        prefix = frontend.shape[1]
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    s_total = x.shape[1]
    positions = jnp.arange(s_total)[None, :]

    segs = _segments(layer_plan(cfg))
    offsets: dict[str, int] = {}
    aux_total = jnp.zeros((), jnp.float32)

    for kind, count in segs:
        if kind == "shared_attn":
            for _ in range(count):
                x, aux = _block_fwd(cfg, kind, params["shared_attn"], x,
                                    positions)
                aux_total += aux
            continue
        start = offsets.get(kind, 0)
        offsets[kind] = start + count
        stack = jax.tree.map(lambda a: a[start:start + count],
                             params["stacks"][kind])

        def body(carry, layer_p, _kind=kind):
            x_c, aux_c = carry
            x_n, aux = _block_fwd(cfg, _kind, layer_p, x_c, positions)
            if cfg.seq_parallel:
                # sequence parallelism: the residual stream (and the
                # per-layer saved activation for the scan backward) lives
                # sequence-sharded over the model axis (Megatron-SP).
                x_n = constrain(x_n, ("dp", "model", None))
            return (x_n, aux_c + aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stack)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if prefix:
        x = x[:, prefix:, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    return logits, aux_total


def loss_and_metrics(params: Params, cfg: ModelConfig, batch: dict,
                     remat: bool = False) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend"), remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + cfg.aux_loss_coef * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# prefill (forward + decode-state capture, for serving)
# ---------------------------------------------------------------------------

def _block_prefill(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                   positions: jax.Array) -> tuple[jax.Array, PyTree]:
    if kind in ("attn", "attn_moe", "shared_attn"):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, (k, v) = attention_block(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            positions=positions, return_kv=True)
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps)
        if kind == "attn_moe":
            y, _ = moe_block(p["moe"], h, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = x + ffn(p["ffn"], h, cfg.act)
        return x, {"k": k, "v": v}
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    if kind == "mamba2":
        y, st = mamba2_block(p["mamba"], h, n_heads=cfg.n_heads,
                             head_dim=cfg.mamba_head_dim,
                             ssm_state=cfg.ssm_state, return_state=True)
    elif kind == "mlstm":
        y, st = mlstm_block(p["mlstm"], h, n_heads=cfg.n_heads,
                            return_state=True)
    elif kind == "slstm":
        y, st = slstm_block(p["slstm"], h, n_heads=cfg.n_heads,
                            return_state=True)
    else:
        raise ValueError(kind)
    return x + y, st


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            max_len: int, frontend: Optional[jax.Array] = None
            ) -> tuple[jax.Array, PyTree]:
    """Process the full prompt; return (last-token logits [B,V], decode
    state sized for ``max_len``) — the serving engine's prefill task."""
    x = params["embed"][tokens]
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    bsz, s_total = x.shape[0], x.shape[1]
    if max_len < s_total:
        raise ValueError(f"max_len {max_len} < prompt {s_total}")
    positions = jnp.arange(s_total)[None, :]

    segs = _segments(layer_plan(cfg))
    offsets: dict[str, int] = {}
    collected: dict[str, list] = {}

    for kind, count in segs:
        skey = _STATE_KEY[kind]
        if kind == "shared_attn":
            for _ in range(count):
                x, st = _block_prefill(cfg, kind, params["shared_attn"], x,
                                       positions)
                collected.setdefault(skey, []).append(st)
            continue
        start = offsets.get(kind, 0)
        offsets[kind] = start + count
        stack = jax.tree.map(lambda a: a[start:start + count],
                             params["stacks"][kind])

        def body(x_c, layer_p, _kind=kind):
            x_n, st = _block_prefill(cfg, _kind, layer_p, x_c, positions)
            return x_n, st

        x, sts = jax.lax.scan(body, x, stack)     # sts: stacked [count, ...]
        collected.setdefault(skey, []).append(sts)

    # assemble the decode-state pytree (segment stacks in plan order).
    # shared_attn parts are per-application (unstacked) -> stack; scanned
    # segment parts are already stacked [count, ...] -> concat.
    state: dict[str, PyTree] = {}
    for skey, parts in collected.items():
        if skey == "shared_kv":
            state[skey] = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        elif len(parts) == 1:
            state[skey] = parts[0]
        else:
            state[skey] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    # pad KV caches out to max_len and attach lengths
    length = jnp.full((bsz,), s_total, jnp.int32)
    for skey in ("kv", "shared_kv"):
        if skey not in state:
            continue
        kv = state[skey]
        pad = max_len - s_total
        state[skey] = {
            "k": jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "length": jnp.broadcast_to(length, kv["k"].shape[:1] + (bsz,)),
        }

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, -1] @ head).astype(jnp.float32)
    return logits, state


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Stacked per-type decode state mirroring the layer plan."""
    dt = _dtype(cfg)
    hd = cfg.resolved_head_dim
    plan = layer_plan(cfg)
    state: dict[str, PyTree] = {}
    n_attn = sum(1 for t in plan if t in ("attn", "attn_moe"))
    if n_attn:
        one = init_kv_cache(batch, max_len, cfg.n_kv_heads, hd, dt)
        state["kv"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_attn,) + a.shape), one)
    n_shared = sum(1 for t in plan if t == "shared_attn")
    if n_shared:
        one = init_kv_cache(batch, max_len, cfg.n_kv_heads, hd, dt)
        state["shared_kv"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_shared,) + a.shape), one)
    n_mamba = sum(1 for t in plan if t == "mamba2")
    if n_mamba:
        one = init_mamba2_state(batch, cfg.n_heads, cfg.mamba_head_dim,
                                cfg.ssm_state, dt)
        state["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_mamba,) + a.shape), one)
    n_ml = sum(1 for t in plan if t == "mlstm")
    if n_ml:
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        one = init_mlstm_state(batch, cfg.n_heads, di // cfg.n_heads, dt)
        state["mlstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_ml,) + a.shape), one)
    n_sl = sum(1 for t in plan if t == "slstm")
    if n_sl:
        one = init_slstm_state(batch, cfg.d_model, dt)
        state["slstm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_sl,) + a.shape), one)
    return state


_STATE_KEY = {"attn": "kv", "attn_moe": "kv", "shared_attn": "shared_kv",
              "mamba2": "mamba", "mlstm": "mlstm", "slstm": "slstm"}


def _block_decode(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                  st: PyTree) -> tuple[jax.Array, PyTree]:
    if kind in ("attn", "attn_moe", "shared_attn"):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, st = attention_decode(
            p["attn"], h, st, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps)
        if kind == "attn_moe":
            y, _ = moe_block(p["moe"], h, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = x + ffn(p["ffn"], h, cfg.act)
        return x, st
    if kind == "mamba2":
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, st = mamba2_decode(p["mamba"], h, st, n_heads=cfg.n_heads,
                              head_dim=cfg.mamba_head_dim,
                              ssm_state=cfg.ssm_state)
        return x + y, st
    if kind == "mlstm":
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, st = mlstm_decode(p["mlstm"], h, st, n_heads=cfg.n_heads)
        return x + y, st
    if kind == "slstm":
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, st = slstm_decode(p["slstm"], h, st, n_heads=cfg.n_heads)
        return x + y, st
    raise ValueError(kind)


def decode_step(params: Params, cfg: ModelConfig, state: PyTree,
                tokens: jax.Array) -> tuple[jax.Array, PyTree]:
    """One decode step.  tokens: [B] int32 -> (logits [B, V], new state).

    Scans over each stacked layer group; the matching state stack is the
    scan carry input, so compile time stays O(#segments), not O(#layers).
    """
    x = params["embed"][tokens][:, None, :]          # [B, 1, d]
    segs = _segments(layer_plan(cfg))
    state_off: dict[str, int] = {}    # running offset into each state stack
    param_off: dict[str, int] = {}    # running offset into each param stack
    new_state = dict(state)

    for kind, count in segs:
        skey = _STATE_KEY[kind]
        s0 = state_off.get(skey, 0)
        state_off[skey] = s0 + count
        st_stack = jax.tree.map(lambda a: a[s0:s0 + count], state[skey])

        if kind == "shared_attn":
            # weight-shared block: scan over its per-application caches only
            def body(x_c, sl, _kind=kind):
                return _block_decode(cfg, _kind, params["shared_attn"], x_c, sl)

            x, st_new = jax.lax.scan(body, x, st_stack)
        else:
            p0 = param_off.get(kind, 0)
            param_off[kind] = p0 + count
            p_stack = jax.tree.map(lambda a: a[p0:p0 + count],
                                   params["stacks"][kind])

            def body(x_c, inp, _kind=kind):
                layer_p, sl = inp
                return _block_decode(cfg, _kind, layer_p, x_c, sl)

            x, st_new = jax.lax.scan(body, x, (p_stack, st_stack))

        new_state[skey] = jax.tree.map(
            lambda full, new, _s0=s0: jax.lax.dynamic_update_slice_in_dim(
                full, new.astype(full.dtype), _s0, axis=0),
            new_state[skey], st_new)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, new_state
