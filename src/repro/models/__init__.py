"""Unified model zoo (pure-pytree functional JAX)."""
from .transformer import (decode_step, forward, init_decode_state,
                          init_params, layer_plan, loss_and_metrics)

__all__ = ["decode_step", "forward", "init_decode_state", "init_params",
           "layer_plan", "loss_and_metrics"]
