"""GQA attention block: train/prefill (flash kernel) and decode (cached).

Cache layout [B, T, Hkv, D] keeps the sequence dim second so long-context
decode can shard it over the *model* axis (see parallel/sharding.py) — the
softmax over a sharded T lowers to cheap per-(b,h) all-reduces instead of
an all-gather of the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..parallel.sharding import constrain
from .layers import apply_rope, init_linear


def init_attention(key: jax.Array, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": init_linear(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": init_linear(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": init_linear(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(params: dict, x: jax.Array, n_heads: int, n_kv_heads: int,
                 head_dim: int):
    b = x.shape[0]
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, -1, n_heads, head_dim)
    k = k.reshape(b, -1, n_kv_heads, head_dim)
    v = v.reshape(b, -1, n_kv_heads, head_dim)
    return q, k, v


def attention_block(params: dict, x: jax.Array, *, n_heads: int,
                    n_kv_heads: int, head_dim: int, rope_theta: float,
                    positions: jax.Array | None = None,
                    return_kv: bool = False, force_chunked: bool = False):
    """Full-sequence causal attention (training / prefill).  With
    ``return_kv`` also returns the rotated K/V [B,S,Hkv,D] for cache fill."""
    bsz, s, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    # kernels expect [B, H, S, D]
    out = ops.flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), causal=True,
                              force_chunked=force_chunked)
    out = out.swapaxes(1, 2).reshape(bsz, s, n_heads * head_dim)
    out = out @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(params: dict, x: jax.Array, cache: dict, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     rope_theta: float) -> tuple[jax.Array, dict]:
    """One-token decode.  x: [B, 1, d]; cache: {"k","v": [B,T,Hkv,D],
    "length": [B]} -> (out [B,1,d], updated cache)."""
    bsz = x.shape[0]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = cache["length"][:, None]                       # [B,1]
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    # scatter the new K/V row at position `length` per batch element.
    # The cache is pinned sequence-sharded over the model axis (SP): the
    # softmax over T then lowers to per-(b,h) all-reduces instead of a
    # full-cache reshard/gather.
    _kv_spec = ("dp", "model", None, None)
    t = cache["k"].shape[1]
    onehot = jax.nn.one_hot(cache["length"], t, dtype=k.dtype)   # [B,T]
    k_cache = constrain(cache["k"] + onehot[:, :, None, None] * k, _kv_spec)
    v_cache = constrain(cache["v"] + onehot[:, :, None, None] * v, _kv_spec)
    lengths = cache["length"] + 1

    out = ops.decode_attention(q[:, 0], k_cache, v_cache, lengths)
    out = constrain(out, ("dp", None, None))
    out = out.reshape(bsz, 1, n_heads * head_dim)
    new_cache = {"k": k_cache, "v": v_cache, "length": lengths}
    return out @ params["wo"], new_cache


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.float32) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }
