"""Elastic cluster runtime: the paper's technique applied to the fleet.

A multi-pod training job observes *dynamic performance asymmetry* exactly
like the paper's cores do: a pod slowed by a co-scheduled job, a thermally
throttled host, DCN congestion.  The ``PodMonitor`` is a PTT over the
topology of pods (task type = "train_step" / "eval_step" / ...), fed with
measured per-pod step times, with the paper's 1:4 weighted update — so
detection has the same hysteresis (≈3 observations) the paper validated.

Mitigations, in escalation order (cheapest first):
  1. rebalance — DAM-C-style cost minimization: reassign per-pod grad-accum
     microbatch counts inversely proportional to predicted step time, so the
     all-reduce barrier waits for no straggler (this is "molding" the step:
     the task's width in tokens, not chips).
  2. drain    — if a pod's predicted time exceeds ``drain_ratio`` x median,
     schedule it out (elastic scale-down): emit a RescalePlan that shrinks
     the DP extent; the trainer restarts from checkpoint with the new mesh.
  3. restore  — a recovered pod (ratio back under ``restore_ratio``) is
     scheduled back in at the next checkpoint boundary.

Built on the unified scheduling kernel's primitives (DESIGN.md §3):
measurements flow through the same :func:`~..core.lifecycle.ptt_observe`
feedback path as task commits in either execution engine, and a drained
pod is expressed as the same interned :class:`~..core.places.LiveView`
availability mask a revoked pod-slice produces — ``apply_to(scheduler)``
hands it to a scheduler driving the DES or the threaded runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.lifecycle import ptt_observe
from ..core.places import LiveView, Topology, tpu_pod_slices
from ..core.ptt import PTTBank
from ..core.schedulers import Scheduler


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """What the trainer should do at the next safe point."""
    kind: str                      # "rebalance" | "drain" | "restore" | "none"
    microbatch_share: tuple[float, ...] = ()   # per-pod fraction of tokens
    active_pods: tuple[int, ...] = ()
    reason: str = ""


@dataclasses.dataclass
class PodMonitor:
    n_pods: int
    slices_per_pod: int = 16
    rebalance_ratio: float = 1.15   # act when max/min predicted time exceeds
    drain_ratio: float = 2.5        # drain a pod slower than this x median
    restore_ratio: float = 1.25
    topology: Topology = None       # type: ignore[assignment]
    ptt: PTTBank = None             # type: ignore[assignment]

    def __post_init__(self):
        if self.topology is None:
            self.topology = tpu_pod_slices(self.n_pods, self.slices_per_pod)
        if self.ptt is None:
            # paper's 1:4 weighting -> ~3 steps of hysteresis
            self.ptt = PTTBank(self.topology, new_weight=1.0, old_weight=4.0)
        self._drained: set[int] = set()

    # -- feeding measurements --------------------------------------------------
    def observe(self, pod: int, step_time: float,
                task_type: str = "train_step") -> None:
        part = self.topology.partitions[pod]
        place = part.place_containing(part.start, self.slices_per_pod) \
            if self.slices_per_pod in part.widths else \
            part.place_containing(part.start, max(part.widths))
        # same PTT-feedback path (and therefore the same 1:4 hysteresis
        # semantics) as a task commit in either execution engine
        ptt_observe(self.ptt, task_type, place, step_time)

    def predicted(self, task_type: str = "train_step") -> list[float]:
        tbl = self.ptt.for_type(task_type)
        out = []
        for p in self.topology.partitions:
            w = self.slices_per_pod if self.slices_per_pod in p.widths \
                else max(p.widths)
            out.append(tbl.get(p.place_containing(p.start, w)))
        return out

    # -- kernel bridge ----------------------------------------------------------
    def live_view(self) -> Optional[LiveView]:
        """The interned availability mask of the un-drained fleet — the
        same :class:`LiveView` object the scheduling kernel's engines
        consume for revoked capacity (None = every pod schedulable).
        Draining a pod and revoking a pod-slice are one mechanism."""
        if not self._drained:
            return None
        return self.topology.live_view(frozenset(self._drained))

    def apply_to(self, scheduler: Scheduler) -> None:
        """Point a scheduler driving either engine over this fleet at the
        monitor's availability mask: drained pods leave every wake-time
        placement search until restored.  The mask governs *placement*
        (no HIGH task binds to a drained pod; LOW work may still be
        stolen by its idle cores — taking cores out of execution outright
        is the preemption subsystem's job).  Engines clear the mask when
        their run ends (a revoked-capacity view must never leak into an
        unrelated later run), so re-apply before each run."""
        if scheduler.topology is not self.topology:
            raise ValueError("scheduler does not run over this fleet")
        scheduler.live = self.live_view()

    # -- planning ---------------------------------------------------------------
    def plan(self, task_type: str = "train_step") -> RescalePlan:
        times = self.predicted(task_type)
        active = [i for i in range(self.n_pods) if i not in self._drained]
        known = [(i, times[i]) for i in active if times[i] > 0]
        if len(known) < 2:
            return RescalePlan("none", reason="insufficient observations")
        vals = sorted(t for _, t in known)
        median = vals[len(vals) // 2]

        # 2. drain pathological stragglers
        to_drain = [i for i, t in known if t > self.drain_ratio * median]
        if to_drain:
            remaining = tuple(i for i in active if i not in to_drain)
            if remaining:
                self._drained.update(to_drain)
                return RescalePlan(
                    "drain", active_pods=remaining,
                    reason=f"pods {to_drain} at >{self.drain_ratio}x median "
                           f"({[round(times[i]/median, 2) for i in to_drain]}x)")

        # 3. restore recovered pods
        recovered = [i for i in self._drained
                     if 0 < times[i] <= self.restore_ratio * median]
        if recovered:
            for i in recovered:
                self._drained.discard(i)
            return RescalePlan(
                "restore",
                active_pods=tuple(i for i in range(self.n_pods)
                                  if i not in self._drained),
                reason=f"pods {recovered} recovered")

        # 1. DAM-C-style token rebalance (mold the per-pod microbatch count)
        tmax, tmin = max(t for _, t in known), min(t for _, t in known)
        if tmax / tmin > self.rebalance_ratio:
            inv = [1.0 / t for _, t in known]
            total = sum(inv)
            share = [0.0] * self.n_pods
            for (i, _), w in zip(known, inv):
                share[i] = w / total
            return RescalePlan(
                "rebalance", microbatch_share=tuple(share),
                active_pods=tuple(i for i, _ in known),
                reason=f"straggler ratio {tmax / tmin:.2f} > "
                       f"{self.rebalance_ratio}")
        return RescalePlan("none", active_pods=tuple(active))

    def microbatches_per_pod(self, total_microbatches: int,
                             plan: Optional[RescalePlan] = None) -> list[int]:
        """Integer microbatch counts per pod honoring a rebalance plan
        (largest-remainder rounding; every active pod gets >= 1)."""
        plan = plan or self.plan()
        if plan.kind != "rebalance":
            active = plan.active_pods or tuple(range(self.n_pods))
            base = total_microbatches // len(active)
            rem = total_microbatches - base * len(active)
            out = [0] * self.n_pods
            for j, i in enumerate(active):
                out[i] = base + (1 if j < rem else 0)
            return out
        shares = plan.microbatch_share
        raw = [s * total_microbatches for s in shares]
        out = [max(1, int(r)) if s > 0 else 0 for r, s in zip(raw, shares)]
        while sum(out) > total_microbatches:
            out[out.index(max(out))] -= 1
        while sum(out) < total_microbatches:
            fl = [r - o for r, o in zip(raw, out)]
            out[fl.index(max(fl))] += 1
        return out
