"""Fault tolerance: heartbeats, failure detection, checkpoint-restart.

``HeartbeatMonitor`` tracks liveness per worker (host/pod); a worker is
declared failed after ``timeout`` without a beat.  ``run_with_recovery``
is the generic supervisor loop: it executes a step function, and on
(injected or real) worker failure restores the last checkpoint, skips the
data stream ahead to the restored step (exact, because batches are a pure
function of step), optionally shrinks the active-pod set via the elastic
monitor, and resumes.  Tests inject failures deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from .elastic import PodMonitor, RescalePlan


class HeartbeatMonitor:
    def __init__(self, workers: list[int], timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self._last: dict[int, float] = {w: now for w in workers}
        self._failed: set[int] = set()

    def beat(self, worker: int) -> None:
        self._last[worker] = self.clock()
        self._failed.discard(worker)

    def failed_workers(self) -> set[int]:
        now = self.clock()
        for w, t in self._last.items():
            if now - t > self.timeout:
                self._failed.add(w)
        return set(self._failed)

    def healthy(self) -> bool:
        return not self.failed_workers()


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    kind: str          # "failure" | "straggler" | "rescale"
    detail: str


@dataclasses.dataclass
class Supervisor:
    """Glue object the trainer consults every step."""
    heartbeat: HeartbeatMonitor
    pods: Optional[PodMonitor] = None
    events: list[RecoveryEvent] = dataclasses.field(default_factory=list)

    def check(self, step: int) -> Optional[str]:
        """Returns an action: None | "restart" (failure detected)."""
        failed = self.heartbeat.failed_workers()
        if failed:
            self.events.append(RecoveryEvent(step, "failure",
                                             f"workers {sorted(failed)}"))
            return "restart"
        return None

    def elastic_plan(self, step: int) -> Optional[RescalePlan]:
        if self.pods is None:
            return None
        plan = self.pods.plan()
        if plan.kind != "none":
            self.events.append(RecoveryEvent(step, "rescale",
                                             f"{plan.kind}: {plan.reason}"))
        return plan
