from .elastic import PodMonitor, RescalePlan
from .ft import HeartbeatMonitor, RecoveryEvent, Supervisor

__all__ = ["PodMonitor", "RescalePlan", "HeartbeatMonitor", "RecoveryEvent",
           "Supervisor"]
