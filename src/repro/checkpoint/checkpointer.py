"""Sharded, atomic, async checkpointing.

Layout:
  <dir>/step_000123/
      arrays.npz          — all leaves, keyed by flattened tree path
      manifest.json       — step, data-stream state, tree structure digest
  <dir>/LATEST            — text file naming the last *complete* step dir

Writes go to ``step_X.tmp`` then ``os.replace`` (atomic on POSIX), and
LATEST is only updated after the rename — a crash mid-save can never leave
a half checkpoint as the restore target.  ``save_async`` hands the host
copy to a writer thread so the train loop does not stall on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(jax.device_get(tree))
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "extra": extra or {},
                    "n_leaves": len(flat)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # LATEST updated only after the atomic rename
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[dict] = None) -> None:
        self.wait()                       # one in flight at a time
        host_tree = jax.device_get(tree)  # snapshot before training mutates

        def run():
            try:
                self.save(step, host_tree, extra)
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template: PyTree, step: Optional[int] = None
                ) -> tuple[PyTree, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return _unflatten(template, flat), manifest

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
