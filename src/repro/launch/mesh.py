"""Production meshes.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips — the pod axis is the
    DCN dimension; gradients reduce hierarchically (ICI inside each pod,
    then one DCN all-reduce across pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
