"""CPU-scale serving driver: batched requests through the PTT-scheduled
engine (reduced model), demonstrating criticality-aware placement under
injected interference.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --requests 12 --scheduler DAM-P --slow-core 0:4
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHS
from ..core import tpu_pod_slices
from ..serve import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--scheduler", default="DAM-P")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--slices", type=int, default=2)
    ap.add_argument("--slow-core", default=None,
                    help="core:factor, e.g. 0:4 = core 0 runs 4x slower")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    topo = tpu_pod_slices(args.pods, args.slices)
    slowdown = None
    if args.slow_core:
        c, f = args.slow_core.split(":")
        slowdown = {int(c): float(f)}
    engine = ServingEngine(cfg, topo, scheduler=args.scheduler,
                           max_len=args.prompt_len + args.new_tokens + 8,
                           slowdown=slowdown)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab, size=args.prompt_len),
                      max_new_tokens=args.new_tokens)
    metrics = engine.run(timeout=300.0)
    stats = engine.latency_stats()
    print(f"[serve] {stats}")
    print(f"[serve] prefill placement: "
          f"{ {k: v for k, v in metrics.priority_placement().items()} }")


if __name__ == "__main__":
    main()
