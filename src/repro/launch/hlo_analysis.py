"""Trip-count-aware roofline extraction from optimized (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — a scanned
48-layer model with 8 grad-accum microbatches is undercounted ~384x, which
would make every roofline term garbage.  This module parses
``compiled.as_text()`` and walks the computation graph weighting each
while body by its trip count (jax scans lower to while loops whose
condition compares the induction variable against a constant — we read
that constant).

Per-device outputs:
  flops            — 2*M*N*K for every dot, weighted by enclosing loops
  bytes            — operand + result bytes of every top-level op (fusion
                     ops count their boundary, not their interior), i.e.
                     the HBM traffic a perfectly-fused executor would see
  collectives      — result bytes per collective opcode (all-reduce
                     weighted 2x for the ring), loop-weighted
  coll_counts      — issue counts per opcode, loop-weighted
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$")
_OPCODE_RE = re.compile(r"^(?P<type>\([^)]*\)|\S+)\s+(?P<op>[\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    type_str: str
    operands: list[str]
    attrs: str
    line: str
    is_root: bool = False


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    cur_name = None
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw)
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur_name = m.group("name")
                cur = []
            continue
        if line.strip() == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        op = om.group("op")
        paren = rest[om.end():]
        # operand names are inside the first balanced paren group
        depth, i = 1, 0
        while i < len(paren) and depth:
            if paren[i] == "(":
                depth += 1
            elif paren[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = paren[:i], paren[i:]
        cur.append(_Instr(m.group("name"), op, om.group("type"),
                          _OPERAND_RE.findall(operand_str), attrs, line,
                          is_root=line.lstrip().startswith("ROOT")))
    return comps


def _trip_count(comp: list[_Instr]) -> int:
    """jax scan conditions: compare(induction, constant) -> the constant."""
    for ins in comp:
        if ins.opcode == "constant" and ins.type_str.startswith("s32[]"):
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                return max(1, int(m.group(1)))
    return 1


def _attr(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _dims_attr(attrs: str, key: str) -> list[int]:
    m = re.search(key + r"=\{([0-9,]*)\}", attrs)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


class HloAnalysis:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives: dict[str, float] = {}
        self.coll_counts: dict[str, float] = {}
        entry = self._find_entry(text)
        if entry:
            self._walk(entry, 1.0, count_bytes=True)

    def _find_entry(self, text: str) -> str | None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m and m.group(1) in self.comps:
            return m.group(1)
        # fallback: largest computation
        return max(self.comps, key=lambda k: len(self.comps[k]), default=None)

    def _walk(self, comp_name: str, weight: float, count_bytes: bool):
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        symtab = {ins.name: ins.type_str for ins in comp}
        for ins in comp:
            op = ins.opcode
            if op == "while":
                body = _attr(ins.attrs, "body")
                cond = _attr(ins.attrs, "condition")
                trips = _trip_count(self.comps.get(cond, [])) if cond else 1
                if body:
                    self._walk(body, weight * trips, count_bytes)
                continue
            if op in ("call", "async-start", "custom-call"):
                tgt = _attr(ins.attrs, "to_apply") or _attr(ins.attrs, "called_computations")
                if tgt:
                    self._walk(tgt, weight, count_bytes)
                continue
            if op == "conditional":
                for key in ("true_computation", "false_computation"):
                    tgt = _attr(ins.attrs, key)
                    if tgt:
                        self._walk(tgt, weight, count_bytes)
                continue
            if op == "fusion":
                tgt = _attr(ins.attrs, "calls")
                if tgt:
                    self._walk(tgt, weight, count_bytes=False)  # flops only
                if count_bytes:
                    self.bytes += weight * self._fusion_bytes(ins, symtab, tgt)
                continue
            if op == "dot":
                self.flops += weight * self._dot_flops(ins, symtab)
                if count_bytes:
                    self.bytes += weight * self._io_bytes(ins, symtab)
                continue
            if op in COLLECTIVES or any(op == c + "-start" for c in COLLECTIVES):
                base = op.replace("-start", "")
                nbytes = _shape_bytes(ins.type_str)
                factor = 2.0 if base == "all-reduce" else 1.0
                self.collectives[base] = self.collectives.get(base, 0.0) + \
                    weight * nbytes * factor
                self.coll_counts[base] = self.coll_counts.get(base, 0.0) + weight
                if count_bytes:
                    self.bytes += weight * self._io_bytes(ins, symtab)
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "all-reduce-done", "all-gather-done", "copy-done",
                      "async-done"):
                continue
            if count_bytes:
                self.bytes += weight * self._io_bytes(ins, symtab)

    def _io_bytes(self, ins: _Instr, symtab: dict[str, str]) -> float:
        """Bytes actually touched.  Slicing/indexed ops must NOT count their
        full operands: a dynamic-slice of a stacked [L, ...] parameter inside
        a layer scan reads one slice, not the whole stack (counting the stack
        x trip-count overstates HBM traffic by orders of magnitude)."""
        op = ins.opcode
        result = _shape_bytes(ins.type_str)
        if op in ("dynamic-slice", "slice", "gather", "iota", "broadcast",
                  "reshape", "transpose", "convert", "reduce", "copy"):
            # read ~result-sized region (+ write result)
            return 2.0 * result
        if op == "dynamic-update-slice":
            upd = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
            upd_b = _shape_bytes(upd) if upd else result
            return 2.0 * upd_b          # read update + write the region
        if op == "scatter":
            upd = symtab.get(ins.operands[2]) if len(ins.operands) > 2 else None
            upd_b = _shape_bytes(upd) if upd else result
            return 3.0 * upd_b          # read region+update, write region
        total = float(result)
        for opnd in ins.operands:
            t = symtab.get(opnd)
            if t:
                total += _shape_bytes(t)
        return total

    def _fusion_bytes(self, ins: _Instr, symtab: dict[str, str],
                      comp_name: str | None) -> float:
        """HBM traffic of a fusion = what it reads from each parameter +
        what it writes.

        * a parameter consumed only through slicing ops (dynamic-slice /
          slice / gather), possibly behind bitcasts, is read slice-sized —
          this is how scanned layer stacks are accessed; counting the full
          stack x trip-count overstates traffic by the layer count;
        * a parameter that is the *target* (operand 0) of a
          dynamic-update-slice is aliased in place — not read;
        * if the fusion root is a dynamic-update-slice, the write is the
          update row, not the whole buffer (scan-ys accumulation pattern);
        * fused intermediates never touch HBM."""
        result = float(_shape_bytes(ins.type_str))
        comp = self.comps.get(comp_name) if comp_name else None
        if comp is None:
            return result + sum(_shape_bytes(symtab.get(o, ""))
                                for o in ins.operands)
        inner_by_name = {i.name: i for i in comp}

        def through_bitcast(name):
            """Consumers of `name`, looking through pure layout ops."""
            out = []
            for i in comp:
                for pos, opnd in enumerate(i.operands):
                    if opnd != name:
                        continue
                    if i.opcode in ("bitcast", "reshape", "transpose",
                                    "copy", "convert"):
                        out.extend(through_bitcast(i.name))
                    else:
                        out.append((i, pos))
            return out

        param_bytes: dict[str, float] = {}
        for inner in comp:
            if inner.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", inner.line)
                if m and int(m.group(1)) < len(ins.operands):
                    outer_t = symtab.get(ins.operands[int(m.group(1))], "")
                    param_bytes[inner.name] = float(_shape_bytes(outer_t))

        slicing = ("dynamic-slice", "slice", "gather")
        total = 0.0
        for pname, pbytes in param_bytes.items():
            reads, full = 0.0, False
            for c, pos in through_bitcast(pname):
                if c.opcode in slicing:
                    reads += _shape_bytes(c.type_str)
                elif c.opcode == "dynamic-update-slice" and pos == 0:
                    pass                      # in-place alias target
                else:
                    full = True
            total += pbytes if full else reads

        # the write side
        root = next((i for i in comp if i.is_root), None)
        while root is not None and root.opcode in (
                "bitcast", "reshape", "transpose", "copy", "convert"):
            root = inner_by_name.get(root.operands[0]) if root.operands else None
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) > 1:
            upd = inner_by_name.get(root.operands[1])
            total += _shape_bytes(upd.type_str) if upd is not None else result
        else:
            total += result
        return total

    def _dot_flops(self, ins: _Instr, symtab: dict[str, str]) -> float:
        out_dims = _shape_dims(ins.type_str)
        out_n = 1
        for dl in out_dims:
            for d in dl:
                out_n *= d
        lhs_t = symtab.get(ins.operands[0]) if ins.operands else None
        contract = 1
        if lhs_t:
            lhs_dims = _shape_dims(lhs_t)
            if lhs_dims:
                for d in _dims_attr(ins.attrs, "lhs_contracting_dims"):
                    if d < len(lhs_dims[0]):
                        contract *= lhs_dims[0][d]
        return 2.0 * out_n * contract

    def summary(self) -> dict:
        coll_total = sum(self.collectives.values())
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collectives, total=coll_total),
            "collective_counts": self.coll_counts,
        }


def analyze_hlo(text: str) -> dict:
    return HloAnalysis(text).summary()
