"""CPU-scale training driver (reduced configs).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 50 --batch 8 --seq 128

The full configs are exercised by the dry-run; this driver actually trains
the reduced variant end-to-end with checkpointing and the straggler
monitor, and demonstrates restart-after-kill (--resume).
"""
from __future__ import annotations

import argparse
import tempfile

from ..configs import ARCHS
from ..data import DataConfig
from ..optim import AdamWConfig
from ..train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) architecture config")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if not args.full_config:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")

    trainer = Trainer(cfg, opt_cfg, data_cfg, tcfg, ckpt_dir)
    if args.resume and trainer.try_restore():
        print(f"[train] resumed from step {trainer.step}")
    hist = trainer.run()
    print(f"[train] done: {len(hist)} steps, "
          f"final loss {hist[-1]['loss']:.4f}, checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
