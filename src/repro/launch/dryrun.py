import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder devices.  Nothing
else in the repo sets this flag (smoke tests and benches see 1 device).

Per cell this driver:
  1. builds abstract params / optimizer / batch / decode-state trees with
     jax.eval_shape (ShapeDtypeStruct only — nothing is allocated);
  2. jits the step with in/out shardings from parallel/sharding.py and
     runs .lower().compile();
  3. prints compiled.memory_analysis() (proof it fits per-chip HBM) and
     cost_analysis() (FLOPs / bytes);
  4. parses compiled.as_text() for all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute result bytes;
  5. computes the three roofline terms (compute / memory / collective,
     TPU v5e constants) and writes a JSON artifact under
     benchmarks/artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SHAPES, shape_applicable
from ..models import init_decode_state, init_params
from ..optim import AdamWConfig, init_opt_state
from ..parallel import (batch_specs, decode_state_specs, opt_moment_specs,
                        param_specs, to_named)
from ..train import make_decode_step, make_prefill_step
from .mesh import make_production_mesh

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (task-specified ~50 GB/s/link)
HBM_PER_CHIP = 16 * 2 ** 30

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

def n_micro_for(mesh) -> int:
    """Grad-accum microbatches per train step: keep one sequence per DP
    shard per microbatch (batch 256: 16 micro on single pod, 8 on multi)."""
    from ..parallel.sharding import dp_axes
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    return max(1, 256 // dp)

_COLL_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the compiled
    (SPMD-partitioned) module.  all-reduce moves ~2x its payload on a ring
    (reduce-scatter + all-gather phases)."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:
            continue
        nbytes = 0
        for sm in _SHAPE_RE.finditer(m.group("out")):
            dims = sm.group("dims")
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(sm.group("dt"), 4)
        factor = 2.0 if op == "all-reduce" else 1.0
        totals[op] = totals.get(op, 0.0) + nbytes * factor
        counts[op] = counts.get(op, 0) + 1
    totals["total"] = sum(totals.values())
    return {"bytes": totals, "counts": counts}


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


FSDP_BYTES_THRESHOLD = 2.5e9   # bf16 params per device above this -> FSDP


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (step_fn, arg_shapes, in_shardings) for one cell."""
    cfg = dataclasses.replace(ARCHS[arch], dtype="bfloat16")
    shape = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)

    # TP shards params over "model"; if that still exceeds the HBM budget
    # (the 70B VLM, the 30B MoEs), add FSDP over "data" and sequence
    # parallelism (SP costs weight-grad partial-sum ARs in the scan bwd —
    # only worth it when activation memory is critical; §Perf iteration 3).
    per_dev_param_bytes = cfg.n_params * 2 / mesh.shape["model"]
    use_fsdp = per_dev_param_bytes > FSDP_BYTES_THRESHOLD
    cfg = dataclasses.replace(cfg, seq_parallel=use_fsdp)
    p_shape = _abstract(lambda: init_params(cfg, key))
    p_spec = param_specs(p_shape, mesh, fsdp=use_fsdp)

    if shape.kind == "train":
        opt_shape = _abstract(init_opt_state, p_shape)
        o_spec = opt_moment_specs_tree(p_shape, opt_shape, mesh)
        n_micro = n_micro_for(mesh)
        micro = shape.global_batch // n_micro
        s_text = shape.seq_len - (cfg.frontend_len if cfg.frontend != "none" else 0)
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct((n_micro, micro, s_text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((n_micro, micro, s_text), jnp.int32),
        }
        if cfg.frontend != "none":
            batch_shape["frontend"] = jax.ShapeDtypeStruct(
                (n_micro, micro, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        b_spec = micro_batch_specs(batch_shape, mesh)
        opt_cfg = AdamWConfig()
        step = make_accum_train_step(cfg, opt_cfg,
                                     grad_specs=opt_moment_specs(p_shape, mesh),
                                     n_micro=n_micro)
        args = (p_shape, opt_shape, batch_shape)
        shardings = (p_spec, o_spec, b_spec)
        out_spec = (p_spec, o_spec, None)
    elif shape.kind == "prefill":
        s_text = shape.seq_len - (cfg.frontend_len if cfg.frontend != "none" else 0)
        batch_shape = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, s_text), jnp.int32)}
        if cfg.frontend != "none":
            batch_shape["frontend"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.frontend_len, cfg.d_model),
                jnp.bfloat16)
        b_spec = batch_specs(batch_shape, mesh)
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        st_shape = _abstract(
            lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
        st_spec = decode_state_specs(st_shape, mesh)
        args = (p_shape, batch_shape)
        shardings = (p_spec, b_spec)
        out_spec = (None, st_spec)
    else:  # decode
        st_shape = _abstract(
            lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
        st_spec = decode_state_specs(st_shape, mesh)
        tok_shape = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        t_spec = batch_specs(tok_shape, mesh)
        step = make_decode_step(cfg)
        args = (p_shape, st_shape, tok_shape)
        shardings = (p_spec, st_spec, t_spec)
        out_spec = (None, st_spec)
    return cfg, step, args, shardings, out_spec


def micro_batch_specs(batch_shape, mesh):
    """[n_micro, B_micro, ...]: micro axis replicated, batch over DP."""
    from ..parallel.sharding import dp_axes, sanitize
    dp = dp_axes(mesh)

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        return sanitize((None, dp) + (None,) * (len(shape) - 2), shape, mesh)

    return jax.tree.map(spec_for, batch_shape)


def opt_moment_specs_tree(p_shape, opt_shape, mesh):
    """Specs for the optimizer pytree {m, v, step, master?}."""
    from jax.sharding import PartitionSpec as P
    moments = opt_moment_specs(p_shape, mesh)
    spec = {"m": moments, "v": moments, "step": P()}
    if "master" in opt_shape:
        spec["master"] = moments
    return spec


def make_accum_train_step(cfg, opt_cfg, grad_specs=None, n_micro=8):
    """Grad-accumulation train step: scan over the microbatches, then
    one optimizer update — bounds logits/activation memory while keeping
    the full global batch semantics in a single jitted step.

    ``grad_specs`` (ZeRO-2): pin the fp32 accumulator to the optimizer-
    moment sharding (params sharding + "data" on a free dim) — GSPMD then
    emits a reduce-scatter per microbatch instead of holding a replicated
    fp32 gradient buffer (4 bytes/param/device -> 4/DP bytes)."""
    from ..models import loss_and_metrics
    from ..optim import apply_updates

    def pin(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def step(params, opt_state, batch):
        def micro_grad(carry, micro):
            gsum, lsum = carry

            def loss_fn(p):
                return loss_and_metrics(p, cfg, micro, remat=True)

            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            gsum = pin(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, lsum + metrics["loss"]), None

        g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))
        (gsum, lsum), _ = jax.lax.scan(micro_grad, (g0, 0.0), batch)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_p, new_o, info = apply_updates(params, grads, opt_state, opt_cfg)
        return new_p, new_o, {"loss": lsum / n_micro, **info}

    return step


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = False) -> dict:
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg_full = ARCHS[arch]
    ok, reason = shape_applicable(cfg_full, SHAPES[shape_name])
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        record.update(status="SKIPPED", reason=reason)
        _write(path, record)
        print(f"[dryrun] {tag}: SKIPPED ({reason.split(':')[0]})")
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        from ..parallel import sharding_ctx
        cfg, step, args, shardings, out_spec = build_cell(
            arch, shape_name, mesh)
        kind = SHAPES[shape_name].kind
        # donate the big state: train donates params+opt, decode the cache
        donate = (0, 1) if kind == "train" else ((1,) if kind == "decode" else ())
        with mesh, sharding_ctx(mesh):
            jitted = jax.jit(step,
                             in_shardings=to_named(shardings, mesh),
                             out_shardings=None,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        from .hlo_analysis import analyze_hlo
        hla = analyze_hlo(hlo)   # trip-count-weighted (cost_analysis counts
        #                          while bodies once — useless for scans)
        coll = {"bytes": hla["collective_bytes"],
                "counts": hla["collective_counts"]}

        flops_total = float(hla["flops"])          # per-device
        bytes_total = float(hla["bytes"])          # per-device
        compute_s = flops_total / PEAK_FLOPS
        memory_s = bytes_total / HBM_BW
        coll_s = coll["bytes"].get("total", 0.0) / ICI_BW

        shape = SHAPES[shape_name]
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        model_flops = 6 * cfg_full.n_active_params * tokens if shape.kind == "train" \
            else 2 * cfg_full.n_active_params * tokens
        mem_record = {}
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            mem_record[attr] = getattr(mem, attr, None)
        args_b = mem_record.get("argument_size_in_bytes") or 0
        temp_b = mem_record.get("temp_size_in_bytes") or 0
        fits = (args_b + temp_b) <= HBM_PER_CHIP

        record.update(
            status="OK",
            n_chips=n_chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=mem_record,
            fits_hbm=bool(fits),
            per_device_bytes=int(args_b + temp_b),
            cost_analysis_raw={k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float))},
            collectives=coll,
            roofline={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": coll_s,
                "dominant": max(
                    (("compute", compute_s), ("memory", memory_s),
                     ("collective", coll_s)), key=lambda kv: kv[1])[0],
                "model_flops": float(model_flops),
                "hlo_flops_per_dev": flops_total,
                "useful_flops_ratio": float(model_flops / n_chips
                                            / max(flops_total, 1.0)),
            },
        )
        print(f"[dryrun] {tag}: OK chips={n_chips} "
              f"per-dev={int((args_b + temp_b) / 2 ** 20)}MiB fits={fits} "
              f"compute={compute_s * 1e3:.1f}ms mem={memory_s * 1e3:.1f}ms "
              f"coll={coll_s * 1e3:.1f}ms dom={record['roofline']['dominant']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # record failures — they are bugs to fix
        record.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
    _write(path, record)
    return record


def _write(path: str, record: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    if not args.all and (args.arch is None or args.shape is None):
        ap.error("pass --arch and --shape, or --all")

    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, mesh_kind, args.out,
                                        args.skip_existing))
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIPPED" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"[dryrun] done: {n_ok} OK, {n_skip} skipped, {n_fail} FAILED "
          f"of {len(results)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
