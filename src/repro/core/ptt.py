"""Performance Trace Table (paper §4.1.1).

One PTT per *task type*.  Entries are indexed by execution place
``(leader core, width)`` and hold a weighted moving average of observed
execution times as seen by the leader core:

    updated = (old_weight * old + new_weight * obs) / (old_weight + new_weight)

with the paper's recommended ratio 1:4 (``new_weight=1, old_weight=4``) so at
least three observations are needed before the entry tracks a new performance
regime.  Entries start at zero, which the schedulers interpret as
"unexplored — try me first", guaranteeing every place is evaluated at least
once early in the run (paper: "The entries are initialized to zero. This
ensures that all possible execution places are evaluated at least once").

The Algorithm-1 searches (``global_search`` / ``local_search`` /
``width1_search``) run as masked argmins over the dense table using the
topology's precomputed place-index arrays: unexplored (0.0) entries win
automatically, ties prefer narrower places, and residual ties break
*randomly* so equal predictions never pile onto the lowest core id.  This
keeps wake-time placement O(1)-ish numpy work instead of a Python loop over
every place per HIGH task.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Iterable, Optional

import numpy as np

from .places import ExecutionPlace, Topology

# Below this many candidates the searches run as plain-Python loops over the
# persistent mirror lists (numpy's fixed per-call overhead dominates tiny
# argmins on embedded-class topologies like tx2); above it they run as numpy
# masked argmins over the same persistent arrays.  Both paths perform the
# identical IEEE-754 float64 operations over the identical candidate order,
# so the crossover is behavior-invisible.
_PY_SEARCH_MAX = 128


class PTT:
    """Trace table for a single task type over a topology's places.

    Stored as a dense ``[n_cores, n_width_slots]`` float array (rows are
    per-core — the paper lays rows out to fit cache lines so each core
    touches its own line; we keep the same row-major layout).  Invalid
    (core, width) combinations hold NaN.
    """

    def __init__(self, topology: Topology, *, new_weight: float = 1.0,
                 old_weight: float = 4.0, first_visit_direct: bool = True):
        self.topology = topology
        self.new_weight = float(new_weight)
        self.old_weight = float(old_weight)
        self.first_visit_direct = first_visit_direct
        widths = sorted({w for p in topology.partitions for w in p.widths})
        self._w_slot = {w: i for i, w in enumerate(widths)}
        self.table = np.full((topology.n_cores, len(widths)), np.nan)
        self.visits = np.zeros_like(self.table, dtype=np.int64)
        # update-order tick per entry (-1 = never updated): staleness metric
        # for the forced-revisit escape hatch (see ``stalest``)
        self.last_update = np.full(self.table.shape, -1, dtype=np.int64)
        self._tick = 0
        for place in topology.places():
            self.table[place.leader, self._w_slot[place.width]] = 0.0
        self._lock = threading.Lock()

        # Vectorized-search metadata: for the i-th valid place, its flat
        # offset into ``table`` and its width (float).  ``_flat`` is a view,
        # so in-place ``update``s are immediately visible to the searches.
        self._places = topology.places()
        n_slots = len(widths)
        slots = np.array([self._w_slot[pl.width] for pl in self._places],
                         dtype=np.int64)
        self._pos = topology.place_leaders * n_slots + slots
        self._wf = topology.place_widths_f
        self._flat = self.table.reshape(-1)
        self._lu_flat = self.last_update.reshape(-1)
        self._visits_flat = self.visits.reshape(-1)

        # Persistent place-aligned score arrays, the search-side invariant:
        # _vals[i] mirrors table[place i], _costs[i] == _vals[i] * width_i,
        # _lu_place[i] mirrors last_update[place i].  They are maintained
        # incrementally by update()/prime() (the only table writers), so the
        # searches never re-gather or re-multiply the dense table per wake.
        # The *_l lists are plain-Python mirrors of the same doubles feeding
        # the small-n fast path.
        n_places = len(self._places)
        self._vals = np.zeros(n_places)
        self._costs = np.zeros(n_places)
        self._lu_place = np.full(n_places, -1, dtype=np.int64)
        self._vals_l = [0.0] * n_places
        self._costs_l = [0.0] * n_places
        self._visits_l = [0] * n_places
        self._lu_l = [-1] * n_places
        self._wf_l = self._wf.tolist()
        self._pos_l = self._pos.tolist()
        self._all_idx_l = list(range(n_places))
        self._pidx = {(pl.leader, pl.width): i
                      for i, pl in enumerate(self._places)}
        # Per-core local candidate lists (lazily materialized) so the hot
        # local_search fast path never re-converts the index array.
        self._local_js: list[Optional[list[int]]] = [None] * topology.n_cores
        # Small-n tables defer the numpy-side stores (dense table + place
        # mirrors) from update()/prime() to a flush the numpy/score_fn
        # search branches and snapshot() trigger: the plain-Python search
        # path reads only the *_l lists, so per-commit numpy scalar stores
        # would be pure overhead.  The flushed values are bit-identical to
        # the write-through ones (same doubles, same cells).
        self._lazy_np = n_places <= _PY_SEARCH_MAX
        self._np_dirty = False

    # -- queries ------------------------------------------------------------
    def get(self, place: ExecutionPlace) -> float:
        """Predicted execution time; 0.0 means unexplored."""
        i = self._pidx.get((place.leader, place.width))
        if i is None:        # invalid combination: NaN, like the dense read
            return float(self.table[place.leader, self._w_slot[place.width]])
        return self._vals_l[i]

    def visited(self, place: ExecutionPlace) -> int:
        i = self._pidx.get((place.leader, place.width))
        if i is None:        # invalid combination: 0, like the dense read
            return int(self.visits[place.leader, self._w_slot[place.width]])
        return self._visits_l[i]

    def best_explored(self) -> Optional[float]:
        """Minimum *measured* time estimate across this table's valid
        places — never-updated entries (whose 0.0 means "unexplored", not
        "instant") are excluded.  None until any place has been visited.
        The per-shard PTT-divergence summary the global rebalancer
        compares (read-only; list mirrors, so lazy-np state is
        irrelevant)."""
        best = None
        vl = self._vals_l
        nv = self._visits_l
        for i in self._all_idx_l:
            if nv[i] and (best is None or vl[i] < best):
                best = vl[i]
        return best

    def _flush_np(self) -> None:
        """Propagate deferred update()/prime() writes into the dense table
        and the numpy place mirrors (lazy small-n mode only)."""
        with self._lock:
            if not self._np_dirty:
                return
            self._vals[:] = self._vals_l
            self._costs[:] = self._costs_l
            self._lu_place[:] = self._lu_l
            self._flat[self._pos] = self._vals
            self._visits_flat[self._pos] = self._visits_l
            self._lu_flat[self._pos] = self._lu_l
            self._np_dirty = False

    # -- updates ------------------------------------------------------------
    def update(self, place: ExecutionPlace, observed: float) -> float:
        """Weighted-average update, performed by the leader on task commit."""
        if observed < 0 or not math.isfinite(observed):
            raise ValueError(f"bad observation {observed!r}")
        i = self._pidx.get((place.leader, place.width))
        if i is None:
            raise KeyError(f"invalid place {place}")
        with self._lock:
            if self._visits_l[i] == 0 and self.first_visit_direct:
                new = float(observed)
            else:
                new = (self.old_weight * self._vals_l[i]
                       + self.new_weight * observed) / (
                    self.old_weight + self.new_weight)
            cost = new * self._wf_l[i]
            tick = self._tick
            self._tick = tick + 1
            if self._lazy_np:
                self._np_dirty = True
            else:
                pos = self._pos_l[i]
                self._flat[pos] = new
                self._visits_flat[pos] += 1
                self._lu_flat[pos] = tick
                self._vals[i] = new
                self._costs[i] = cost
                self._lu_place[i] = tick
            self._vals_l[i] = new
            self._costs_l[i] = cost
            self._visits_l[i] += 1
            self._lu_l[i] = tick
            return new

    def update_nolock(self, place: ExecutionPlace, observed: float) -> float:
        """Single-threaded-caller form of :meth:`update` (the DES commit
        path): identical math and mirror writes, no lock acquisition."""
        if observed < 0 or not math.isfinite(observed):
            raise ValueError(f"bad observation {observed!r}")
        i = self._pidx.get((place.leader, place.width))
        if i is None:
            raise KeyError(f"invalid place {place}")
        if self._visits_l[i] == 0 and self.first_visit_direct:
            new = float(observed)
        else:
            new = (self.old_weight * self._vals_l[i]
                   + self.new_weight * observed) / (
                self.old_weight + self.new_weight)
        cost = new * self._wf_l[i]
        tick = self._tick
        self._tick = tick + 1
        if self._lazy_np:
            self._np_dirty = True
        else:
            pos = self._pos_l[i]
            self._flat[pos] = new
            self._visits_flat[pos] += 1
            self._lu_flat[pos] = tick
            self._vals[i] = new
            self._costs[i] = cost
            self._lu_place[i] = tick
        self._vals_l[i] = new
        self._costs_l[i] = cost
        self._visits_l[i] += 1
        self._lu_l[i] = tick
        return new

    def prime(self, place: ExecutionPlace, value: float) -> bool:
        """Seed an *unexplored* entry with a prior estimate (PTT warmup
        without traffic).  Returns True if the entry was primed, False if
        it already holds a measurement (priming never overrides data).
        A primed entry does not count as visited: the first real
        observation still overwrites it directly (``first_visit_direct``)
        and ``stalest`` still treats it as never-measured — the prior is
        deliberately weak."""
        if value <= 0 or not math.isfinite(value):
            raise ValueError(f"bad prime value {value!r}")
        i = self._pidx.get((place.leader, place.width))
        if i is None:
            raise KeyError(f"invalid place {place}")
        with self._lock:
            if self._visits_l[i] == 0 and self._vals_l[i] == 0.0:
                new = float(value)
                cost = new * self._wf_l[i]
                if self._lazy_np:
                    self._np_dirty = True
                else:
                    self._flat[self._pos_l[i]] = new
                    self._vals[i] = new
                    self._costs[i] = cost
                self._vals_l[i] = new
                self._costs_l[i] = cost
                return True
            return False

    # -- searches (Algorithm 1 primitives) ------------------------------------
    def _score(self, place: ExecutionPlace, *, cost: bool) -> tuple[float, float]:
        """Sort key: unexplored (0.0) places sort first, then by predicted
        time (or parallel cost = time*width).  Ties break toward narrower
        places (use fewer resources when indifferent)."""
        t = self.get(place)
        value = t * place.width if cost else t
        return (value, place.width)

    def best(self, places: Iterable[ExecutionPlace], *, cost: bool,
             rng=None) -> ExecutionPlace:
        """argmin with *random* final tie-break: equal predictions must not
        systematically pile decisions onto the lowest core id.

        Generic (any candidate iterable) Python path — the hot searches
        below use the vectorized ``_best_from_indices`` instead."""
        best_score, cands = None, []
        for pl in places:
            s = self._score(pl, cost=cost)
            if best_score is None or s < best_score:
                best_score, cands = s, [pl]
            elif s == best_score:
                cands.append(pl)
        if len(cands) > 1 and rng is not None:
            return cands[rng.randrange(len(cands))]
        return cands[0]

    def _pick_min(self, score: np.ndarray, w: np.ndarray,
                  idx: Optional[np.ndarray], rng) -> ExecutionPlace:
        """Shared argmin tail of every search: minimal score, ties prefer
        the narrowest width, residual ties break uniformly at random."""
        cands = np.flatnonzero(score == score.min())
        if len(cands) > 1:
            wt = w[cands]
            cands = cands[wt == wt.min()]
        if len(cands) == 1 or rng is None:
            k = cands[0]
        else:
            k = cands[rng.randrange(len(cands))]
        return self._places[int(k) if idx is None else int(idx[int(k)])]

    def _pick_min_py(self, cands: list, rng) -> ExecutionPlace:
        """Python-path argmin tail: ``cands`` already holds the minimal-score
        place indices in candidate order; filter to the narrowest width and
        draw the residual tie exactly like ``_pick_min``."""
        if len(cands) > 1:
            wl = self._wf_l
            wmin = min(wl[j] for j in cands)
            cands = [j for j in cands if wl[j] == wmin]
        if len(cands) == 1 or rng is None:
            return self._places[cands[0]]
        return self._places[cands[rng.randrange(len(cands))]]

    def _best_from_indices(self, idx: Optional[np.ndarray], *, cost: bool,
                           rng=None, load: Optional[np.ndarray] = None,
                           penalty: float = 0.0,
                           score_fn: Optional[Callable] = None
                           ) -> ExecutionPlace:
        """Masked argmin over the persistent score arrays restricted to
        place indices ``idx`` (None = all valid places).  Semantics
        identical to ``best`` over the same candidates in the same order:
        unexplored entries (0.0) sort first, ties prefer the narrowest
        width, residual ties are broken uniformly at random.

        ``load`` (aligned with the full place list) makes the search
        queue-aware: the score becomes ``ptt + penalty * load[place]``, so
        concurrent wakes spread over places instead of herding onto the
        current argmin.  ``load=None`` (the default) is the exact
        pre-load-awareness code path.

        ``score_fn`` (the ``placement_backend="jax"`` hook) computes the
        score vector ``vals + penalty * load`` externally (e.g. as a jitted
        kernel); the tie-break tail stays host-side so the RNG draw
        sequence is backend-independent."""
        use_load = load is not None and penalty > 0.0
        if score_fn is not None:
            if self._np_dirty:
                self._flush_np()
            vals = self._costs if cost else self._vals
            w = self._wf
            if idx is not None:
                vals, w = vals[idx], w[idx]
            lsub = None
            if use_load:
                lsub = load if idx is None else load[idx]
            score = np.asarray(score_fn(vals, lsub, penalty))
            return self._pick_min(score, w, idx, rng)
        n = len(self._all_idx_l) if idx is None else len(idx)
        if n <= _PY_SEARCH_MAX:
            vl = self._costs_l if cost else self._vals_l
            js = self._all_idx_l if idx is None else idx.tolist()
            best = None
            cands = None
            if use_load:
                ll = load.tolist() if isinstance(load, np.ndarray) else load
                for j in js:
                    s = vl[j] + penalty * ll[j]
                    if best is None or s < best:
                        best, cands = s, [j]
                    elif s == best:
                        cands.append(j)
            else:
                for j in js:
                    s = vl[j]
                    if best is None or s < best:
                        best, cands = s, [j]
                    elif s == best:
                        cands.append(j)
            return self._pick_min_py(cands, rng)
        if self._np_dirty:
            self._flush_np()
        vals = self._costs if cost else self._vals
        w = self._wf
        if idx is not None:
            vals, w = vals[idx], w[idx]
        score = vals
        if use_load:
            score = vals + penalty * (load if idx is None else load[idx])
        return self._pick_min(score, w, idx, rng)

    def local_search(self, core: int, *, cost: bool = True, rng=None,
                     load: Optional[np.ndarray] = None,
                     penalty: float = 0.0,
                     idx: Optional[np.ndarray] = None,
                     score_fn: Optional[Callable] = None) -> ExecutionPlace:
        """Paper: keep partition+core fixed, mold only the width.  ``idx``
        overrides the candidate set (a live-masked subset of the core's
        local places under sub-pod revocation); None is the exact
        unmasked path."""
        if idx is None:
            js = self._local_js[core]
            if js is None:
                js = self._local_js[core] = \
                    self.topology.local_place_indices(core).tolist()
            # inlined small-n no-load loop (identical ops/order to the
            # generic _best_from_indices python branch)
            if score_fn is None and len(js) <= _PY_SEARCH_MAX and (
                    load is None or penalty <= 0.0):
                vl = self._costs_l if cost else self._vals_l
                best = None
                cands = None
                for j in js:
                    s = vl[j]
                    if best is None or s < best:
                        best, cands = s, [j]
                    elif s == best:
                        cands.append(j)
                return self._pick_min_py(cands, rng)
            idx = self.topology.local_place_indices(core)
        return self._best_from_indices(idx, cost=cost, rng=rng, load=load,
                                       penalty=penalty, score_fn=score_fn)

    def local_search_cost(self, core: int, rng) -> ExecutionPlace:
        """Positional fast form of ``local_search(core, cost=True,
        rng=rng)`` — the per-dequeue LOW placement call (same ops/order)."""
        js = self._local_js[core]
        if js is None:
            js = self._local_js[core] = \
                self.topology.local_place_indices(core).tolist()
        if len(js) <= _PY_SEARCH_MAX:
            vl = self._costs_l
            best = None
            cands = None
            for j in js:
                s = vl[j]
                if best is None or s < best:
                    best, cands = s, [j]
                elif s == best:
                    cands.append(j)
            return self._pick_min_py(cands, rng)
        return self._best_from_indices(
            self.topology.local_place_indices(core), cost=True, rng=rng)

    def global_search(self, *, cost: bool, rng=None,
                      idx: Optional[np.ndarray] = None,
                      load: Optional[np.ndarray] = None,
                      penalty: float = 0.0,
                      score_fn: Optional[Callable] = None) -> ExecutionPlace:
        """Paper: sweep all execution places in the system.  ``idx``
        restricts the sweep to those place indices (a revoked-capacity
        live view); None sweeps everything, exactly as before."""
        if idx is None and score_fn is None and (
                load is None or penalty <= 0.0):
            js = self._all_idx_l
            if len(js) <= _PY_SEARCH_MAX:
                # inlined small-n no-load loop (identical ops/order to the
                # generic _best_from_indices python branch)
                vl = self._costs_l if cost else self._vals_l
                best = None
                cands = None
                for j in js:
                    s = vl[j]
                    if best is None or s < best:
                        best, cands = s, [j]
                    elif s == best:
                        cands.append(j)
                return self._pick_min_py(cands, rng)
        return self._best_from_indices(idx, cost=cost, rng=rng,
                                       load=load, penalty=penalty,
                                       score_fn=score_fn)

    def width1_search(self, *, cost: bool = False, rng=None,
                      idx: Optional[np.ndarray] = None,
                      load: Optional[np.ndarray] = None,
                      penalty: float = 0.0,
                      score_fn: Optional[Callable] = None) -> ExecutionPlace:
        """Global sweep restricted to width-1 places (the DA scheduler).
        ``idx``, when given, must already be a width-1 subset (e.g. a
        live view's ``width1_idx``); None uses every width-1 place."""
        return self._best_from_indices(
            self.topology.width1_place_indices if idx is None else idx,
            cost=cost, rng=rng, load=load, penalty=penalty,
            score_fn=score_fn)

    def stalest(self, idx: Optional[np.ndarray] = None, *,
                rng=None) -> ExecutionPlace:
        """The least-recently-*updated* candidate (never-updated entries are
        stalest of all) — the forced-revisit pick for the explore-exploit
        escape hatch.  A poisoned entry (one bad measurement, then shunned
        by every argmin forever) is exactly the entry whose update tick
        stops advancing, so it is what this returns.  Ties prefer narrower
        places, then break uniformly at random, like the searches."""
        n = len(self._all_idx_l) if idx is None else len(idx)
        if n <= _PY_SEARCH_MAX:
            vl = self._lu_l
            js = self._all_idx_l if idx is None else idx.tolist()
            best = None
            cands = None
            for j in js:
                s = vl[j]
                if best is None or s < best:
                    best, cands = s, [j]
                elif s == best:
                    cands.append(j)
            return self._pick_min_py(cands, rng)
        if self._np_dirty:
            self._flush_np()
        ages = self._lu_place if idx is None else self._lu_place[idx]
        w = self._wf if idx is None else self._wf[idx]
        return self._pick_min(ages, w, idx, rng)

    def snapshot(self) -> np.ndarray:
        if self._np_dirty:
            self._flush_np()
        return self.table.copy()


class PTTBank:
    """One PTT per task type (paper: 'one table is instantiated for each
    task type')."""

    def __init__(self, topology: Topology, **ptt_kwargs):
        self.topology = topology
        self.ptt_kwargs = ptt_kwargs
        self._tables: dict[str, PTT] = {}
        self._lock = threading.Lock()

    def for_type(self, task_type_name: str) -> PTT:
        tbl = self._tables.get(task_type_name)    # lock-free hot path:
        if tbl is not None:                       # dict reads are atomic
            return tbl
        with self._lock:
            tbl = self._tables.get(task_type_name)
            if tbl is None:
                tbl = self._tables[task_type_name] = PTT(
                    self.topology, **self.ptt_kwargs)
            return tbl

    def __iter__(self):
        return iter(self._tables.items())
