"""Performance Trace Table (paper §4.1.1).

One PTT per *task type*.  Entries are indexed by execution place
``(leader core, width)`` and hold a weighted moving average of observed
execution times as seen by the leader core:

    updated = (old_weight * old + new_weight * obs) / (old_weight + new_weight)

with the paper's recommended ratio 1:4 (``new_weight=1, old_weight=4``) so at
least three observations are needed before the entry tracks a new performance
regime.  Entries start at zero, which the schedulers interpret as
"unexplored — try me first", guaranteeing every place is evaluated at least
once early in the run (paper: "The entries are initialized to zero. This
ensures that all possible execution places are evaluated at least once").

The Algorithm-1 searches (``global_search`` / ``local_search`` /
``width1_search``) run as masked argmins over the dense table using the
topology's precomputed place-index arrays: unexplored (0.0) entries win
automatically, ties prefer narrower places, and residual ties break
*randomly* so equal predictions never pile onto the lowest core id.  This
keeps wake-time placement O(1)-ish numpy work instead of a Python loop over
every place per HIGH task.
"""
from __future__ import annotations

import threading
from typing import Iterable, Optional

import numpy as np

from .places import ExecutionPlace, Topology


class PTT:
    """Trace table for a single task type over a topology's places.

    Stored as a dense ``[n_cores, n_width_slots]`` float array (rows are
    per-core — the paper lays rows out to fit cache lines so each core
    touches its own line; we keep the same row-major layout).  Invalid
    (core, width) combinations hold NaN.
    """

    def __init__(self, topology: Topology, *, new_weight: float = 1.0,
                 old_weight: float = 4.0, first_visit_direct: bool = True):
        self.topology = topology
        self.new_weight = float(new_weight)
        self.old_weight = float(old_weight)
        self.first_visit_direct = first_visit_direct
        widths = sorted({w for p in topology.partitions for w in p.widths})
        self._w_slot = {w: i for i, w in enumerate(widths)}
        self.table = np.full((topology.n_cores, len(widths)), np.nan)
        self.visits = np.zeros_like(self.table, dtype=np.int64)
        # update-order tick per entry (-1 = never updated): staleness metric
        # for the forced-revisit escape hatch (see ``stalest``)
        self.last_update = np.full(self.table.shape, -1, dtype=np.int64)
        self._tick = 0
        for place in topology.places():
            self.table[place.leader, self._w_slot[place.width]] = 0.0
        self._lock = threading.Lock()

        # Vectorized-search metadata: for the i-th valid place, its flat
        # offset into ``table`` and its width (float).  ``_flat`` is a view,
        # so in-place ``update``s are immediately visible to the searches.
        self._places = topology.places()
        n_slots = len(widths)
        slots = np.array([self._w_slot[pl.width] for pl in self._places],
                         dtype=np.int64)
        self._pos = topology.place_leaders * n_slots + slots
        self._wf = topology.place_widths_f
        self._flat = self.table.reshape(-1)
        self._lu_flat = self.last_update.reshape(-1)

    # -- queries ------------------------------------------------------------
    def get(self, place: ExecutionPlace) -> float:
        """Predicted execution time; 0.0 means unexplored."""
        return float(self.table[place.leader, self._w_slot[place.width]])

    def visited(self, place: ExecutionPlace) -> int:
        return int(self.visits[place.leader, self._w_slot[place.width]])

    # -- updates ------------------------------------------------------------
    def update(self, place: ExecutionPlace, observed: float) -> float:
        """Weighted-average update, performed by the leader on task commit."""
        if observed < 0 or not np.isfinite(observed):
            raise ValueError(f"bad observation {observed!r}")
        r, c = place.leader, self._w_slot[place.width]
        with self._lock:
            old = self.table[r, c]
            if np.isnan(old):
                raise KeyError(f"invalid place {place}")
            if self.visits[r, c] == 0 and self.first_visit_direct:
                new = float(observed)
            else:
                new = (self.old_weight * old + self.new_weight * observed) / (
                    self.old_weight + self.new_weight)
            self.table[r, c] = new
            self.visits[r, c] += 1
            self.last_update[r, c] = self._tick
            self._tick += 1
            return new

    def prime(self, place: ExecutionPlace, value: float) -> bool:
        """Seed an *unexplored* entry with a prior estimate (PTT warmup
        without traffic).  Returns True if the entry was primed, False if
        it already holds a measurement (priming never overrides data).
        A primed entry does not count as visited: the first real
        observation still overwrites it directly (``first_visit_direct``)
        and ``stalest`` still treats it as never-measured — the prior is
        deliberately weak."""
        if value <= 0 or not np.isfinite(value):
            raise ValueError(f"bad prime value {value!r}")
        r, c = place.leader, self._w_slot[place.width]
        with self._lock:
            if np.isnan(self.table[r, c]):
                raise KeyError(f"invalid place {place}")
            if self.visits[r, c] == 0 and self.table[r, c] == 0.0:
                self.table[r, c] = float(value)
                return True
            return False

    # -- searches (Algorithm 1 primitives) ------------------------------------
    def _score(self, place: ExecutionPlace, *, cost: bool) -> tuple[float, float]:
        """Sort key: unexplored (0.0) places sort first, then by predicted
        time (or parallel cost = time*width).  Ties break toward narrower
        places (use fewer resources when indifferent)."""
        t = self.get(place)
        value = t * place.width if cost else t
        return (value, place.width)

    def best(self, places: Iterable[ExecutionPlace], *, cost: bool,
             rng=None) -> ExecutionPlace:
        """argmin with *random* final tie-break: equal predictions must not
        systematically pile decisions onto the lowest core id.

        Generic (any candidate iterable) Python path — the hot searches
        below use the vectorized ``_best_from_indices`` instead."""
        best_score, cands = None, []
        for pl in places:
            s = self._score(pl, cost=cost)
            if best_score is None or s < best_score:
                best_score, cands = s, [pl]
            elif s == best_score:
                cands.append(pl)
        if len(cands) > 1 and rng is not None:
            return cands[rng.randrange(len(cands))]
        return cands[0]

    def _gather(self, flat: np.ndarray, idx: Optional[np.ndarray]):
        """Per-candidate values + widths for place indices ``idx``
        (None = all valid places)."""
        if idx is None:
            return flat[self._pos], self._wf
        return flat[self._pos[idx]], self._wf[idx]

    def _pick_min(self, score: np.ndarray, w: np.ndarray,
                  idx: Optional[np.ndarray], rng) -> ExecutionPlace:
        """Shared argmin tail of every search: minimal score, ties prefer
        the narrowest width, residual ties break uniformly at random."""
        cands = np.flatnonzero(score == score.min())
        if len(cands) > 1:
            wt = w[cands]
            cands = cands[wt == wt.min()]
        if len(cands) == 1 or rng is None:
            k = cands[0]
        else:
            k = cands[rng.randrange(len(cands))]
        return self._places[int(k) if idx is None else int(idx[int(k)])]

    def _best_from_indices(self, idx: Optional[np.ndarray], *, cost: bool,
                           rng=None, load: Optional[np.ndarray] = None,
                           penalty: float = 0.0) -> ExecutionPlace:
        """Masked argmin over the dense table restricted to place indices
        ``idx`` (None = all valid places).  Semantics identical to ``best``
        over the same candidates in the same order: unexplored entries (0.0)
        sort first, ties prefer the narrowest width, residual ties are
        broken uniformly at random.

        ``load`` (aligned with the full place list) makes the search
        queue-aware: the score becomes ``ptt + penalty * load[place]``, so
        concurrent wakes spread over places instead of herding onto the
        current argmin.  ``load=None`` (the default) is the exact
        pre-load-awareness code path."""
        vals, w = self._gather(self._flat, idx)
        score = vals * w if cost else vals
        if load is not None and penalty > 0.0:
            score = score + penalty * (load if idx is None else load[idx])
        return self._pick_min(score, w, idx, rng)

    def local_search(self, core: int, *, cost: bool = True, rng=None,
                     load: Optional[np.ndarray] = None,
                     penalty: float = 0.0,
                     idx: Optional[np.ndarray] = None) -> ExecutionPlace:
        """Paper: keep partition+core fixed, mold only the width.  ``idx``
        overrides the candidate set (a live-masked subset of the core's
        local places under sub-pod revocation); None is the exact
        unmasked path."""
        return self._best_from_indices(
            self.topology.local_place_indices(core) if idx is None else idx,
            cost=cost, rng=rng, load=load, penalty=penalty)

    def global_search(self, *, cost: bool, rng=None,
                      idx: Optional[np.ndarray] = None,
                      load: Optional[np.ndarray] = None,
                      penalty: float = 0.0) -> ExecutionPlace:
        """Paper: sweep all execution places in the system.  ``idx``
        restricts the sweep to those place indices (a revoked-capacity
        live view); None sweeps everything, exactly as before."""
        return self._best_from_indices(idx, cost=cost, rng=rng,
                                       load=load, penalty=penalty)

    def width1_search(self, *, cost: bool = False, rng=None,
                      idx: Optional[np.ndarray] = None,
                      load: Optional[np.ndarray] = None,
                      penalty: float = 0.0) -> ExecutionPlace:
        """Global sweep restricted to width-1 places (the DA scheduler).
        ``idx``, when given, must already be a width-1 subset (e.g. a
        live view's ``width1_idx``); None uses every width-1 place."""
        return self._best_from_indices(
            self.topology.width1_place_indices if idx is None else idx,
            cost=cost, rng=rng, load=load, penalty=penalty)

    def stalest(self, idx: Optional[np.ndarray] = None, *,
                rng=None) -> ExecutionPlace:
        """The least-recently-*updated* candidate (never-updated entries are
        stalest of all) — the forced-revisit pick for the explore-exploit
        escape hatch.  A poisoned entry (one bad measurement, then shunned
        by every argmin forever) is exactly the entry whose update tick
        stops advancing, so it is what this returns.  Ties prefer narrower
        places, then break uniformly at random, like the searches."""
        ages, w = self._gather(self._lu_flat, idx)
        return self._pick_min(ages, w, idx, rng)

    def snapshot(self) -> np.ndarray:
        return self.table.copy()


class PTTBank:
    """One PTT per task type (paper: 'one table is instantiated for each
    task type')."""

    def __init__(self, topology: Topology, **ptt_kwargs):
        self.topology = topology
        self.ptt_kwargs = ptt_kwargs
        self._tables: dict[str, PTT] = {}
        self._lock = threading.Lock()

    def for_type(self, task_type_name: str) -> PTT:
        with self._lock:
            tbl = self._tables.get(task_type_name)
            if tbl is None:
                tbl = self._tables[task_type_name] = PTT(
                    self.topology, **self.ptt_kwargs)
            return tbl

    def __iter__(self):
        return iter(self._tables.items())
