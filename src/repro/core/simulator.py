"""Discrete-event simulator of the XiTAO-style runtime (paper §4.1.2).

Faithfully models the scheduler-visible machinery:

* per-core Work Stealing Queue (WSQ, owner LIFO / thief FIFO) holding ready
  tasks, and a FIFO Assembly Queue (AQ) holding placed tasks; a molded task's
  pointer is inserted into *all* member AQs atomically and starts when every
  member reaches it (paper Fig. 3 steps 1-7);
* binding placement of HIGH tasks at wake time, re-run of the local width
  search after a steal (steps 4-5), PTT update by the leader on commit
  (step 8) with multiplicative measurement noise;
* dynamic asymmetry: per-core piecewise-constant speed profiles (DVFS) and
  co-running background apps that time-share their pinned cores and pressure
  the partition's shared memory bandwidth.

Progress integration uses piecewise-constant rates: every event (task
start/finish, speed breakpoint, background episode edge) re-derives each
*affected* running task's rate

    rate = min_{c in place} speed(c,t)/share(c) * min(1, bw_cap/bw_demand)^s

and re-schedules versioned completion events.  All randomness is seeded.

One scheduling kernel, two engines
----------------------------------
Queue structure and lifecycle decisions live in the engine-agnostic
kernel shared with the threaded runtime: split HIGH-FIFO/LOW-LIFO WSQs,
assembly queues, priority-aware dequeue, O(cores) steal-victim selection
with seeded tie-breaks (``core/queues.py``), and the wake → place →
dequeue/steal-with-re-search → commit → PTT-feedback state machine
(``core/lifecycle.py``, parameterized over this simulator's virtual
clock).  This module is the *discrete-event driver* over that kernel:
everything below is about integrating task progress through
piecewise-constant rates as fast as possible.

Incremental-dispatch architecture (the hot path)
------------------------------------------------
The original engine re-ran a shuffled fixpoint over *all* cores after every
event and re-scanned whole queues per decision; the machinery below keeps
scheduler-visible behavior but does O(changed state) work per event:

* **Split WSQs** — each core's WSQ is a HIGH-FIFO + LOW-LIFO deque pair
  (``queues.SplitWSQ``).  Priority dequeue ("serve the oldest HIGH first,
  newest LOW otherwise") and steal ("oldest stealable first") become O(1)
  pops instead of O(queue) scans.  Priority-oblivious schedulers (RWS
  family) route all tasks through the LOW deque, preserving their plain
  mixed-LIFO order.
* **O(cores) victim selection** — the steal heuristic "victim with the most
  stealable tasks, random tie-break" reads per-queue lengths instead of
  counting matching tasks per victim (the seed engine's dominant cost:
  O(cores x queue length) ``may_steal`` scans per steal attempt).
* **Idle-core worklist** — ``_dispatch`` drains a dirty-set of cores whose
  state changed since the last event (work pushed, task placed, member core
  freed) in shuffled rounds mirroring the old two-phase (local, then steal)
  fixpoint.  Cores that find neither local work nor a steal victim park in
  a *starving* set and are only re-woken when stealable work appears.
* **Dirty-flag rate refresh** — per-core effective speeds (DVFS x
  background time-sharing) are cached and recomputed only at speed/bg
  breakpoints; partition bandwidth demand is maintained incrementally on
  task start/commit.  ``_refresh_rates`` touches only tasks whose inputs
  changed: all of them after a speed/bg event, bandwidth-sensitive tasks in
  dirtied domains after demand shifts, and freshly started tasks otherwise.
* **Vectorized rate refresh** — when a refresh touches many running tasks
  at once (wide topologies such as ``tx2_xl(8+)`` / ``haswell_cluster``
  with hundreds of cores), the per-task Python loop switches to a numpy
  pass over the running-task rate vector: gathered per-leader speeds,
  per-bandwidth-key slowdown factors, and a vectorized changed-rate mask
  so only tasks whose rate actually moved re-enter the event queue.  Both
  paths perform the identical float64 operations, so results are
  bit-for-bit the same whichever one runs (``_VEC_MIN`` sets the
  crossover).
* **Lazy-deletion event-queue compaction** — every rate change makes the
  task's previously scheduled finish event stale (versioned events; stale
  ones are skipped on pop).  On bandwidth-heavy workloads rates change at
  nearly every event, so stale entries can dominate the heap.  The engine
  counts outstanding stale events and, when they exceed
  ``_COMPACT_MIN_STALE`` *and* half the heap, rebuilds the heap keeping
  only live events (O(heap) re-heapify, amortized O(1) per push).  Pop
  order of surviving events is untouched — the (t, seq) key is a total
  order — so compaction is behavior-invisible; ``heap_peak`` records the
  high-water mark for tests and diagnostics.

Preemptible capacity (pod-slice revocation)
-------------------------------------------
An optional :class:`~.preemption.PreemptionModel` attaches seeded
partition-granular revoke/restore episodes.  At a **revoke** edge the
engine (in order):

1. marks the partition's cores down (they leave the dispatch worklist and
   the starving set; the scheduler receives the interned
   :class:`~.places.LiveView` so every wake-time search is restricted to
   surviving places);
2. preempts the partition's *running* tasks — ``preempt="restart"``
   discards their progress, ``"checkpoint"`` folds the completed fraction
   into ``task.resume_frac`` and charges ``resume_penalty`` extra work at
   the next start — releasing their cores, bandwidth demand and finish
   events (which turn stale, feeding the compaction accounting);
3. drains the partition's AQs (placed-but-unstarted tasks lose their
   place but no progress) and WSQs back to the scheduler;
4. re-places every displaced task on the surviving partitions — **HIGH
   tasks first** (running, then AQ, then WSQ order within each class), so
   criticality-aware schedulers immediately re-bind the critical path
   while RWS-family schedulers scatter, which is exactly the behavioral
   difference the preemption benchmarks measure.

At a **restore** edge the cores re-enter the dispatch loop and steal
their way back to work.  With no model attached every preemption code
path is behind a ``None``/flag check and runs are bit-identical to
builds without the subsystem (pinned against the golden schedules).

Decision *distributions* (victim tie-breaks, core processing order) are
unchanged, but the RNG draw sequence differs from the pre-refactor engine,
so seeded runs are statistically — not bit-for-bit — identical to it;
``tests/test_golden_schedule.py`` pins the current behavior.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from typing import Iterable, Optional

import numpy as np

from .dag import DAG
from .faults import FaultModel, FaultState, RecoveryPolicy
from .interference import BackgroundApp, SpeedProfile, SpeedProfileBase
from .lifecycle import split_by_priority
from .metrics import RunMetrics, TaskRecord
from .places import ExecutionPlace
from .preemption import PreemptionModel
from .queues import BatchingConfig
from .schedulers import Scheduler
from .shards import ShardingSpec, make_control_plane
from .task import PARTITION_BW, Priority, Task

_EPS = 1e-12
_NO_DEMAND = (0.0, 0)
# refresh batches at least this large take the numpy path (see module
# docstring); below it the plain Python loop is faster (tx2-class runs
# rarely have more than ~6 running tasks)
_VEC_MIN = 32
# compact the event heap when stale entries exceed this count AND this
# fraction of the heap (hysteresis: small runs never pay the rebuild).
# Both are Simulator kwargs; these module constants are the defaults.
_COMPACT_MIN_STALE = 64
_COMPACT_HEAP_FRAC = 0.5


class _Running:
    __slots__ = ("task", "place", "remaining", "rate", "base", "version",
                 "cores", "domain", "mem_s", "cap", "bw_contrib", "bwkey",
                 "work_assigned", "fault", "slow_mult", "token")

    def __init__(self, task: Task, place: ExecutionPlace, remaining: float,
                 domain: str, cap: float, bwkey: int):
        self.task = task
        self.place = place
        self.remaining = remaining  # work-seconds left at rate 1.0
        self.work_assigned = remaining  # assignment size (for checkpoints)
        self.rate = -1.0            # <0 = not yet scheduled a finish event
        self.base = -1.0            # min core speed over place (pre-bw rate)
        self.version = 0
        self.cores = place.cores
        self.domain = domain
        self.mem_s = task.type.mem_sensitivity
        self.cap = cap
        self.bw_contrib = task.type.bw_demand * place.width
        self.bwkey = bwkey          # interned (domain, cap, mem_s) id; -1 = bw-insensitive
        # fault-injection state (see ``core/faults.py``): the armed fault
        # for this execution (``remaining`` is truncated to its strike
        # point so the strike is an ordinary finish event), the fail-slow
        # rate multiplier in force, and the straggle-event guard token
        self.fault = None
        self.slow_mult = 1.0
        self.token = 0


class Simulator:
    def __init__(self, scheduler: Scheduler, *,
                 speed: Optional[SpeedProfileBase] = None,
                 background: Iterable[BackgroundApp] = (),
                 preemption: Optional[PreemptionModel] = None,
                 faults: Optional[FaultModel] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 sharding: Optional[ShardingSpec] = None,
                 batching: Optional[BatchingConfig] = None,
                 reshard_at: Iterable[tuple[float, int]] = (),
                 horizon: float = 1e6,
                 event_mode: str = "cohort",
                 compact_min_stale: int = _COMPACT_MIN_STALE,
                 compact_heap_frac: float = _COMPACT_HEAP_FRAC):
        if event_mode not in ("cohort", "scalar"):
            raise ValueError(f"unknown event_mode {event_mode!r} "
                             "(expected 'cohort' or 'scalar')")
        if compact_min_stale < 0:
            raise ValueError(f"compact_min_stale {compact_min_stale!r} < 0")
        if not 0.0 < compact_heap_frac <= 1.0:
            raise ValueError(f"compact_heap_frac {compact_heap_frac!r} "
                             "outside (0, 1]")
        self.event_mode = event_mode
        self.sched = scheduler
        self.topo = scheduler.topology
        self.rng = scheduler.rng
        self.speed = speed or SpeedProfile(self.topo.n_cores)
        self.background = list(background)
        self.preemption = preemption
        self.sharding = sharding
        self.horizon = horizon

        n = self.topo.n_cores
        # the control plane: the engine-agnostic scheduling kernel (split
        # WSQs + AQs, steal policy, wake/requeue placement, PTT feedback —
        # shared with the threaded runtime, see core/lifecycle.py), or N
        # of them behind the sharded plane (core/shards.py).  Groupings
        # that yield one shard *are* the flat kernel (the equivalence pin).
        self.kernel = make_control_plane(scheduler, now=lambda: self.now,
                                         sharding=sharding)
        self.queues = self.kernel.queues
        # modeled scheduler overhead: each shard (1 for the flat kernel)
        # is a single-server decision queue — wakes serialize through it
        # at ``decision_s`` apiece.  Zero cost skips the event machinery
        # entirely (the exact pre-overhead path, bit-identical).
        self._n_shards = getattr(self.kernel, "n_shards", 1)
        self._decision_s = sharding.decision_s if sharding is not None else 0.0
        if self._decision_s > 0.0:
            self._shard_of = (self.kernel.shard_of_core
                              if self._n_shards > 1 else [0] * n)
            self._shard_free = [0.0] * self._n_shards
            self._decide_depth = [0] * self._n_shards
            if self._n_shards > 1:
                # expose the decision-server backlog to the plane so the
                # overflow/rebalance logic can see the modeled bottleneck
                self.kernel.decision_backlog = (
                    lambda s: self._decide_depth[s] * self._decision_s)
        # continuous batching: a max_batch=1 config is the disabled path
        # by definition (the degeneracy pin), so normalize it to None —
        # every batching branch below then stays dead code
        if batching is not None and not batching.enabled:
            batching = None
        if batching is not None and faults is not None and faults.enabled:
            raise ValueError("continuous batching with fault injection is "
                             "not supported: a batched dispatch has no "
                             "per-member retry semantics")
        self._batching = batching
        self.kernel.batching = batching
        # online re-sharding events: (t, pods_per_shard), applied in event
        # order (sharded control plane only; see _reshard)
        self._reshard_at = tuple(sorted(reshard_at))
        if self._reshard_at and self._n_shards <= 1:
            raise ValueError("reshard_at requires a sharded control plane")
        self._pend = itertools.count()
        self._pending_decide: dict[int, tuple[Task, int]] = {}
        self._pending_migrate: dict[int, tuple[Task, int]] = {}
        self.aq: list[deque[_Running]] = self.queues.aq
        self.core_busy: list[Optional[_Running]] = [None] * n
        self.running: dict[int, _Running] = {}
        self.now = 0.0
        self._seq = itertools.count()
        self._events: list[tuple] = []   # (t, seq, kind, tid, version)
        self._done = 0
        self._outstanding = 0
        self.metrics = RunMetrics(n_cores=n)

        # incremental-dispatch state: every core starts on the worklist (the
        # first round parks workless cores in the starving set, after which
        # only state changes re-queue them)
        self._dirty: set[int] = set(range(n))
        self._starving: set[int] = set()    # idle cores out of steal targets

        # dirty-flag rate-refresh state
        self._fresh: list[_Running] = []    # started since last refresh
        self._dirty_domains: set[str] = set()
        self._rates_global_dirty = False
        self._demand: dict[str, tuple[float, int]] = {}  # foreground bw
        self._speed_now = [self.speed.speed(c, 0.0) for c in range(n)]
        self._bg_mult = [1.0] * n
        self._bg_demand: dict[str, tuple[float, int]] = {}
        self._core_speed = list(self._speed_now)
        self._core_speed_arr: Optional[np.ndarray] = None  # lazy np mirror
        self._vec_min = _VEC_MIN

        # bandwidth-key interning for the vectorized refresh: one id per
        # distinct (domain, cap, mem_sensitivity) combination seen
        self._bwkey_id: dict[tuple, int] = {}
        self._bwkeys: list[tuple] = []
        # Last *applied* bandwidth factor per interned key (NaN = never
        # applied) + per-domain key registry: a dirty domain only rescans
        # the running set when some key's recomputed factor actually moved
        # (an unchanged factor recomputes a bitwise-equal rate, which the
        # _EPS change test always rejects — so skipping the scan is
        # state-identical).  Every branch that applies factors writes the
        # cache back, keeping the invariant inductive.
        self._key_factor: list[float] = []
        self._dom_bwkeys: dict[str, list[int]] = {}
        # Domains with any applied factor != 1.0.  A demand *decrease* in a
        # cool domain provably keeps every factor at 1.0 (dem shrinks, cap
        # grows as streams drop), so those sites skip the dirty-domain mark
        # entirely; increases always mark.  Conservative: factor appliers
        # add domains eagerly, only the full dirty-domain sweep removes.
        self._hot_doms: set[str] = set()

        # lazy-deletion event-queue state
        self._stale = 0                     # outstanding dead finish events
        self._compact_min_stale = compact_min_stale
        self._compact_heap_frac = compact_heap_frac
        self.heap_peak = 0                  # high-water mark of the heap
        self.compactions = 0

        # preemptible-capacity state (inert without a PreemptionModel);
        # core-granular — a sub-pod episode revokes a subset of its
        # partition's cores and leaves the siblings dispatching
        self._core_up = [True] * n
        self._down_cores: set[int] = set()
        self._ckpt = (preemption is not None
                      and preemption.preempt == "checkpoint")
        self._resume_penalty = (preemption.resume_penalty
                                if preemption is not None else 0.0)
        self.preempt_events = 0             # revoke edges applied
        self.tasks_preempted = 0            # task executions cut short
        self.work_lost = 0.0                # discarded progress (work-s)

        # fault-injection state (inert without an *enabled* FaultModel — a
        # zero-probability model is normalized away here, so attaching one
        # is literally the None path; the golden pins check this)
        if faults is not None and not faults.enabled:
            faults = None
        self.faults = faults
        self._fx = (FaultState(faults, recovery or RecoveryPolicy())
                    if faults is not None else None)
        self._pending_retry: dict[int, Task] = {}   # tid -> task in backoff
        self._notice_token: dict[int, int] = {}     # eidx -> live notice event
        self._tok = itertools.count(1)              # straggle/notice guards

        # load-coupled speed profiles (e.g. a power governor that detunes
        # harder on loaded partitions, ``interference.LoadCoupledGovernor``)
        # are fed per-partition busy-core counts before every rate refresh;
        # a profile without the hook costs one getattr at construction
        self._load_coupled = bool(getattr(self.speed, "load_coupled", False))
        if self._load_coupled:
            self._pidx_of = [0] * n
            for pidx, part in enumerate(self.topo.partitions):
                for c in part.cores:
                    self._pidx_of[c] = pidx

        # hot-path bindings.  With the flat (unsharded) kernel the wake and
        # commit plumbing — timestamp stamping, measurement-noise draws,
        # PTT feedback routing — is inlined into _wake/_commit below; every
        # *decision* (placement searches, tie-breaks, EMA folding) still
        # runs in scheduler/PTT code, and the draws are made in the same
        # order from the same streams, so results are bit-identical to the
        # generic kernel calls the sharded plane keeps using.
        self._flat = self._n_shards == 1
        self._track_load = self.kernel.track_load if self._flat else True
        self._inline_choose = self._flat and not self._track_load
        self._choose_place = (scheduler.place_on_dequeue if self._inline_choose
                              else self.kernel.choose_place)
        self._ptt_bank = scheduler.ptt
        self._ptt_for: dict = {}    # type name -> PTT (same objects as bank)
        self._rec_append = self.metrics.records.append
        # _dispatch's working set, bound once (all are init-only objects
        # mutated in place, never rebound)
        self._disp_binds = (self._dirty, self.core_busy, self.aq,
                            self.queues.wsq, self._core_up, self._starving,
                            self.rng)
        # per-leader (domain, bw cap, partition kind) — one tuple per
        # leader core, resolved lazily at first placement
        self._leader_info: list = [None] * n
        self._recompute_bg()

    # ------------------------------------------------------------------ util
    def _push_event(self, t: float, kind: str, tid: int = -1, version: int = -1):
        events = self._events
        heapq.heappush(events, (t, next(self._seq), kind, tid, version))
        if len(events) > self.heap_peak:
            self.heap_peak = len(events)

    def _maybe_compact(self):
        """Rebuild the heap without stale finish events once they dominate.
        Surviving events keep their (t, seq) keys — a total order — so pop
        order (and therefore every simulation result) is unchanged.  The
        trigger thresholds are the ``compact_min_stale`` /
        ``compact_heap_frac`` constructor kwargs; at the defaults (64,
        0.5) this is the exact historical stale>64-and-half-the-heap
        condition."""
        if (self._stale <= self._compact_min_stale
                or self._stale <= self._compact_heap_frac
                * len(self._events)):
            return
        running = self.running
        live = []
        for ev in self._events:
            if ev[2] == "finish":
                rec = running.get(ev[3])
                if rec is None or rec.version != ev[4]:
                    continue
            live.append(ev)
        heapq.heapify(live)
        # in-place so the run loop's local alias of ``self._events`` stays valid
        self._events[:] = live
        self._stale = 0
        self.compactions += 1

    def _recompute_speed(self):
        """Re-derive cached per-core DVFS speeds (on a speed breakpoint)."""
        self._speed_now = self.speed.speeds_at(self.now)
        self._update_core_speed()
        self._rates_global_dirty = True

    def _recompute_bg(self):
        """Re-derive background co-runner state (on an episode boundary):
        per-core time-share/thrash multipliers and per-domain bandwidth
        demand contributed by active background apps."""
        n = self.topo.n_cores
        n_bg = [0] * n
        thrash = [0.0] * n
        bg_demand: dict[str, tuple[float, int]] = {}
        now = self.now
        for b in self.background:
            if not b.active(now):
                continue
            for c in b.cores:
                n_bg[c] += 1
                if b.thrash > thrash[c]:
                    thrash[c] = b.thrash
            if b.task_type.bw_demand > 0:
                for c in b.cores:
                    dom = self.topo.partition_of(c).domain
                    d, k = bg_demand.get(dom, _NO_DEMAND)
                    bg_demand[dom] = (d + b.task_type.bw_demand, k + 1)
        self._bg_mult = [
            (1.0 - thrash[c]) / (1 + n_bg[c]) if n_bg[c] else 1.0
            for c in range(n)]
        self._bg_demand = bg_demand
        self._update_core_speed()
        self._rates_global_dirty = True

    def _update_core_speed(self):
        self._core_speed = [s * m for s, m in
                            zip(self._speed_now, self._bg_mult)]
        self._core_speed_arr = None          # np mirror rebuilt on demand

    def _bw_factor(self, key: tuple) -> float:
        """Bandwidth-share slowdown for one (domain, cap, sensitivity)
        combination under the current foreground + background demand."""
        dom, cap0, s = key
        dem, streams = self._demand.get(dom, _NO_DEMAND)
        bd = self._bg_demand.get(dom)
        if bd is not None:
            dem += bd[0]
            streams += bd[1]
        if streams > 1:     # same doubles as max(0.6, 1 - .08*max(0, n-1))
            red = 1.0 - 0.08 * (streams - 1)
            cap = cap0 * (red if red > 0.6 else 0.6)
        else:
            cap = cap0
        return (cap / dem) ** s if dem > cap else 1.0

    def _refresh_rates(self):
        """Re-derive rates + reschedule finishes for tasks whose inputs
        changed since the last event (see module docstring)."""
        if self._load_coupled:
            busy = [0] * len(self.topo.partitions)
            pidx_of = self._pidx_of
            for c, rec in enumerate(self.core_busy):
                if rec is not None:
                    busy[pidx_of[c]] += 1
            if self.speed.set_busy(busy):
                # partition occupancy moved -> the governor's detune factor
                # moved -> every cached core speed is stale
                self._recompute_speed()
        dd_dom = None   # last domain swept below; lets the fresh fast
        #                 path reuse the factor just written to _key_factor
        if self._rates_global_dirty:
            recs = list(self.running.values())
        elif self._dirty_domains:
            # Recompute the factor of every key registered under a dirty
            # domain; only keys whose factor *moved* force a rescan (an
            # unchanged factor reproduces each rec's rate bitwise, so the
            # change test below would reject every one of them anyway —
            # the dominant unsaturated-domain case costs one pow per key
            # instead of a scan over the running set).
            dd = self._dirty_domains
            kf = self._key_factor
            dbk = self._dom_bwkeys
            bwkeys = self._bwkeys
            hot = self._hot_doms
            demand = self._demand
            bg_demand = self._bg_demand
            changed = None
            for dom in dd:
                keys = dbk.get(dom)
                if keys is None:
                    continue
                # _bw_factor inlined with the per-domain demand state
                # hoisted out of the per-key loop (same doubles)
                dem, streams = demand.get(dom, _NO_DEMAND)
                bd = bg_demand.get(dom)
                if bd is not None:
                    dem += bd[0]
                    streams += bd[1]
                if streams > 1:
                    red = 1.0 - 0.08 * (streams - 1)
                    if red < 0.6:
                        red = 0.6
                else:
                    red = 1.0
                all_one = True
                for k in keys:
                    key = bwkeys[k]
                    cap = key[1] * red
                    f = (cap / dem) ** key[2] if dem > cap else 1.0
                    if f != 1.0:
                        all_one = False
                    if f != kf[k]:
                        kf[k] = f
                        if changed is None:
                            changed = {k}
                        else:
                            changed.add(k)
                if all_one:
                    hot.discard(dom)
                else:
                    hot.add(dom)
                dd_dom = dom
            dd.clear()
            if changed is not None:
                recs = [r for r in self.running.values()
                        if r.rate < 0.0 or r.bwkey in changed]
            elif self._fresh:
                recs = None     # factors still; only fresh recs need rates
            else:
                return
        elif self._fresh:
            recs = None
        else:
            return
        if recs is None:
            fresh = self._fresh
            if len(fresh) == 1:
                # dominant case — one commit freed one place, dispatch
                # started one task.  Same float ops as the generic path
                # below, minus the batch plumbing.
                rec = fresh[0]
                fresh.clear()
                if self.running.get(rec.task.tid) is not rec:
                    return
                cs = self._core_speed
                cores = rec.cores
                rec.base = cs[cores[0]] if len(cores) == 1 else \
                    min(cs[c] for c in cores)
                rate = rec.base
                k = rec.bwkey
                if k >= 0 and rec.domain == dd_dom:
                    # this rec's domain was swept just above and no factor
                    # moved (changed is None), so _key_factor[k] already
                    # holds the exact double the inline recompute below
                    # would produce — reuse it and skip the pow
                    f = self._key_factor[k]
                    if f != 1.0:
                        rate *= f
                elif k >= 0:
                    # _bw_factor inlined (same doubles)
                    dom = rec.domain
                    dem, streams = self._demand.get(dom, _NO_DEMAND)
                    bd = self._bg_demand.get(dom)
                    if bd is not None:
                        dem += bd[0]
                        streams += bd[1]
                    if streams > 1:
                        red = 1.0 - 0.08 * (streams - 1)
                        cap = rec.cap * (red if red > 0.6 else 0.6)
                    else:
                        cap = rec.cap
                    if dem > cap:
                        f = (cap / dem) ** rec.mem_s
                        self._key_factor[k] = f
                        self._hot_doms.add(dom)
                        if f != 1.0:
                            rate *= f
                    else:
                        self._key_factor[k] = 1.0
                if rec.slow_mult != 1.0:
                    rate *= rec.slow_mult
                if rate < 1e-9:
                    rate = 1e-9
                # a fresh rec always has rate < 0: push unconditionally
                rec.rate = rate
                rec.version += 1
                events = self._events
                heapq.heappush(
                    events, (self.now + rec.remaining / rate,
                             next(self._seq), "finish", rec.task.tid,
                             rec.version))
                if len(events) > self.heap_peak:
                    self.heap_peak = len(events)
                return
            # defensive: a rec that started and was then killed/preempted
            # before this refresh would push a finish event that corrupts
            # the stale accounting.  Both event loops refresh immediately
            # after dispatching each live event, so the identity check
            # always passes today; it guards future refresh deferral.
            running = self.running
            recs = [r for r in fresh if running.get(r.task.tid) is r]
            if not recs:
                self._fresh.clear()
                return
        if len(recs) >= self._vec_min:
            self._refresh_rates_np(recs)
        else:
            self._refresh_rates_py(recs)
        self._fresh.clear()
        self._dirty_domains.clear()
        self._rates_global_dirty = False

    def _refresh_rates_py(self, recs: list[_Running]):
        """Per-task Python path (small refresh batches).  ``rec.bwkey >= 0``
        is exactly ``rec.mem_s > 0`` (the placement interning invariant),
        so the shared-slowdown memo keys on the interned int."""
        cs = self._core_speed
        now = self.now
        factors: dict = {}      # bwkey id -> slowdown
        bwkeys = self._bwkeys
        kf = self._key_factor
        global_dirty = self._rates_global_dirty
        events = self._events
        seq = self._seq
        heappush = heapq.heappush
        eps = _EPS
        for rec in recs:
            # the min-over-member-cores speed only moves on speed/bg events
            # (global dirty) — demand-only refreshes reuse the cached value
            if global_dirty or rec.base < 0.0:
                cores = rec.cores
                rec.base = rate = cs[cores[0]] if len(cores) == 1 else \
                    min(cs[c] for c in cores)
            else:
                rate = rec.base
            k = rec.bwkey
            if k >= 0:
                f = factors.get(k)
                if f is None:
                    f = factors[k] = kf[k] = self._bw_factor(bwkeys[k])
                    if f != 1.0:
                        self._hot_doms.add(rec.domain)
                if f != 1.0:
                    rate *= f
            sm = rec.slow_mult
            if sm != 1.0:
                rate *= sm              # fail-slow degradation in force
            if rate < 1e-9:
                rate = 1e-9
            old = rec.rate
            if old < 0 or abs(rate - old) > eps * (rate if rate > old
                                                   else old):
                if old >= 0:
                    self._stale += 1     # previous finish event is now dead
                rec.rate = rate
                rec.version += 1
                heappush(events, (now + rec.remaining / rate, next(seq),
                                  "finish", rec.task.tid, rec.version))
        # high-water mark: the heap only grows inside the loop, so one
        # post-loop check sees the same maximum as a per-push check
        if len(events) > self.heap_peak:
            self.heap_peak = len(events)

    def _refresh_rates_np(self, recs: list[_Running]):
        """Vectorized path over the running-task rate vector.  Performs the
        same float64 operations as the Python path (gather/min for bases,
        one shared slowdown factor per bandwidth key, identical change
        test), so the two paths are bit-for-bit interchangeable."""
        n = len(recs)
        cs_list = self._core_speed
        cs = self._core_speed_arr
        if cs is None:
            cs = self._core_speed_arr = np.array(cs_list, dtype=np.float64)
        if self._rates_global_dirty:
            leaders = np.fromiter((r.cores[0] for r in recs), np.int64,
                                  count=n)
            base = cs[leaders]
            for i, rec in enumerate(recs):
                cores = rec.cores
                if len(cores) > 1:
                    base[i] = min(cs_list[c] for c in cores)
                rec.base = base[i]
        else:
            base = np.fromiter((r.base for r in recs), np.float64, count=n)
            for i in np.flatnonzero(base < 0.0):
                rec = recs[i]
                cores = rec.cores
                b = cs_list[cores[0]] if len(cores) == 1 else \
                    min(cs_list[c] for c in cores)
                rec.base = b
                base[i] = b
        rate = base                          # reuse; base is not read again
        if self._bwkeys:
            kid = np.fromiter((r.bwkey for r in recs), np.int64, count=n)
            sens = kid >= 0
            if sens.any():
                fmap = np.ones(len(self._bwkeys), dtype=np.float64)
                for u in np.unique(kid[sens]):
                    f = self._bw_factor(self._bwkeys[u])
                    fmap[u] = self._key_factor[u] = f
                    if f != 1.0:
                        self._hot_doms.add(self._bwkeys[u][0])
                # rate * 1.0 is an exact identity for positive floats, so
                # multiplying the insensitive lanes too changes nothing
                rate = rate * np.where(sens, fmap[np.maximum(kid, 0)], 1.0)
        if self._fx is not None:
            # fail-slow multipliers; x1.0 lanes are exact identities, so
            # this stays bit-for-bit interchangeable with the Python path
            rate = rate * np.fromiter((r.slow_mult for r in recs),
                                      np.float64, count=n)
        np.maximum(rate, 1e-9, out=rate)
        old = np.fromiter((r.rate for r in recs), np.float64, count=n)
        changed = (old < 0.0) | (np.abs(rate - old)
                                 > _EPS * np.maximum(rate, old))
        now = self.now
        push = self._push_event
        for i in np.flatnonzero(changed):
            rec = recs[i]
            if rec.rate >= 0:
                self._stale += 1             # previous finish event is now dead
            r = rate[i]
            rec.rate = r
            rec.version += 1
            push(now + rec.remaining / r, "finish", rec.task.tid, rec.version)

    def _advance(self, t: float):
        dt = t - self.now
        if dt <= 0:
            if dt < -1e-9 * max(1.0, abs(self.now)):
                raise RuntimeError(f"time went backwards: {self.now} -> {t}")
            return      # same instant (fp jitter)
        running = self.running
        if len(running) >= self._vec_min:
            # array path for wide topologies: the elementwise
            # ``remaining - (dt * rate)`` is the identical IEEE-754
            # operation pair as the scalar loop, so both paths are
            # bit-for-bit interchangeable (same contract as the
            # vectorized rate refresh)
            recs = list(running.values())
            n = len(recs)
            step = np.fromiter((r.rate for r in recs), np.float64, count=n)
            step *= dt
            rem = np.fromiter((r.remaining for r in recs), np.float64,
                              count=n)
            rem -= step
            for rec, v in zip(recs, rem.tolist()):
                rec.remaining = v
        else:
            for rec in running.values():
                rec.remaining -= dt * rec.rate
        self.now = t

    # ----------------------------------------------------------------- wake
    def _mark(self, core: int):
        self._dirty.add(core)
        self._starving.discard(core)

    def _enqueue(self, task: Task, core: int):
        """Push a ready task onto ``core``'s WSQ (shared by first wakes and
        preemption requeues — the outstanding count moves only on wake).
        ``WorkQueues.push`` is inlined (per-run-constant flags)."""
        queues = self.queues
        q = queues.wsq[core]
        if queues.route_high and task.priority == Priority.HIGH:
            q.high.append(task)
        else:
            q.low.append(task)
        if queues.track_load:
            queues.queued_s[core] += task.load_est
        self._dirty.add(core)
        self._starving.discard(core)
        # new stealable work re-opens the starving cores' steal loop —
        # only the receiving shard's cores when steal groups fence the
        # victim scans (a foreign starving core could never steal it)
        if self._starving and self.queues.stealable(task):
            groups = self.queues.groups
            if groups is None:
                self._dirty |= self._starving
                self._starving.clear()
            else:
                g = groups[core]
                woken = {c for c in self._starving if groups[c] == g}
                self._dirty |= woken
                self._starving -= woken

    def _wake(self, task: Task, waker_core: int):
        self._outstanding += 1
        if self._decision_s == 0.0:
            if self._flat:
                # inlined SchedulingKernel.wake (plumbing only; the
                # placement decision below is the same scheduler call)
                task.t_ready = self.now
                target = self.sched.place_on_wake(task, waker_core)
                core = waker_core if target is None else target
                if self._track_load:
                    self.kernel._stamp_load_est(task, core)
                self._enqueue(task, core)
            else:
                self._enqueue(task, self.kernel.wake(task, waker_core))
            return
        # modeled decision latency: the wake queues at its shard's
        # decision server and lands when the server gets to it
        s = self._shard_of[waker_core]
        t = max(self.now, self._shard_free[s]) + self._decision_s
        self._shard_free[s] = t
        self._decide_depth[s] += 1
        pid = next(self._pend)
        self._pending_decide[pid] = (task, waker_core, s)
        self._push_event(t, "decide", pid)

    def _decide(self, pid: int):
        """A queued wake decision completes: run the placement now (the
        waker may have been revoked inside the decision latency — fall
        back to the first live core; no RNG is drawn)."""
        task, waker, s = self._pending_decide.pop(pid)
        self._decide_depth[s] -= 1
        if not self._core_up[waker]:
            waker = self.kernel.live_cores()[0]
        self._enqueue(task, self.kernel.wake(task, waker))

    def _rebalance(self):
        """One rebalance round: plan + pop the migrating tasks now, land
        each after the round's decision latency + per-task migration
        cost.  Re-arms itself while the run still has outstanding work."""
        spec = self.sharding
        if self._outstanding > 0:
            lat = spec.rebalance_decision_s + spec.migration_s
            for task, dst in self.kernel.rebalancer.plan_round():
                pid = next(self._pend)
                self._pending_migrate[pid] = (task, dst)
                self._push_event(self.now + lat, "migrate", pid)
            self._push_event(self.now + spec.rebalance_period_s, "rebalance")

    def _migrate_land(self, pid: int):
        task, dst = self._pending_migrate.pop(pid)
        self._enqueue(task, self.kernel.migrate_in(task, dst))

    def _reshard(self, idx: int):
        """Apply one online re-sharding event: regroup the pods into new
        shards (:meth:`ShardedControlPlane.reshard`) and land the
        rebalancer's catch-up migration round immediately.  The plane
        mutates ``shard_of_core`` and the steal-group fences in place, so
        the decision-server binding and every queued reference stay
        valid."""
        _, pps = self._reshard_at[idx]
        moves = self.kernel.reshard(pps)
        self._n_shards = self.kernel.n_shards
        if self._decision_s > 0.0 and self._n_shards > len(self._shard_free):
            # grow the decision-server arrays; wakes queued under old
            # shard ids drain against their (still-indexed) old servers
            grow = self._n_shards - len(self._shard_free)
            self._shard_free.extend([0.0] * grow)
            self._decide_depth.extend([0] * grow)
        for task, dst in moves:
            self._enqueue(task, self.kernel.migrate_in(task, dst))

    def _requeue(self, task: Task):
        """Hand a displaced task back to the scheduler (see
        :meth:`SchedulingKernel.requeue_displaced`)."""
        self._enqueue(task, self.kernel.requeue_displaced(task))

    def submit(self, dag: DAG):
        if self._fx is not None:
            # fault sequence numbers follow the DAG's deterministic BFS
            # order, shared with the threaded engine (cross-engine parity)
            self._fx.register_dag(dag)
        for root in dag.roots:
            self._wake(root, waker_core=0)

    # ------------------------------------------------------------ preemption
    def _set_availability(self):
        """Refresh the control plane's live view(s) after a revoke/restore
        edge (views are interned on the topology; the kernel's requeue
        path reads live cores straight off the view; a sharded plane
        composes the down set with each shard's fence)."""
        self.kernel.set_availability(frozenset(self._down_cores))

    def _preempt_running(self, rec: _Running):
        """Cut one running task short: release cores, bandwidth demand and
        the (now stale) finish event; checkpoint or discard its progress."""
        task = rec.task
        if rec.rate >= 0:
            self._stale += 1            # outstanding finish event is dead
        rec.version += 1
        del self.running[task.tid]
        for c in rec.cores:
            self.core_busy[c] = None
        if rec.bw_contrib > 0.0:
            dom = rec.domain
            d, k = self._demand[dom]
            self._demand[dom] = _NO_DEMAND if k <= 1 else \
                (d - rec.bw_contrib, k - 1)
            if dom in self._hot_doms:
                self._dirty_domains.add(dom)
        if rec.fault is not None:
            # an armed fault truncated ``remaining`` to its strike point;
            # restore the true outstanding work before checkpoint /
            # work-lost accounting (the re-execution re-draws the fault)
            rec.remaining += rec.work_assigned * (1.0 - rec.fault.frac)
            rec.fault = None
        if self._ckpt and rec.work_assigned > 0.0:
            # completed fraction of this assignment carries over (penalty
            # work counts as progress too — a resumed-then-preempted task
            # re-pays proportionally, not absolutely)
            task.resume_frac *= rec.remaining / rec.work_assigned
        else:
            self.work_lost += max(rec.work_assigned - rec.remaining, 0.0)
        task.preempt_count += 1
        self.tasks_preempted += 1

    def _revoke(self, eidx: int):
        """Apply one revoke edge: episode ``eidx``'s cores — the whole
        partition, or a sub-pod subset — go down; all work on them
        returns to the scheduler and re-places on survivors, HIGH tasks
        first."""
        cores = self.preemption.cores_of(eidx, self.topo)
        for c in cores:
            if not self._core_up[c]:
                raise RuntimeError(f"core {c} revoked twice")
        self._down_cores.update(cores)
        self.preempt_events += 1
        self._set_availability()
        displaced: list[Task] = []
        seen: set[int] = set()
        notice = self.preemption.notice if self.preemption is not None else 0.0
        if notice > 0.0:
            # 1) notice window: running tasks keep executing and are only
            #    preempted at its expiry (token-guarded — a restore before
            #    the expiry lets them run to completion, and a stale event
            #    from an earlier episode can never fire into a later one)
            token = next(self._tok)
            self._notice_token[eidx] = token
            self._push_event(self.now + notice, "notice", eidx, token)
        else:
            # 1) running tasks: any execution with a member core in the
            #    revoked set dies (a place may straddle the revoked subset
            #    and live siblings; dedup via core scan)
            for c in cores:
                rec = self.core_busy[c]
                if rec is not None and rec.task.tid not in seen:
                    seen.add(rec.task.tid)
                    self._preempt_running(rec)
                    displaced.append(rec.task)
        # 2) placed-but-unstarted tasks in the revoked cores' AQs (their
        #    place dies; no progress to account).  A sub-pod revocation
        #    can leave the record's copies in *live* siblings' AQs — pull
        #    those too, or the task would run twice.
        seen.clear()
        down_set = set(cores)
        doomed: list = []
        for c in cores:
            for rec in self.aq[c]:
                if rec.task.tid not in seen:
                    seen.add(rec.task.tid)
                    displaced.append(rec.task)
                    doomed.append(rec)
            self.aq[c].clear()
        for rec in doomed:
            for mc in rec.cores:
                if mc not in down_set:
                    try:
                        self.aq[mc].remove(rec)
                    except ValueError:
                        pass
                    self._mark(mc)      # a freed AQ head may unblock members
        # 3) ready tasks in the revoked cores' WSQs, in steal order
        displaced.extend(self.queues.drain_wsq(cores))
        high, low = split_by_priority(displaced)
        # down cores leave the dispatch sets until restored
        for c in cores:
            self._core_up[c] = False
            self._dirty.discard(c)
            self._starving.discard(c)
        # 4) re-place on the survivors — HIGH tasks re-bind first, so the
        #    critical path recovers before the bulk work lands
        for task in high:
            self._requeue(task)
        for task in low:
            self._requeue(task)

    def _restore(self, eidx: int):
        """Apply one restore edge: the episode's cores re-enter the
        dispatch loop (empty-handed — they steal their way back)."""
        self._down_cores.difference_update(
            self.preemption.cores_of(eidx, self.topo))
        self._notice_token.pop(eidx, None)   # pending notice expiry is void
        self._set_availability()
        for c in self.preemption.cores_of(eidx, self.topo):
            self._core_up[c] = True
            self._mark(c)

    # -------------------------------------------------------------- dispatch
    def _try_assign_from_wsq(self, core: int) -> bool:
        """Pop own WSQ (priority-aware — ``WorkQueues.pop_local`` inlined,
        the flags are per-run constants) and place the task into AQs.  The
        losing copy of a hedged pair may be parked in a WSQ when the winner
        commits; it is dropped — and resolved — here rather than removed
        eagerly."""
        queues = self.queues
        q = queues.wsq[core]
        track = queues.track_load
        pd = queues.priority_dequeue
        while True:
            if pd and q.high:
                task = q.high.popleft()
            elif q.low:
                task = q.low.pop()
            elif q.high:
                task = q.high.popleft()
            else:
                return False
            if track:
                queues.queued_s[core] -= task.load_est
            if self._fx is not None and (task.hedge_of or task).committed:
                self._outstanding -= 1      # hedge loser resolves at pop
                continue
            if self._batching is not None and task.batch_key is not None:
                self.kernel.form_dispatch(task, core)
            self._place_into_aqs(task, core)
            return True

    def _try_steal(self, thief: int) -> bool:
        """Steal from the WSQ with the most stealable tasks (paper step 3),
        FIFO end; re-run the place search at the thief (steps 4-5).  Victim
        selection reads O(cores) queue lengths; maxima tie-break uniformly
        at random, as the shuffled scan did."""
        while True:
            victim = self.queues.pick_victim(thief, self.rng)
            if victim < 0:
                return False
            t = self.queues.steal_pop(victim)     # oldest stealable
            if self._fx is not None and (t.hedge_of or t).committed:
                self._outstanding -= 1      # hedge loser resolves at pop
                continue
            if self._flat:
                t.bound_place = None    # inlined on_steal: decision redone
            else:
                self.kernel.on_steal(t)
            if self._batching is not None and t.batch_key is not None:
                # same-key members still sit in the victim's queue —
                # coalesce there, then execute at the thief
                self.kernel.form_dispatch(t, victim)
            self._place_into_aqs(t, thief)
            return True

    def _place_into_aqs(self, task: Task, worker_core: int):
        # ``_choose_place`` is ``place_on_dequeue`` directly when the flat
        # kernel tracks no load (its only other job is the load charge), so
        # a bound HIGH task skips the call entirely — same decision either way
        place = task.bound_place
        if place is None or not self._inline_choose:
            place = self._choose_place(task, worker_core)
        info = self._leader_info[place.leader]
        if info is None:
            part = self.topo.partition_of(place.leader)
            info = self._leader_info[place.leader] = (
                part.domain, PARTITION_BW[part.kind], part.kind, {})
        domain, cap, kind, bw_by_mems = info
        mem_s = task.type.mem_sensitivity
        if mem_s > 0.0:
            bwkey = bw_by_mems.get(mem_s)
            if bwkey is None:
                key = (domain, cap, mem_s)
                bwkey = self._bwkey_id.get(key)
                if bwkey is None:
                    bwkey = self._bwkey_id[key] = len(self._bwkeys)
                    self._bwkeys.append(key)
                    self._key_factor.append(math.nan)
                    self._dom_bwkeys.setdefault(domain, []).append(bwkey)
                bw_by_mems[mem_s] = bwkey
        else:
            bwkey = -1
        base = task.type.duration(kind, place.width)
        if task.resume_frac != 1.0:
            # checkpointed resume: outstanding fraction of the new place's
            # full duration, plus the resume penalty (restart kills keep
            # resume_frac at 1.0 and take this place's full duration)
            base = base * (task.resume_frac + self._resume_penalty)
        rec = _Running(task, place, remaining=base,
                       domain=domain, cap=cap, bwkey=bwkey)
        if task.preempt_count:
            # version-epoch per execution: a stale finish event from a
            # preempted run must never collide with this run's versions
            # (they are compared for equality), so each re-placement
            # starts a disjoint version range
            rec.version = task.preempt_count << 32
        aq = self.aq
        dirty = self._dirty
        starving = self._starving
        for c in rec.cores:
            aq[c].append(rec)
            dirty.add(c)
            starving.discard(c)

    def _try_start_aq(self, core: int) -> bool:
        """Start the AQ head if every member core has it at head and is idle."""
        aq = self.aq
        busy = self.core_busy
        if busy[core] is not None:
            return False
        q = aq[core]
        if not q:
            return False
        rec = q[0]
        cores = rec.cores
        if len(cores) == 1:     # width-1: the caller's checks suffice
            q.popleft()
            busy[core] = rec
        else:
            for c in cores:
                if busy[c] is not None or not aq[c] or aq[c][0] is not rec:
                    return False
            for c in cores:
                aq[c].popleft()
                busy[c] = rec
        task = rec.task
        task.place = rec.place
        task.t_start = self.now
        self.running[task.tid] = rec
        self._fresh.append(rec)          # rate + finish set by _refresh_rates
        if rec.bw_contrib > 0.0:
            dom = rec.domain
            d, k = self._demand.get(dom, _NO_DEMAND)
            self._demand[dom] = (d + rec.bw_contrib, k + 1)
            self._dirty_domains.add(dom)
        if self._fx is not None:
            self._on_start_faults(rec)
        return True

    def _dispatch(self):
        """Drain the idle-core worklist.  Each round mirrors one pass of the
        old all-cores fixpoint — phase A: local work (AQ head, then own WSQ);
        phase B: idle cores with no local work attempt one steal — but only
        over cores whose state changed.  Round order is shuffled so ties
        break randomly, not by core id."""
        dirty, busy, aq, wsq, up, starving, rng = self._disp_binds
        while dirty:
            if len(dirty) == 1:
                # the overwhelmingly common worklist is a single core
                # (one commit released one place) — no sort, no shuffle
                # draw (the shuffles below only fire on len > 1 anyway)
                batch = [dirty.pop()]
            else:
                batch = sorted(dirty, reverse=True)
                dirty.clear()
                rng.shuffle(batch)
            # phase A: local work only (AQ head, then own WSQ)
            for c in batch:
                if busy[c] is not None or not up[c]:
                    continue
                if aq[c]:
                    self._try_start_aq(c)
                else:
                    self._try_assign_from_wsq(c)
            # phase B: idle cores with empty AQs and WSQs attempt to steal
            # (re-shuffled, like the pre-refactor fixpoint: steal order must
            # not correlate with local-work order)
            if len(batch) > 1:
                rng.shuffle(batch)
            for c in batch:
                q = wsq[c]
                if busy[c] is not None or not up[c] or aq[c] \
                        or q.high or q.low:
                    continue
                if not self._try_steal(c):
                    starving.add(c)

    # ---------------------------------------------------------------- faults
    def _on_start_faults(self, rec: _Running):
        """Arm this execution's injected fault — ``remaining`` is truncated
        to the strike point, so the strike is an ordinary finish event —
        and schedule the straggler check at ``k`` x the PTT expectation
        (token-guarded: commits and re-placements invalidate it).  Hedge
        duplicates run clean: they exist to escape a degraded place."""
        task = rec.task
        if task.hedge_of is not None:
            return
        fault = self._fx.draw(task, self.now)
        if fault is not None:
            rec.fault = fault
            rec.remaining = rec.work_assigned * fault.frac
        exp = self.kernel.expected_duration(task, rec.place)
        if exp > 0.0:
            rec.token = next(self._tok)
            self._push_event(self.now + self._fx.policy.straggler_k * exp,
                             "straggle", task.tid, rec.token)

    def _kill_running(self, rec: _Running, event_outstanding: bool):
        """Remove an execution without committing (fault death or hedge-
        loser cancel): release its cores — marked, unlike a revocation's,
        they are still up and must re-enter dispatch — its bandwidth
        demand, and its finish event."""
        if event_outstanding and rec.rate >= 0:
            self._stale += 1
        rec.version += 1
        del self.running[rec.task.tid]
        for c in rec.cores:
            self.core_busy[c] = None
            self._mark(c)
        if rec.bw_contrib > 0.0:
            dom = rec.domain
            d, k = self._demand[dom]
            self._demand[dom] = _NO_DEMAND if k <= 1 else \
                (d - rec.bw_contrib, k - 1)
            if dom in self._hot_doms:
                self._dirty_domains.add(dom)

    def _on_fault_trigger(self, rec: _Running):
        """The finish event at an armed fault's strike point fired."""
        fault = rec.fault
        if fault.kind == "slow":
            # the place silently degrades: the rest of the work proceeds
            # at 1/factor of the healthy rate; nothing fails, so only the
            # straggler detector can see it
            rec.fault = None
            self.metrics.faults_failslow += 1
            rec.slow_mult = 1.0 / fault.factor
            rec.remaining = rec.work_assigned * (1.0 - fault.frac)
            rec.rate = -1.0         # re-derived (with slow_mult) on refresh
            rec.version += 1
            self._fresh.append(rec)
            return
        self._fail_running(rec)

    def _fail_running(self, rec: _Running):
        """Fail-stop strike: the execution dies.  Penalize the place in
        the PTT, then retry after a seeded backoff (the task re-enters the
        kernel's ``requeue_displaced`` placement at the retry event) or
        fail permanently once the attempt budget is spent."""
        task = rec.task
        pol = self._fx.policy
        self.metrics.faults_failstop += 1
        executed = rec.work_assigned * rec.fault.frac - rec.remaining
        self.metrics.work_lost_faults_s += max(executed, 0.0)
        elapsed = self.now - task.t_start
        rec.fault = None
        self._kill_running(rec, event_outstanding=False)
        self.kernel.fault_feedback(task, rec.place, elapsed, pol.fail_penalty)
        task.fault_count += 1
        if task.hedge_dup is not None and not task.committed:
            # the original died but its speculative duplicate is still in
            # flight — leave recovery to the copy on the healthier place
            self._outstanding -= 1
            return
        if task.fault_count > pol.max_retries:
            self.metrics.failed_tasks += 1
            self.metrics.errors.append(
                f"task {task.tid} ({task.type.name}) failed permanently "
                f"after {task.fault_count - 1} retries")
            self._outstanding -= 1
            return
        self.metrics.retries += 1
        self._pending_retry[task.tid] = task
        self._push_event(self.now + self._fx.backoff(task), "retry", task.tid)

    def _on_straggler(self, rec: _Running):
        """The execution outlived ``k`` x its PTT expectation.  Flag it;
        if hedging is on and the task is HIGH, launch a speculative
        duplicate on the PTT-best place sharing no core with the
        straggler (first commit wins, the loser is cancelled)."""
        task = rec.task
        self.metrics.stragglers += 1
        pol = self._fx.policy
        if (not pol.hedge or task.priority != Priority.HIGH
                or task.hedge_launched or task.committed):
            return
        place = self.kernel.hedge_place(task, set(rec.cores),
                                        self._fx.hedge_rng)
        if place is None:
            return
        task.hedge_launched = True
        dup = Task(type=task.type, priority=task.priority,
                   payload=task.payload)
        dup.hedge_of = task
        dup.bound_place = place     # honored by place_on_dequeue everywhere
        task.hedge_dup = dup
        dup.t_ready = self.now
        self.metrics.hedges_launched += 1
        self._outstanding += 1
        self._place_into_aqs(dup, place.leader)

    def _cancel_copy(self, task: Task):
        """Reap the losing copy of a hedged pair: kill it if running, drop
        a pending retry or an AQ placement; a WSQ entry is dropped (and
        resolved) lazily at the next pop.  Each copy resolves exactly
        once."""
        self.kernel.discharge(task)     # whatever load it held is void
        rec = self.running.get(task.tid)
        if rec is not None:
            executed = rec.work_assigned - rec.remaining
            if rec.fault is not None:
                executed = rec.work_assigned * rec.fault.frac - rec.remaining
                rec.fault = None
            self.metrics.work_hedged_s += max(executed, 0.0)
            self._kill_running(rec, event_outstanding=True)
            self._outstanding -= 1
            return
        if self._pending_retry.pop(task.tid, None) is not None:
            self._outstanding -= 1
            return
        for dq in self.aq:
            for r in dq:
                if r.task is task:
                    for c in r.cores:
                        try:
                            self.aq[c].remove(r)
                        except ValueError:
                            pass
                        self._mark(c)   # freed AQ heads may unblock members
                    self._outstanding -= 1
                    return

    def _suppress_commit(self, rec: _Running):
        """A losing copy ran to completion after the logical task had
        already committed (normally unreachable — cancellation reaps
        losers first; kept so the invariants hold if one slips through)."""
        self.kernel.discharge(rec.task)
        self.metrics.work_hedged_s += max(rec.work_assigned - rec.remaining,
                                          0.0)
        self._kill_running(rec, event_outstanding=False)
        self._outstanding -= 1

    def _notice_expire(self, eidx: int):
        """The revocation notice window closed with the episode's cores
        still down: preempt whatever is still running there (work
        finished inside the window committed normally — that is the
        point)."""
        del self._notice_token[eidx]
        displaced: list[Task] = []
        seen: set[int] = set()
        for c in self.preemption.cores_of(eidx, self.topo):
            rec = self.core_busy[c]
            if rec is not None and rec.task.tid not in seen:
                seen.add(rec.task.tid)
                self._preempt_running(rec)
                displaced.append(rec.task)
        high, low = split_by_priority(displaced)
        for task in high:
            self._requeue(task)
        for task in low:
            self._requeue(task)

    # --------------------------------------------------------------- commit
    def _commit(self, rec: _Running):
        task = rec.task
        if self._fx is not None:
            logical = task.hedge_of or task
            if logical.committed:
                self._suppress_commit(rec)  # the other copy already won
                return
            logical.committed = True
            if task.hedge_of is not None:
                self.metrics.hedge_wins += 1
                self._cancel_copy(logical)          # the original lost
            elif task.hedge_dup is not None:
                self._cancel_copy(task.hedge_dup)   # the duplicate lost
        task.t_end = self.now
        busy = self.core_busy
        dirty = self._dirty
        starving = self._starving
        for c in rec.cores:
            busy[c] = None
            dirty.add(c)
            starving.discard(c)
        del self.running[task.tid]
        members = task.batch_members or ()
        self._done += 1 + len(members)
        self._outstanding -= 1 + len(members)
        if rec.bw_contrib > 0.0:
            dom = rec.domain
            d, k = self._demand[dom]
            # pin the total back to exactly zero when the domain drains so
            # incremental +/- never accumulates float residue
            self._demand[dom] = _NO_DEMAND if k <= 1 else \
                (d - rec.bw_contrib, k - 1)
            if dom in self._hot_doms:
                self._dirty_domains.add(dom)

        # Leader measures and updates the PTT (with measurement noise +
        # heavy-tailed spikes from OS jitter on short tasks).  Flat-kernel
        # inline of observe_simulated + ptt_feedback: same draws from the
        # same stream in the same order, same EMA fold.
        ttype = task.type
        if self._flat:
            rng = self.rng
            if ttype.noise:
                noise = rng.gauss(1.0, ttype.noise)
                if noise < 0.5:     # same doubles as min(max(n,.5),2.)
                    noise = 0.5
                elif noise > 2.0:
                    noise = 2.0
                observed = (task.t_end - task.t_start) * noise
            else:
                observed = (task.t_end - task.t_start) * 1.0
            if ttype.spike_prob and rng.random() < ttype.spike_prob:
                observed *= ttype.spike_mag
            if self._track_load:
                self.kernel.discharge(task)
            tbl = self._ptt_for.get(ttype.name)
            if tbl is None:
                tbl = self._ptt_for[ttype.name] = \
                    self._ptt_bank.for_type(ttype.name)
            tbl.update_nolock(rec.place, observed)
            if members and self._track_load:
                for m in members:
                    self.kernel.discharge(m)
        else:
            observed = self.kernel.observe_simulated(
                ttype, task.t_end - task.t_start)
            if members:
                self.kernel.batch_feedback(task, rec.place, observed)
            else:
                self.kernel.ptt_feedback(task, rec.place, observed)

        # A winning duplicate commits on behalf of its logical task:
        # successors and the record's sojourn anchor come from it.
        src = task if task.hedge_of is None else task.hedge_of
        leader = rec.place.leader
        self._rec_append(TaskRecord(
            ttype.name, int(task.priority), leader, rec.place.width,
            src.t_ready, task.t_start, task.t_end))
        if members:
            base = ttype.batch_base or ttype.name
            self.metrics.batches.append((ttype.name, tuple(sorted(
                [base] + [m.type.name for m in members]))))
            for m in members:
                m.t_start = task.t_start
                m.t_end = task.t_end

        # Wake dependents; dynamic DAG growth.  Flat-kernel inline of
        # commit_successors (same dependency bookkeeping, no generator):
        # the DES is single-threaded, so the lockless decrement is exact.
        # A batched dispatch walks the leader's successors first, then
        # each member's in coalesce order — same order as the threaded
        # engine's commit.
        if self._flat:
            for child in src.children:
                child.n_deps -= 1
                if child.n_deps == 0:
                    self._wake(child, leader)
            if src.on_commit is not None:
                for new_task in src.on_commit(src):
                    if new_task.n_deps == 0:
                        self._wake(new_task, leader)
            for m in members:
                for child in m.children:
                    child.n_deps -= 1
                    if child.n_deps == 0:
                        self._wake(child, leader)
                if m.on_commit is not None:
                    for new_task in m.on_commit(m):
                        if new_task.n_deps == 0:
                            self._wake(new_task, leader)
        else:
            for ready in self.kernel.commit_successors(src):
                self._wake(ready, leader)
            for m in members:
                for ready in self.kernel.commit_successors(m):
                    self._wake(ready, leader)

    # ------------------------------------------------------------------ run
    def _run_scalar(self):
        """Reference event loop: one event per iteration, bookkeeping
        (dispatch / rate refresh / compaction / termination) after every
        live event.  Retained verbatim as the bit-identity oracle for the
        cohort loop (``tests/test_cohort_parity.py``)."""
        events = self._events
        running = self.running
        while events:
            t, _, kind, tid, version = heapq.heappop(events)
            if t > self.horizon:
                break
            if kind == "finish":
                rec = running.get(tid)
                if rec is None or rec.version != version:
                    self._stale -= 1               # stale (lazy deletion)
                    continue
                self._advance(t)
                if rec.remaining > 1e-9 * max(rec.rate, 1.0):
                    rec.version += 1               # numeric drift: reschedule
                    self._push_event(self.now + rec.remaining / rec.rate,
                                     "finish", tid, rec.version)
                    continue
                if rec.fault is not None:
                    self._on_fault_trigger(rec)    # armed strike point
                else:
                    self._commit(rec)
            elif kind == "straggle":
                rec = running.get(tid)
                if rec is None or rec.token != version:
                    continue       # execution already ended or re-placed
                self._advance(t)
                self._on_straggler(rec)
            elif kind == "retry":
                retry_task = self._pending_retry.pop(tid, None)
                if retry_task is None:
                    continue       # cancelled while in backoff
                self._advance(t)
                self._requeue(retry_task)
            elif kind == "notice":
                if self._notice_token.get(tid) != version:
                    continue       # partition restored (or re-revoked)
                self._advance(t)
                self._notice_expire(tid)
            else:   # speed / bg / revoke / restore / control-plane event
                self._advance(t)
                if kind == "speed":
                    self._recompute_speed()
                    nb = self.speed.next_breakpoint(t)
                    if nb is not None and nb <= self.horizon:
                        self._push_event(nb, "speed")
                elif kind == "bg":
                    self._recompute_bg()
                elif kind == "revoke":
                    self._revoke(tid)
                elif kind == "restore":
                    self._restore(tid)
                elif kind == "decide":
                    self._decide(tid)
                elif kind == "migrate":
                    self._migrate_land(tid)
                elif kind == "rebalance":
                    self._rebalance()
                elif kind == "reshard":
                    self._reshard(tid)
            self._dispatch()
            self._refresh_rates()
            self._maybe_compact()
            if self._outstanding == 0 and not running:
                break

    def _run_cohort(self):
        """Array-native event loop.  Pops the full same-timestamp cohort in
        an inner loop sharing one rate-integration advance per unique
        timestamp (vectorized across the running set past ``_vec_min``) and
        one compaction check per cohort; stale events take a fast path that
        touches nothing but the lazy-deletion counter, and dispatch/refresh
        only run when their dirty state says there is work.  Decision points
        fire in exactly the scalar reference order, so results are
        bit-identical to ``_run_scalar`` (pinned by the parity suite).

        Rate refresh stays per live event rather than deferring to the
        cohort boundary: two refresh-triggering events at one timestamp
        would otherwise fold into a single EMA-free recompute whose rates
        can differ from the eager pair's within the ``_EPS`` change test,
        silently nudging finish times off the scalar path.
        """
        events = self._events
        running = self.running
        heappop = heapq.heappop
        horizon = self.horizon
        dirty = self._dirty
        fresh = self._fresh
        dirty_domains = self._dirty_domains
        load_coupled = self._load_coupled
        pending_retry = self._pending_retry
        notice_token = self._notice_token
        while events:
            ev = heappop(events)
            t = ev[0]
            if t > horizon:
                break
            while True:
                kind = ev[2]
                live = True
                if kind == "finish":
                    rec = running.get(ev[3])
                    if rec is None or rec.version != ev[4]:
                        self._stale -= 1           # stale (lazy deletion)
                        live = False
                    else:
                        if self.now != t:
                            self._advance(t)
                        rate = rec.rate
                        if rec.remaining > 1e-9 * (rate if rate > 1.0
                                                   else 1.0):
                            rec.version += 1       # drift: reschedule
                            self._push_event(t + rec.remaining / rate,
                                             "finish", ev[3], rec.version)
                            live = False
                        elif rec.fault is not None:
                            self._on_fault_trigger(rec)
                        else:
                            self._commit(rec)
                elif kind == "straggle":
                    rec = running.get(ev[3])
                    if rec is None or rec.token != ev[4]:
                        live = False   # execution already ended or re-placed
                    else:
                        if self.now != t:
                            self._advance(t)
                        self._on_straggler(rec)
                elif kind == "retry":
                    retry_task = pending_retry.pop(ev[3], None)
                    if retry_task is None:
                        live = False   # cancelled while in backoff
                    else:
                        if self.now != t:
                            self._advance(t)
                        self._requeue(retry_task)
                elif kind == "notice":
                    if notice_token.get(ev[3]) != ev[4]:
                        live = False   # partition restored (or re-revoked)
                    else:
                        if self.now != t:
                            self._advance(t)
                        self._notice_expire(ev[3])
                else:   # speed / bg / revoke / restore / control-plane
                    if self.now != t:
                        self._advance(t)
                    if kind == "speed":
                        self._recompute_speed()
                        nb = self.speed.next_breakpoint(t)
                        if nb is not None and nb <= horizon:
                            self._push_event(nb, "speed")
                    elif kind == "bg":
                        self._recompute_bg()
                    elif kind == "revoke":
                        self._revoke(ev[3])
                    elif kind == "restore":
                        self._restore(ev[3])
                    elif kind == "decide":
                        self._decide(ev[3])
                    elif kind == "migrate":
                        self._migrate_land(ev[3])
                    elif kind == "rebalance":
                        self._rebalance()
                    elif kind == "reshard":
                        self._reshard(ev[3])
                if live:
                    if dirty:
                        self._dispatch()
                    if (fresh or dirty_domains or self._rates_global_dirty
                            or load_coupled):
                        self._refresh_rates()
                    if self._outstanding == 0 and not running:
                        return
                if not events or events[0][0] != t:
                    break
                ev = heappop(events)
            stale = self._stale
            if (stale > self._compact_min_stale
                    and stale > self._compact_heap_frac * len(events)):
                self._maybe_compact()

    def run(self) -> RunMetrics:
        for b in self.background:
            if b.t_start > 0:
                self._push_event(b.t_start, "bg")
            if b.t_end < self.horizon:
                self._push_event(b.t_end, "bg")
        if self.preemption is not None:
            n_parts = len(self.topo.partitions)
            for eidx, (pidx, t0, t1) in enumerate(self.preemption.episodes):
                if not 0 <= pidx < n_parts:
                    raise ValueError(f"preemption episode for partition "
                                     f"{pidx}; topology has {n_parts}")
                if t0 <= self.horizon:
                    self._push_event(t0, "revoke", eidx)
                    if t1 <= self.horizon:
                        self._push_event(t1, "restore", eidx)
        if (self._n_shards > 1
                and self.sharding.rebalance_period_s > 0.0):
            self._push_event(self.sharding.rebalance_period_s, "rebalance")
        for i, (t, _) in enumerate(self._reshard_at):
            if t <= self.horizon:
                self._push_event(t, "reshard", i)
        # speed breakpoints are *pulled* lazily — one outstanding event at
        # a time, the next asked of the profile only when it fires — so a
        # DVFS wave spanning the 1e6 s horizon contributes O(1) heap
        # entries and closed-form profiles never enumerate anything
        nb = self.speed.next_breakpoint(0.0)
        if nb is not None and nb <= self.horizon:
            self._push_event(nb, "speed")

        self._dispatch()
        self._refresh_rates()
        if self.event_mode == "scalar":
            self._run_scalar()
        else:
            self._run_cohort()
        # a run that finishes mid-outage must not leak its availability
        # mask into later runs reusing the scheduler (PTT state is meant
        # to carry across runs; a revoked-capacity view is not)
        self.kernel.end_run()
        self.metrics.finish(self.now)
        self.metrics.preempt_events = self.preempt_events
        self.metrics.tasks_preempted = self.tasks_preempted
        self.metrics.work_lost_s = self.work_lost
        if self._n_shards > 1:
            self.metrics.migrations = self.kernel.migrations
            self.metrics.overflow_migrations = self.kernel.overflow_migrations
            self.metrics.rebalance_rounds = self.kernel.rebalance_rounds
            self.metrics.migrated_load_s = self.kernel.migrated_load_s
            self.metrics.reshard_rounds = self.kernel.reshard_rounds
        return self.metrics


def simulate(dag: DAG, scheduler: Scheduler, *,
             speed: Optional[SpeedProfileBase] = None,
             background: Iterable[BackgroundApp] = (),
             preemption: Optional[PreemptionModel] = None,
             faults: Optional[FaultModel] = None,
             recovery: Optional[RecoveryPolicy] = None,
             sharding: Optional[ShardingSpec] = None,
             batching: Optional[BatchingConfig] = None,
             reshard_at: Iterable[tuple[float, int]] = (),
             horizon: float = 1e6,
             event_mode: str = "cohort",
             compact_min_stale: int = _COMPACT_MIN_STALE,
             compact_heap_frac: float = _COMPACT_HEAP_FRAC) -> RunMetrics:
    sim = Simulator(scheduler, speed=speed, background=background,
                    preemption=preemption, faults=faults, recovery=recovery,
                    sharding=sharding, batching=batching,
                    reshard_at=reshard_at, horizon=horizon,
                    event_mode=event_mode,
                    compact_min_stale=compact_min_stale,
                    compact_heap_frac=compact_heap_frac)
    sim.submit(dag)
    return sim.run()
