"""Discrete-event simulator of the XiTAO-style runtime (paper §4.1.2).

Faithfully models the scheduler-visible machinery:

* per-core Work Stealing Queue (WSQ, owner LIFO / thief FIFO) holding ready
  tasks, and a FIFO Assembly Queue (AQ) holding placed tasks; a molded task's
  pointer is inserted into *all* member AQs atomically and starts when every
  member reaches it (paper Fig. 3 steps 1-7);
* binding placement of HIGH tasks at wake time, re-run of the local width
  search after a steal (steps 4-5), PTT update by the leader on commit
  (step 8) with multiplicative measurement noise;
* dynamic asymmetry: per-core piecewise-constant speed profiles (DVFS) and
  co-running background apps that time-share their pinned cores and pressure
  the partition's shared memory bandwidth.

Progress integration uses piecewise-constant rates: every event (task
start/finish, speed breakpoint, background episode edge) re-derives each
running task's rate

    rate = min_{c in place} speed(c,t)/share(c) * min(1, bw_cap/bw_demand)^s

and re-schedules versioned completion events.  All randomness is seeded.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Iterable, Optional

from .dag import DAG
from .interference import BackgroundApp, SpeedProfile
from .metrics import RunMetrics, TaskRecord
from .places import ExecutionPlace
from .schedulers import Scheduler
from .task import PARTITION_BW, Priority, Task

_EPS = 1e-12


@dataclasses.dataclass
class _Running:
    task: Task
    place: ExecutionPlace
    remaining: float            # work-seconds left at rate 1.0
    rate: float = -1.0          # <0 = not yet scheduled a finish event
    version: int = 0


@dataclasses.dataclass(order=True)
class _Event:
    t: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    tid: int = dataclasses.field(compare=False, default=-1)
    version: int = dataclasses.field(compare=False, default=-1)


class Simulator:
    def __init__(self, scheduler: Scheduler, *,
                 speed: Optional[SpeedProfile] = None,
                 background: Iterable[BackgroundApp] = (),
                 horizon: float = 1e6):
        self.sched = scheduler
        self.topo = scheduler.topology
        self.rng = scheduler.rng
        self.speed = speed or SpeedProfile(self.topo.n_cores)
        self.background = list(background)
        self.horizon = horizon

        n = self.topo.n_cores
        self.wsq: list[deque[Task]] = [deque() for _ in range(n)]
        self.aq: list[deque[_Running]] = [deque() for _ in range(n)]
        self.core_busy: list[Optional[_Running]] = [None] * n
        self.running: dict[int, _Running] = {}
        self.now = 0.0
        self._seq = itertools.count()
        self._events: list[_Event] = []
        self._done = 0
        self._outstanding = 0
        self.metrics = RunMetrics(n_cores=n)

    # ------------------------------------------------------------------ util
    def _push_event(self, t: float, kind: str, tid: int = -1, version: int = -1):
        heapq.heappush(self._events, _Event(t, next(self._seq), kind, tid, version))

    def _bg_share(self, core: int) -> tuple[int, float]:
        """(# active co-runners on core, strongest cache-thrash factor)."""
        n, thrash = 0, 0.0
        for b in self.background:
            if core in b.cores and b.active(self.now):
                n += 1
                thrash = max(thrash, b.thrash)
        return n, thrash

    def _partition_bw_demand(self) -> dict[str, tuple[float, int]]:
        """partition -> (aggregate bytes/s demanded, # independent streams).
        More concurrent streams also *degrade* effective DRAM bandwidth
        (bank/row-buffer thrash) — this is the oversubscription the paper's
        moldability avoids: one wide task is one stream, w narrow tasks are
        w streams."""
        demand: dict[str, tuple[float, int]] = {}
        for rec in self.running.values():
            if rec.task.type.bw_demand <= 0:
                continue
            dom = self.topo.partition_of(rec.place.leader).domain
            d, n = demand.get(dom, (0.0, 0))
            demand[dom] = (d + rec.task.type.bw_demand * rec.place.width, n + 1)
        for b in self.background:
            if b.active(self.now) and b.task_type.bw_demand > 0:
                for c in b.cores:
                    dom = self.topo.partition_of(c).domain
                    d, n = demand.get(dom, (0.0, 0))
                    demand[dom] = (d + b.task_type.bw_demand, n + 1)
        return demand

    def _rate_of(self, rec: _Running, demand: dict[str, tuple[float, int]]) -> float:
        core_rate = float("inf")
        for c in rec.place.cores:
            n_bg, thrash = self._bg_share(c)
            r = self.speed.speed(c, self.now) / (1 + n_bg) * (1.0 - thrash) ** (n_bg > 0)
            core_rate = min(core_rate, r)
        s = rec.task.type.mem_sensitivity
        if s > 0.0:
            part = self.topo.partition_of(rec.place.leader)
            cap = PARTITION_BW[part.kind]
            dem, streams = demand.get(part.domain, (0.0, 0))
            cap *= max(0.6, 1.0 - 0.08 * max(0, streams - 1))
            if dem > cap:
                core_rate *= (cap / dem) ** s
        return max(core_rate, 1e-9)

    def _refresh_rates(self):
        """Advance + re-derive every running task's rate; reschedule finishes."""
        demand = self._partition_bw_demand()
        for rec in self.running.values():
            rate = self._rate_of(rec, demand)
            if rec.rate < 0 or abs(rate - rec.rate) > 1e-12 * max(rate, rec.rate):
                rec.rate = rate
                rec.version += 1
                self._push_event(self.now + rec.remaining / rate, "finish",
                                 rec.task.tid, rec.version)

    def _advance(self, t: float):
        dt = t - self.now
        if dt <= 0:
            if dt < -1e-9 * max(1.0, abs(self.now)):
                raise RuntimeError(f"time went backwards: {self.now} -> {t}")
            return      # same instant (fp jitter)
        for rec in self.running.values():
            rec.remaining -= dt * rec.rate
        self.now = t

    # ----------------------------------------------------------------- wake
    def _wake(self, task: Task, waker_core: int):
        task.t_ready = self.now
        target = self.sched.place_on_wake(task, waker_core)
        self.wsq[waker_core if target is None else target].append(task)
        self._outstanding += 1

    def submit(self, dag: DAG):
        for root in dag.roots:
            self._wake(root, waker_core=0)

    # -------------------------------------------------------------- dispatch
    def _try_assign_from_wsq(self, core: int) -> bool:
        """Pop own WSQ and place the task into AQs.  HIGH tasks are served
        first (oldest HIGH — they gate the DAG); LOW tasks pop LIFO for
        locality, as in a classic work-stealing deque."""
        q = self.wsq[core]
        if not q:
            return False
        task = None
        if self.sched.priority_dequeue:
            for i, t in enumerate(q):           # oldest HIGH first
                if t.priority == Priority.HIGH:
                    task = t
                    del q[i]
                    break
        if task is None:
            task = q.pop()                      # newest (plain LIFO deque)
        self._place_into_aqs(task, core)
        return True

    def _try_steal(self, thief: int) -> bool:
        """Steal from the WSQ with the most stealable tasks (paper step 3),
        FIFO end; re-run the place search at the thief (steps 4-5)."""
        best, best_n = -1, 0
        order = list(range(self.topo.n_cores))
        self.rng.shuffle(order)          # random tie-breaking
        for v in order:
            if v == thief:
                continue
            n = sum(1 for t in self.wsq[v] if self.sched.may_steal(t))
            if n > best_n:
                best, best_n = v, n
        if best < 0:
            return False
        victim_q = self.wsq[best]
        for i, t in enumerate(victim_q):          # oldest stealable first
            if self.sched.may_steal(t):
                del victim_q[i]
                t.bound_place = None              # stolen -> decision redone
                self._place_into_aqs(t, thief)
                return True
        return False

    def _place_into_aqs(self, task: Task, worker_core: int):
        place = self.sched.place_on_dequeue(task, worker_core)
        rec = _Running(task, place,
                       remaining=task.type.duration(
                           self.topo.partition_of(place.leader).kind, place.width))
        for c in place.cores:
            self.aq[c].append(rec)

    def _try_start_aq(self, core: int) -> bool:
        """Start the AQ head if every member core has it at head and is idle."""
        if self.core_busy[core] is not None or not self.aq[core]:
            return False
        rec = self.aq[core][0]
        for c in rec.place.cores:
            if self.core_busy[c] is not None or not self.aq[c] or self.aq[c][0] is not rec:
                return False
        for c in rec.place.cores:
            self.aq[c].popleft()
            self.core_busy[c] = rec
        rec.task.place = rec.place
        rec.task.t_start = self.now
        self.running[rec.task.tid] = rec
        # rate + finish event are set by the caller's _refresh_rates()
        return True

    def _dispatch(self):
        """Run idle cores to fixpoint.  Two-phase, mirroring real stealing
        latencies: owners pop their local WSQ essentially for free (phase A),
        while thieves race at a much coarser granularity (phase B).  Core
        order is shuffled per pass so ties are broken randomly, not by id."""
        progress = True
        order = list(range(self.topo.n_cores))
        while progress:
            progress = False
            self.rng.shuffle(order)
            # phase A: local work only (AQ head, then own WSQ)
            for core in order:
                if self.core_busy[core] is not None:
                    continue
                if self._try_start_aq(core):
                    progress = True
                elif not self.aq[core] and self._try_assign_from_wsq(core):
                    progress = True
            # phase B: idle cores with empty AQs attempt to steal
            self.rng.shuffle(order)
            for core in order:
                if self.core_busy[core] is not None or self.aq[core]:
                    continue
                if self._try_start_aq(core):
                    progress = True
                elif not self.wsq[core] and self._try_steal(core):
                    progress = True

    # --------------------------------------------------------------- commit
    def _commit(self, rec: _Running):
        task = rec.task
        task.t_end = self.now
        for c in rec.place.cores:
            self.core_busy[c] = None
        del self.running[task.tid]
        self._done += 1
        self._outstanding -= 1

        # Leader measures and updates the PTT (with measurement noise +
        # heavy-tailed spikes from OS jitter on short tasks).
        duration = task.t_end - task.t_start
        noise = self.rng.gauss(1.0, task.type.noise) if task.type.noise else 1.0
        observed = duration * min(max(noise, 0.5), 2.0)
        if task.type.spike_prob and self.rng.random() < task.type.spike_prob:
            observed *= task.type.spike_mag
        self.sched.ptt.for_type(task.type.name).update(rec.place, observed)

        self.metrics.record(TaskRecord(
            type_name=task.type.name, priority=int(task.priority),
            leader=rec.place.leader, width=rec.place.width,
            t_ready=task.t_ready, t_start=task.t_start, t_end=task.t_end))

        # Wake dependents; dynamic DAG growth.
        leader = rec.place.leader
        for child in task.children:
            child.n_deps -= 1
            if child.n_deps == 0:
                self._wake(child, leader)
        if task.on_commit is not None:
            for new_task in task.on_commit(task):
                if new_task.n_deps == 0:
                    self._wake(new_task, leader)

    # ------------------------------------------------------------------ run
    def run(self) -> RunMetrics:
        for b in self.background:
            if b.t_start > 0:
                self._push_event(b.t_start, "bg")
            if b.t_end < self.horizon:
                self._push_event(b.t_end, "bg")
        for t in self.speed.breakpoints(self.horizon):
            self._push_event(t, "speed")

        self._dispatch()
        self._refresh_rates()
        while self._events:
            ev = heapq.heappop(self._events)
            if ev.t > self.horizon:
                break
            if ev.kind == "finish":
                rec = self.running.get(ev.tid)
                if rec is None or rec.version != ev.version:
                    continue                       # stale
                self._advance(ev.t)
                if rec.remaining > 1e-9 * max(rec.rate, 1.0):
                    rec.version += 1               # numeric drift: reschedule
                    self._push_event(self.now + rec.remaining / rec.rate,
                                     "finish", ev.tid, rec.version)
                    continue
                self._commit(rec)
            else:                                  # speed / bg / noop
                self._advance(ev.t)
            self._dispatch()
            self._refresh_rates()
            if self._outstanding == 0 and not self.running:
                break
        self.metrics.finish(self.now)
        return self.metrics


def simulate(dag: DAG, scheduler: Scheduler, *,
             speed: Optional[SpeedProfile] = None,
             background: Iterable[BackgroundApp] = (),
             horizon: float = 1e6) -> RunMetrics:
    sim = Simulator(scheduler, speed=speed, background=background,
                    horizon=horizon)
    sim.submit(dag)
    return sim.run()
