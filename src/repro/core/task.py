"""Tasks, task types and the moldable cost model (paper §2, §4.2.2).

A *task type* names a kernel (matmul / copy / stencil / kmeans_map / ...)
and carries a cost model used by the discrete-event simulator:

  * ``serial_time[kind]`` — seconds at width=1 on an unperturbed core of a
    partition *kind* (denver, a57, haswell, pod, ...).
  * ``efficiency(width)`` — parallel efficiency; molded duration is
    ``serial / (width * efficiency)``.  May exceed 1.0 slightly for
    cache-pooling effects (a width-4 stencil gets the whole shared L2).
  * ``bw_demand`` / ``mem_sensitivity`` — streaming kernels pressure the
    partition's shared memory bandwidth and are slowed when the sum of
    co-running demands exceeds it.  This is how co-running *copy* chains
    interfere with whole partitions in the paper's experiments.

The real threaded runtime ignores the cost model and *measures* payload
wall time — cost models never influence scheduling there; only the PTT does.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Callable, Optional

from .places import ExecutionPlace


class Priority(enum.IntEnum):
    LOW = 0
    HIGH = 1


# Shared-bandwidth capacity per partition kind (bytes/s) for the contention
# model; roughly: TX2 LPDDR4 split per cluster, Haswell per-socket DDR4,
# TPU per-slice HBM.
# Effective shared-bandwidth capacity of a bw *domain*, keyed by the kind of
# the partitions in it (TX2: both clusters share the LPDDR4 pipe; Haswell:
# one domain per socket; TPU: per-pod aggregate HBM).
PARTITION_BW = {
    "denver": 18.0e9,
    "a57": 18.0e9,
    "haswell": 45.0e9,
    "pod": 8.19e11 * 16,        # current-gen pod (v5p-class HBM)
    "pod_v4": 3.7e11 * 16,      # previous-gen pod (v4-class HBM2)
}


@dataclasses.dataclass(frozen=True)
class TaskType:
    name: str
    serial_time: dict[str, float]
    efficiency: Callable[[int], float] = lambda w: 1.0
    bw_demand: float = 0.0          # bytes/s demanded at width 1
    mem_sensitivity: float = 0.0    # in [0,1]: exponent on the bw-share slowdown
    noise: float = 0.0              # stddev of multiplicative measurement noise
    # heavy-tailed measurement spikes (OS interrupts / timer quantization —
    # dominant for ~10 us tasks; this is what makes the PTT weight ratio
    # matter in the paper's Fig. 8)
    spike_prob: float = 0.0
    spike_mag: float = 1.0
    # Batched-dispatch lineage (continuous batching, serve path): the base
    # type's name when this type was derived via ``batched()``, else None.
    # Lets metrics/tests recover the per-member type behind a ``@bN`` name.
    batch_base: Optional[str] = dataclasses.field(default=None, compare=False)
    # (kind, width) -> molded duration; cost models are pure so the value is
    # computed (and validated) once.  Excluded from eq/repr; mutating a dict
    # inside a frozen dataclass is fine.
    _dur_cache: dict = dataclasses.field(default_factory=dict, init=False,
                                         repr=False, compare=False)
    # (n, member_cost) -> derived batched type (see ``batched()``)
    _batch_cache: dict = dataclasses.field(default_factory=dict, init=False,
                                           repr=False, compare=False)

    def duration(self, kind: str, width: int) -> float:
        """Unperturbed molded duration (the DES divides this by the
        time-varying rate)."""
        d = self._dur_cache.get((kind, width))
        if d is None:
            if kind not in self.serial_time:
                raise KeyError(f"{self.name}: no cost for partition kind {kind!r}")
            eff = self.efficiency(width)
            if not 0.0 < eff <= 1.5:
                raise ValueError(f"{self.name}: efficiency({width})={eff} out of (0,1.5]")
            d = self.serial_time[kind] / (width * eff)
            self._dur_cache[(kind, width)] = d
        return d

    def batched(self, n: int, member_cost: float) -> "TaskType":
        """The cost model of ``n`` of these tasks fused into one dispatch
        (continuous batching): batched decode is memory-bound, so each
        member past the first adds only a ``member_cost`` fraction of the
        base time rather than a full serial repeat.  ``n == 1`` returns
        this type unchanged (the ``max_batch=1`` degeneracy pin); ``n > 1``
        names the derived type ``{name}@b{bucket}`` with a power-of-two
        bucket so the PTT learns batched-dispatch throughput per size
        class, not per-token time.  Cached per (n, member_cost)."""
        if n <= 1:
            return self
        key = (n, member_cost)
        bt = self._batch_cache.get(key)
        if bt is None:
            scale = 1.0 + member_cost * (n - 1)
            bt = TaskType(
                f"{self.name}@b{batch_bucket(n)}",
                {k: v * scale for k, v in self.serial_time.items()},
                efficiency=self.efficiency, bw_demand=self.bw_demand,
                mem_sensitivity=self.mem_sensitivity, noise=self.noise,
                spike_prob=self.spike_prob, spike_mag=self.spike_mag,
                batch_base=self.name)
            self._batch_cache[key] = bt
        return bt


def batch_bucket(n: int) -> int:
    """Smallest power of two >= n — the PTT size class of an n-member
    batched dispatch (``decode@b8`` covers sizes 5-8, etc.)."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    return 1 << (n - 1).bit_length()


_task_ids = itertools.count()


@dataclasses.dataclass
class Task:
    """One DAG node.  ``payload`` is only used by the real runtime: a
    callable ``payload(width) -> None`` that does the actual work."""

    type: TaskType
    priority: Priority = Priority.LOW
    payload: Optional[Callable[[int], None]] = None
    tid: int = dataclasses.field(default_factory=lambda: next(_task_ids))
    # Extra positional arguments appended to the payload call —
    # ``payload(width, *args)`` — so hot-path task factories can share one
    # bound method instead of allocating a closure per task.
    args: tuple = ()

    # Continuous-batching state (see core/queues.py BatchingConfig and
    # SchedulingKernel.form_dispatch).  ``batch_key`` marks a LOW task as
    # coalescible: when an engine dequeues it with batching enabled, queued
    # tasks with the same key join it as ``batch_members`` and the dispatch
    # is re-typed via ``TaskType.batched``.  Members never execute alone —
    # they ride the dispatch through place/commit and get their successors
    # walked at the dispatch's commit.
    batch_key: Optional[str] = None
    batch_members: Optional[list["Task"]] = None

    # DAG linkage
    children: list["Task"] = dataclasses.field(default_factory=list)
    n_deps: int = 0               # unsatisfied input dependencies
    # Dynamic-DAG hook: called on commit; may create & return new tasks
    # (paper §2: tasks may conditionally insert new tasks at runtime).
    on_commit: Optional[Callable[["Task"], list["Task"]]] = None

    # Scheduling state (filled in by the engines)
    bound_place: Optional[ExecutionPlace] = None   # binding decision (high prio)
    place: Optional[ExecutionPlace] = None         # final execution place
    t_ready: float = -1.0
    t_start: float = -1.0
    t_end: float = -1.0

    # Load-accounting state (see ``core/lifecycle.py``): the estimated
    # execution seconds this task contributes to its queue's outstanding
    # work while it sits in a WSQ.  Stamped by the kernel at wake/requeue
    # when load tracking is on; 0.0 (the default) contributes nothing, so
    # untracked runs never touch it.
    load_est: float = 0.0

    # Preemption state (see ``repro.core.preemption``): fraction of the
    # place-normalized work still outstanding (checkpointed progress keeps
    # it < 1.0 across re-placements; "restart" kills leave it at 1.0), and
    # how many times this task has been preempted.
    resume_frac: float = 1.0
    preempt_count: int = 0
    # Threaded-engine revocation signal: while the task executes, this is
    # the current execution's ``threading.Event``; it is set when the
    # task's partition is revoked mid-run.  A *cooperative* payload may
    # poll it and checkpoint by returning the fraction of its outstanding
    # work completed (see core/runtime.py); payloads that ignore it run
    # to completion in the grace window.  None outside execution (and
    # always None in the DES, which preempts running tasks directly).
    revoke_signal: Optional[object] = None

    # Fault-injection / recovery state (see ``repro.core.faults``; all
    # inert without a FaultModel attached).  ``fault_seq`` is the task's
    # deterministic position in the fault draw stream (assigned by
    # ``FaultState.register_dag``); ``fault_count`` counts failed
    # executions (injected fail-stops and real payload exceptions alike)
    # and doubles as the retry-attempt index.  A hedged HIGH task and its
    # speculative duplicate point at each other via ``hedge_dup`` /
    # ``hedge_of``; ``committed`` marks the logical task's first commit
    # (first copy wins, the other is suppressed).
    fault_seq: Optional[int] = None
    fault_count: int = 0
    hedge_of: Optional["Task"] = None      # set on the duplicate only
    hedge_dup: Optional["Task"] = None     # set on the original only
    hedge_launched: bool = False
    committed: bool = False

    def add_child(self, child: "Task") -> "Task":
        self.children.append(child)
        child.n_deps += 1
        return child

    def __hash__(self) -> int:
        return self.tid

    def __repr__(self) -> str:
        p = "H" if self.priority == Priority.HIGH else "L"
        return f"Task<{self.tid}:{self.type.name}:{p}>"


# ---------------------------------------------------------------------------
# The paper's three synthetic node kernels (§4.2.2).
#
# Calibration notes (TX2): Denver ~2x A57 on dense GEMM; A57 L1d is 32 KB vs
# Denver 64 KB, so matmul tiles of 64/80 (48/75 KB working set) spill A57 L1
# and run at a lower per-element rate there; tile 96 spills both L1s into the
# 2 MB shared L2.  Short tasks (tile 32 -> ~10 us) have noisy measurements,
# which is what makes the PTT weight ratio matter in the paper's Fig. 8.
# ---------------------------------------------------------------------------

def _compute_eff(w: int) -> float:
    return {1: 1.0, 2: 0.95, 4: 0.90, 5: 0.90, 8: 0.85, 10: 0.85, 16: 0.80}.get(w, 0.8)


def _memory_eff(w: int) -> float:
    # Streaming: molding widens the stream but shares one memory pipe; the
    # win is mainly *not* co-running w independent streams (contention model).
    return {1: 1.0, 2: 0.80, 4: 0.60, 5: 0.55, 8: 0.45, 10: 0.40, 16: 0.35}.get(w, 0.3)


def _cache_eff(w: int) -> float:
    # Cache-intensive: pooling the shared L2 gives slightly superlinear
    # efficiency at the cluster width.
    return {1: 1.0, 2: 1.05, 4: 1.10, 5: 1.05, 8: 0.95, 10: 0.90, 16: 0.85}.get(w, 0.8)


# per-(kind) GEMM rate in FLOP/s, by tile regime: fits-L1 / spills-to-L2.
# Denver's wide 7-way core is ~3x an A57 on dense fp32 GEMM.
_MM_RATE = {
    "denver": {"l1": 9.0e9, "l2": 7.5e9},
    "a57": {"l1": 3.0e9, "l2": 1.9e9},
    "haswell": {"l1": 3.4e10, "l2": 2.9e10},
    "pod": {"l1": 1.97e14, "l2": 1.80e14},
    # previous-gen pod slice (v4-class): ~0.45x the dense-GEMM rate of the
    # current generation — the static asymmetry of a mixed TPU fleet
    "pod_v4": {"l1": 0.90e14, "l2": 0.82e14},
}
_L1_BYTES = {"denver": 64 * 1024, "a57": 32 * 1024, "haswell": 32 * 1024,
             "pod": 1 << 60, "pod_v4": 1 << 60}


def matmul_type(tile: int = 64) -> TaskType:
    """Compute-intensive GEMM node; per-task tile NxN fp32 (paper: 64)."""
    flops = 2.0 * tile ** 3
    wset = 3 * 4 * tile * tile
    serial = {}
    for kind, rates in _MM_RATE.items():
        regime = "l1" if wset <= _L1_BYTES[kind] else "l2"
        serial[kind] = flops / rates[regime]
    # Molding a tiny GEMM across cores pays a sync cost comparable to the
    # work itself; the efficiency curve improves with tile size.
    if tile <= 64:
        eff = lambda w: {1: 1.0, 2: 0.72, 4: 0.40, 5: 0.36, 8: 0.28,
                         10: 0.25, 16: 0.20}.get(w, 0.2)
    elif tile <= 96:
        eff = lambda w: {1: 1.0, 2: 0.85, 4: 0.65, 5: 0.60, 8: 0.50,
                         10: 0.45, 16: 0.40}.get(w, 0.4)
    else:
        eff = _compute_eff
    # tile 32 -> ~10 us tasks: timer noise is a large fraction of the reading
    # and OS jitter shows up as multi-x spikes; longer tasks average it out.
    noise = {32: 0.20, 64: 0.06, 80: 0.04, 96: 0.03}.get(tile, 0.05)
    spike_p = {32: 0.08, 64: 0.02, 80: 0.01, 96: 0.01}.get(tile, 0.01)
    spike_m = {32: 6.0, 64: 2.0, 80: 1.5, 96: 1.5}.get(tile, 1.5)
    return TaskType(f"matmul{tile}", serial, efficiency=eff,
                    bw_demand=0.05e9, mem_sensitivity=0.15, noise=noise,
                    spike_prob=spike_p, spike_mag=spike_m)


def copy_type(tile: int = 1024) -> TaskType:
    """Memory-intensive streaming copy; tile x tile fp32 read+write.
    Single-core effective stream bandwidth (TX2 ~3 GB/s class)."""
    bytes_moved = 2.0 * 4.0 * tile * tile
    bw = {"denver": 3.5e9, "a57": 2.5e9, "haswell": 1.2e10, "pod": 8.19e11,
          "pod_v4": 3.7e11}
    return TaskType(
        f"copy{tile}", {k: bytes_moved / b for k, b in bw.items()},
        efficiency=_memory_eff,
        bw_demand=3.0e9, mem_sensitivity=1.0, noise=0.03,
    )


def stencil_type(tile: int = 1024) -> TaskType:
    """Cache-intensive 5-point stencil over a tile x tile fp32 grid."""
    flops = 5.0 * tile * tile * 4      # 4 sweeps per task
    rate = {"denver": 5.5e9, "a57": 2.8e9, "haswell": 2.2e10, "pod": 9.0e13,
            "pod_v4": 4.0e13}
    return TaskType(
        f"stencil{tile}", {k: flops / r for k, r in rate.items()},
        efficiency=_cache_eff,
        bw_demand=2.0e9, mem_sensitivity=0.5, noise=0.03,
    )


def mpi_exchange_type(boundary_kb: float = 64.0) -> TaskType:
    """Ghost-cell exchange for the distributed 2D Heat app.  Message passing
    is single-core work, but reserving a width-2 place keeps the co-located
    cache quiet, which measurably helps MPI (paper §5.4 citing [25]) —
    modeled as a small efficiency credit at width 2."""
    t = boundary_kb * 1024 / 1.2e9     # FDR IB effective pt2pt + sw overhead
    eff = lambda w: {1: 1.0, 2: 0.56}.get(w, 1.0 / w)
    return TaskType(
        "mpi_exchange",
        {"haswell": t, "denver": t, "a57": t, "pod": t / 50,
         "pod_v4": t / 25},
        efficiency=eff, bw_demand=1.0e9, mem_sensitivity=0.8, noise=0.05,
    )


def kmeans_map_type(points: int, dims: int, k: int) -> TaskType:
    """K-means assignment step over a chunk of points (data-parallel map)."""
    flops = 3.0 * points * dims * k
    rate = {"haswell": 2.6e10, "denver": 7.0e9, "a57": 3.5e9, "pod": 1.5e14,
            "pod_v4": 6.8e13}
    return TaskType(
        f"kmeans_map{points}x{dims}x{k}",
        {kind: flops / r for kind, r in rate.items()},
        efficiency=_compute_eff, bw_demand=4.0e9, mem_sensitivity=0.4,
        noise=0.04,
    )


def kmeans_reduce_type(k: int, dims: int, chunks: int) -> TaskType:
    """Centroid update (reduction) — the largest serial unit, marked HIGH."""
    flops = 2.0 * k * dims * chunks * 50
    rate = {"haswell": 1.2e10, "denver": 5.0e9, "a57": 2.5e9, "pod": 1.0e14,
            "pod_v4": 4.5e13}
    return TaskType(
        f"kmeans_reduce{k}x{dims}",
        {kind: flops / r for kind, r in rate.items()},
        efficiency=lambda w: {1: 1.0, 2: 0.8}.get(w, 0.6), bw_demand=1.0e9,
        mem_sensitivity=0.3, noise=0.04,
    )
