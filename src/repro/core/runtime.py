"""Real threaded executor — the XiTAO analogue running actual payloads.

Unlike the simulator, nothing here uses cost models: workers execute the
task's ``payload(width)`` callable (typically a jitted JAX kernel), measure
wall time, and feed the *measured* time into the PTT.  Scheduling decisions
come from the same :class:`~.lifecycle.SchedulingKernel` (split
HIGH-FIFO/LOW-LIFO work-stealing queues, assembly queues, seeded
steal-victim selection, wake/requeue placement, PTT feedback) that drives
the discrete-event simulator — this module is only the *threaded driver*:
worker threads, barriers, wall-clock time, and payload execution.  Feature
parity with the DES therefore holds by construction: priority-aware
dequeue, seeded tie-break streams (``ptt_tiebreak="seeded"``,
``ptt_revisit``), LiveView-masked placement, and revocation.

Interference can be injected for tests/demos via ``slowdown``: a mapping
core -> factor; a worker on a slowed core sleeps ``duration*(factor-1)``
after the payload, emulating a co-runner stealing cycles.  (On this
container there is a single physical CPU, so *physical* contention cannot
demonstrate asymmetry; injected slowdown exercises the identical code
paths the scheduler would see on real hardware.)

Molded execution: the leader runs the payload; member cores block on the
task barrier for its duration (XiTAO's simplification: "each entry of the
PTT keeps track of the execution time of the task, as observed by the
leader core").

Open-loop serving mode
----------------------
``start()`` launches the workers immediately and keeps them alive while
requests trickle in (continuous submission); ``drain(timeout)`` stops
accepting, waits for the queues to empty, and returns the metrics.  The
batch-mode ``submit(dag); run()`` path is unchanged (it is exactly
``start-without-accepting`` + ``drain``).

Wall-clock preemption
---------------------
An optional :class:`~.preemption.PreemptionModel` attaches revoke/restore
episodes whose times are interpreted as *wall seconds since run start*,
fired by a timer thread.  At a revoke edge (all under the runtime lock):

1. the partition's cores are marked down and the scheduler receives the
   interned :class:`~.places.LiveView`, so every subsequent wake-time
   search is restricted to surviving places;
2. placed-but-unstarted assignments in the partition's AQs are cancelled
   and their tasks displaced; the partition's WSQs drain;
3. displaced work re-places on the survivors **HIGH tasks first** via the
   kernel's requeue path (the critical path recovers before bulk work);
4. *running* payloads cannot be killed (they are Python frames on worker
   threads) — they get a grace window, exactly the 30-second spot-VM
   signature: the assignment's ``revoked`` event is set, and a
   *cooperative* payload may checkpoint by returning the fraction of its
   outstanding work completed (a float in [0, 1)).  Under
   ``preempt="checkpoint"`` that fraction folds into ``task.resume_frac``
   (which the payload honors on its next execution by skipping completed
   work); under ``"restart"`` the partial progress is discarded and
   counted in ``work_lost_s``.  Non-cooperative payloads simply finish
   and commit — work done during the grace window is work kept.

At a restore edge the cores re-enter the worker loop and steal their way
back to work.  With no model attached every preemption code path is
behind a ``None`` check.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .dag import DAG
from .lifecycle import SchedulingKernel, split_by_priority
from .metrics import RunMetrics, TaskRecord
from .preemption import PreemptionModel
from .schedulers import Scheduler
from .task import Task


class _Assigned:
    __slots__ = ("task", "place", "barrier", "started", "done", "cancelled",
                 "revoked", "partial")

    def __init__(self, task, place):
        self.task = task
        self.place = place
        self.barrier = threading.Barrier(place.width)
        self.started = False            # some member pulled it (uncancellable)
        self.done = threading.Event()
        self.cancelled = False          # displaced by a revoke before start
        self.revoked = threading.Event()   # cooperative-checkpoint signal
        self.partial = None             # fraction done when preempted, else None


class ThreadedRuntime:
    def __init__(self, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 idle_sleep: float = 2e-3,
                 preemption: Optional[PreemptionModel] = None):
        # idle_sleep is only a fallback poll: every work arrival (wake,
        # assignment, requeue, restore) notifies the condition variable,
        # so idle workers do not need a tight poll — 1e-4 here made eight
        # idle workers busy-poll the lock at 10 kHz and starve the
        # payloads themselves on small containers
        self.sched = scheduler
        self.topo = scheduler.topology
        self.kernel = SchedulingKernel(scheduler, now=self._now)
        self.queues = self.kernel.queues
        self.aq = self.queues.aq        # per-core deques of _Assigned
        self.slowdown = dict(slowdown or {})
        self.idle_sleep = idle_sleep
        self.preemption = preemption
        n = self.topo.n_cores
        self.lock = threading.Lock()
        self.work_cv = threading.Condition(self.lock)
        self.outstanding = 0
        self.t0: Optional[float] = None
        self.metrics = RunMetrics(n_cores=n)
        self.stop = False
        self._accepting = False         # True between start() and drain()
        self._started = False
        self._threads: list[threading.Thread] = []
        self._timer: Optional[threading.Thread] = None
        self._core_up = [True] * n
        self._down_parts: set[int] = set()
        self._ckpt = (preemption is not None
                      and preemption.preempt == "checkpoint")
        self.preempt_events = 0
        self.tasks_preempted = 0
        self.work_lost = 0.0

    def _now(self) -> float:
        return 0.0 if self.t0 is None else time.perf_counter() - self.t0

    # -- submission -----------------------------------------------------------
    def _wake(self, task: Task, waker_core: int) -> None:
        with self.work_cv:
            self._wake_locked(task, waker_core)
            self.work_cv.notify_all()

    def _wake_locked(self, task: Task, waker_core: int) -> None:
        core = self.kernel.wake(task, waker_core)
        if not self._core_up[core]:
            # a leader committing its grace-window payload on a revoked
            # partition wakes dependents — they must land on a live core
            live = self.kernel.live_cores()
            rng = self.sched.rng
            core = live[rng.randrange(len(live))] if len(live) > 1 else live[0]
        self.queues.push(task, core)
        self.outstanding += 1

    def submit(self, dag: DAG) -> None:
        if self.t0 is None:
            self.t0 = time.perf_counter()
        for root in dag.roots:
            self._wake(root, waker_core=0)

    # -- worker ---------------------------------------------------------------
    def _pull(self, core: int) -> Optional[_Assigned]:
        with self.lock:
            # 1. own AQ head (down cores still finish work already placed
            #    on them — the grace window)
            if self.aq[core]:
                rec = self.aq[core][0]
                rec.started = True
                return rec
            if not self._core_up[core]:
                return None
            # 2. own WSQ: oldest HIGH first under priority dequeue, else
            #    newest LOW (plain work-stealing LIFO)
            task = self.queues.pop_local(core)
            if task is None:
                # 3. steal: most-loaded victim, seeded tie-break, FIFO end,
                #    re-run of the place search at the thief
                victim = self.queues.pick_victim(core, self.sched.rng)
                if victim < 0:
                    return None
                task = self.queues.steal_pop(victim)
                self.kernel.on_steal(task)
            return self._assign(task, core)

    def _assign(self, task: Task, core: int) -> _Assigned:
        # caller holds self.lock
        place = self.kernel.choose_place(task, core)
        rec = _Assigned(task, place)
        for c in place.cores:
            self.aq[c].append(rec)
        self.work_cv.notify_all()
        head = self.aq[core][0]
        head.started = True
        return head

    def _execute(self, rec: _Assigned, core: int) -> None:
        is_leader = core == rec.place.leader
        rec.barrier.wait()        # all members rendezvous
        if is_leader:
            t_start = self._now()
            rec.task.t_start = t_start
            ret = None
            if rec.task.payload is not None:
                rec.task.revoke_signal = rec.revoked
                try:
                    ret = rec.task.payload(rec.place.width)
                finally:
                    rec.task.revoke_signal = None
            factor = max((self.slowdown.get(c, 1.0) for c in rec.place.cores),
                         default=1.0)
            if factor > 1.0:
                dur = self._now() - t_start
                time.sleep(dur * (factor - 1.0))
            rec.partial = self._partial_fraction(rec, ret)
            rec.done.set()
        else:
            rec.done.wait()
        rec.barrier.wait()
        if is_leader:
            if rec.partial is None:
                self._commit(rec)
            else:
                self._requeue_preempted(rec)

    @staticmethod
    def _partial_fraction(rec: _Assigned, ret) -> Optional[float]:
        """A cooperative payload answering a revocation signal returns the
        fraction of its *outstanding* work it completed (float in [0, 1));
        anything else — including payloads that never look at the signal —
        means the task ran to completion."""
        if (rec.revoked.is_set() and isinstance(ret, float)
                and 0.0 <= ret < 1.0):
            return ret
        return None

    def _requeue_preempted(self, rec: _Assigned) -> None:
        """A checkpointed (or killed-and-restarted) payload: account its
        progress and hand the task back to the scheduler over the live
        view.  ``outstanding`` is untouched — the task is still pending."""
        task = rec.task
        dur = self._now() - task.t_start
        with self.work_cv:
            for c in rec.place.cores:
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            if self._ckpt:
                # completed fraction of this attempt carries over; the
                # payload reads task.resume_frac on its next execution.
                # The resume penalty folds in here as extra outstanding
                # work, mirroring the DES charging full*(resume_frac +
                # penalty) at the next start (a near-zero-progress
                # checkpoint costs slightly more than its remainder, in
                # both engines).
                penalty = (self.preemption.resume_penalty
                           if self.preemption is not None else 0.0)
                task.resume_frac = (task.resume_frac * (1.0 - rec.partial)
                                    + penalty)
            else:
                self.work_lost += dur
            task.preempt_count += 1
            self.tasks_preempted += 1
            self.queues.push(task, self.kernel.requeue_displaced(task))
            self.work_cv.notify_all()

    def _commit(self, rec: _Assigned) -> None:
        task = rec.task
        task.t_end = self._now()
        task.place = rec.place
        observed = task.t_end - task.t_start
        self.kernel.ptt_feedback(task, rec.place, observed)
        with self.lock:
            for c in rec.place.cores:
                # remove this record from each member AQ (it is at/near head)
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            self.metrics.record(TaskRecord(
                type_name=task.type.name, priority=int(task.priority),
                leader=rec.place.leader, width=rec.place.width,
                t_ready=task.t_ready, t_start=task.t_start, t_end=task.t_end))
        for ready in self.kernel.commit_successors(task, lock=self.lock):
            self._wake(ready, rec.place.leader)
        with self.work_cv:
            self.outstanding -= 1
            self.work_cv.notify_all()

    def _worker(self, core: int) -> None:
        while True:
            with self.lock:
                if self.stop:
                    return
            rec = self._pull(core)
            if rec is None:
                with self.work_cv:
                    if self.stop or (self.outstanding == 0
                                     and not self._accepting):
                        return
                    self.work_cv.wait(timeout=self.idle_sleep)
                continue
            if not rec.done.is_set() or core == rec.place.leader:
                self._execute(rec, core)

    # -- wall-clock preemption ------------------------------------------------
    def _preemption_driver(self) -> None:
        """Timer thread: fire revoke/restore edges at their wall-clock
        offsets from run start (restores sort before revokes at equal
        times, like the DES event queue)."""
        edges = sorted(
            [(t0, 1, pidx) for pidx, t0, _ in self.preemption.episodes]
            + [(t1, 0, pidx) for pidx, _, t1 in self.preemption.episodes])
        for t, is_revoke, pidx in edges:
            while not self.stop:
                dt = t - self._now()
                if dt <= 0:
                    break
                time.sleep(min(dt, 0.01))
            if self.stop:
                return
            with self.work_cv:
                if is_revoke:
                    self._revoke_locked(pidx)
                else:
                    self._restore_locked(pidx)
                self.work_cv.notify_all()

    def _revoke_locked(self, pidx: int) -> None:
        part = self.topo.partitions[pidx]
        self._down_parts.add(pidx)
        self.sched.live = self.topo.live_view(frozenset(self._down_parts))
        for c in part.cores:
            self._core_up[c] = False
        self.preempt_events += 1
        displaced: list[Task] = []
        # placed-but-unstarted assignments lose their place (no member has
        # entered the barrier, so cancelling cannot strand anyone); started
        # ones get the cooperative revocation signal and their grace window
        seen: set[int] = set()
        for c in part.cores:
            for rec in self.aq[c]:
                if rec.started:
                    rec.revoked.set()
                elif not rec.cancelled:
                    rec.cancelled = True
                    if rec.task.tid not in seen:
                        seen.add(rec.task.tid)
                        displaced.append(rec.task)
            kept = [r for r in self.aq[c] if not r.cancelled]
            self.aq[c].clear()
            self.aq[c].extend(kept)
        # ready tasks drain in steal order; HIGH tasks re-place first
        displaced.extend(self.queues.drain_wsq(part.cores))
        high, low = split_by_priority(displaced)
        for task in high:
            self.queues.push(task, self.kernel.requeue_displaced(task))
        for task in low:
            self.queues.push(task, self.kernel.requeue_displaced(task))

    def _restore_locked(self, pidx: int) -> None:
        self._down_parts.discard(pidx)
        self.sched.live = (None if not self._down_parts else
                           self.topo.live_view(frozenset(self._down_parts)))
        for c in self.topo.partitions[pidx].cores:
            self._core_up[c] = True

    # -- run ------------------------------------------------------------------
    def _launch(self) -> None:
        if self._started:
            return
        self._started = True
        if self.t0 is None:
            self.t0 = time.perf_counter()
        self._threads = [
            threading.Thread(target=self._worker, args=(c,), daemon=True)
            for c in range(self.topo.n_cores)]
        for th in self._threads:
            th.start()
        if self.preemption is not None and self.preemption.episodes:
            self._timer = threading.Thread(target=self._preemption_driver,
                                           daemon=True)
            self._timer.start()

    def start(self) -> None:
        """Open-loop mode: launch workers now and keep accepting
        submissions until :meth:`drain`."""
        self._accepting = True
        self._launch()

    def drain(self, timeout: float = 120.0) -> RunMetrics:
        """Stop accepting work, wait for the queues to empty (or the
        deadline), shut the workers down and return the metrics."""
        deadline = time.monotonic() + timeout
        with self.work_cv:
            self._accepting = False
            self.work_cv.notify_all()
            while self.outstanding > 0 and time.monotonic() < deadline:
                self.work_cv.wait(timeout=0.05)
            self.stop = True
            self.work_cv.notify_all()
        for th in self._threads:
            th.join(timeout=5.0)
        if self._timer is not None:
            # a revoke edge racing the end of the run must land (or bail
            # on stop) *before* end_run clears the availability mask —
            # otherwise it would re-poison sched.live for a later run
            self._timer.join(timeout=5.0)
        self.kernel.end_run()
        self.metrics.finish(self._now())
        self.metrics.preempt_events = self.preempt_events
        self.metrics.tasks_preempted = self.tasks_preempted
        self.metrics.work_lost_s = self.work_lost
        return self.metrics

    def run(self, timeout: float = 120.0) -> RunMetrics:
        """Batch mode: run everything already submitted to completion."""
        self._launch()
        return self.drain(timeout=timeout)


def run_threaded(dag: DAG, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 preemption: Optional[PreemptionModel] = None,
                 timeout: float = 120.0) -> RunMetrics:
    rt = ThreadedRuntime(scheduler, slowdown=slowdown, preemption=preemption)
    rt.submit(dag)
    return rt.run(timeout=timeout)
