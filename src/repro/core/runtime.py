"""Real threaded executor — the XiTAO analogue running actual payloads.

Unlike the simulator, nothing here uses cost models: workers execute the
task's ``payload(width)`` callable (typically a jitted JAX kernel), measure
wall time, and feed the *measured* time into the PTT.  Scheduling decisions
come from the same :class:`~.lifecycle.SchedulingKernel` (split
HIGH-FIFO/LOW-LIFO work-stealing queues, assembly queues, seeded
steal-victim selection, wake/requeue placement, PTT feedback) that drives
the discrete-event simulator — this module is only the *threaded driver*:
worker threads, barriers, wall-clock time, and payload execution.  Feature
parity with the DES therefore holds by construction: priority-aware
dequeue, seeded tie-break streams (``ptt_tiebreak="seeded"``,
``ptt_revisit``), LiveView-masked placement, and revocation.

Interference can be injected for tests/demos via ``slowdown``: a mapping
core -> factor; a worker on a slowed core sleeps ``duration*(factor-1)``
after the payload, emulating a co-runner stealing cycles.  (On this
container there is a single physical CPU, so *physical* contention cannot
demonstrate asymmetry; injected slowdown exercises the identical code
paths the scheduler would see on real hardware.)

Molded execution: the leader runs the payload; member cores block on the
task barrier for its duration (XiTAO's simplification: "each entry of the
PTT keeps track of the execution time of the task, as observed by the
leader core").

Open-loop serving mode
----------------------
``start()`` launches the workers immediately and keeps them alive while
requests trickle in (continuous submission); ``drain(timeout)`` stops
accepting, waits for the queues to empty, and returns the metrics.  The
batch-mode ``submit(dag); run()`` path is unchanged (it is exactly
``start-without-accepting`` + ``drain``).

Wall-clock preemption
---------------------
An optional :class:`~.preemption.PreemptionModel` attaches revoke/restore
episodes whose times are interpreted as *wall seconds since run start*,
fired by a timer thread.  At a revoke edge (all under the runtime lock):

1. the partition's cores are marked down and the scheduler receives the
   interned :class:`~.places.LiveView`, so every subsequent wake-time
   search is restricted to surviving places;
2. placed-but-unstarted assignments in the partition's AQs are cancelled
   and their tasks displaced; the partition's WSQs drain;
3. displaced work re-places on the survivors **HIGH tasks first** via the
   kernel's requeue path (the critical path recovers before bulk work);
4. *running* payloads cannot be killed (they are Python frames on worker
   threads) — they get a grace window, exactly the 30-second spot-VM
   signature: the assignment's ``revoked`` event is set, and a
   *cooperative* payload may checkpoint by returning the fraction of its
   outstanding work completed (a float in [0, 1)).  Under
   ``preempt="checkpoint"`` that fraction folds into ``task.resume_frac``
   (which the payload honors on its next execution by skipping completed
   work); under ``"restart"`` the partial progress is discarded and
   counted in ``work_lost_s``.  Non-cooperative payloads simply finish
   and commit — work done during the grace window is work kept.

At a restore edge the cores re-enter the worker loop and steal their way
back to work.  With no model attached every preemption code path is
behind a ``None`` check.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .dag import DAG
from .faults import FaultModel, FaultState, RecoveryPolicy
from .lifecycle import split_by_priority
from .metrics import RunMetrics, TaskRecord
from .preemption import PreemptionModel
from .queues import BatchingConfig
from .schedulers import Scheduler
from .shards import ShardingSpec, make_control_plane
from .task import Priority, Task


class _Assigned:
    __slots__ = ("task", "place", "barrier", "started", "done", "cancelled",
                 "revoked", "partial", "fault", "error", "straggle_flagged")

    def __init__(self, task, place):
        self.task = task
        self.place = place
        self.barrier = threading.Barrier(place.width)
        self.started = False            # some member pulled it (uncancellable)
        self.done = threading.Event()
        self.cancelled = False          # displaced by a revoke before start
        self.revoked = threading.Event()   # cooperative-checkpoint signal
        self.partial = None             # fraction done when preempted, else None
        self.fault = None               # armed injected fail-stop, else None
        self.error = None               # real payload exception, else None
        self.straggle_flagged = False   # straggler monitor saw it already


class ThreadedRuntime:
    def __init__(self, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 idle_sleep: float = 2e-3,
                 preemption: Optional[PreemptionModel] = None,
                 faults: Optional[FaultModel] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 supervisor=None,
                 sharding: Optional[ShardingSpec] = None,
                 batching: Optional[BatchingConfig] = None):
        # idle_sleep is only a fallback poll: every work arrival (wake,
        # assignment, requeue, restore) notifies the condition variable,
        # so idle workers do not need a tight poll — 1e-4 here made eight
        # idle workers busy-poll the lock at 10 kHz and starve the
        # payloads themselves on small containers
        self.sched = scheduler
        self.topo = scheduler.topology
        # the control plane: the flat kernel, or one kernel per shard
        # behind the sharded plane (see core/shards.py).  Decision
        # *latency* here is real wall time — the worker threads pay it
        # inside the runtime lock — so unlike the DES nothing is modeled;
        # the rebalancer runs on its own timer thread.
        self.sharding = sharding
        self.kernel = make_control_plane(scheduler, now=self._now,
                                         sharding=sharding)
        self.queues = self.kernel.queues
        # continuous batching: a max_batch=1 config is the disabled path
        # by definition (the degeneracy pin), so normalize it to None here
        # — every batching branch below then stays dead code
        if batching is not None and not batching.enabled:
            batching = None
        if batching is not None and faults is not None and faults.enabled:
            raise ValueError("continuous batching with fault injection is "
                             "not supported: a batched dispatch has no "
                             "per-member retry semantics")
        self.batching = batching
        self.kernel.batching = batching
        self.aq = self.queues.aq        # per-core deques of _Assigned
        self.slowdown = dict(slowdown or {})
        self.idle_sleep = idle_sleep
        self.preemption = preemption
        n = self.topo.n_cores
        self.lock = threading.Lock()
        self.work_cv = threading.Condition(self.lock)
        self.outstanding = 0
        self.t0: Optional[float] = None
        self.metrics = RunMetrics(n_cores=n)
        self.stop = False
        self._accepting = False         # True between start() and drain()
        self._started = False
        self._threads: list[threading.Thread] = []
        self._timer: Optional[threading.Thread] = None
        self._rebalance_thread: Optional[threading.Thread] = None
        self._core_up = [True] * n
        self._down_cores: set[int] = set()
        self._ckpt = (preemption is not None
                      and preemption.preempt == "checkpoint")
        self.preempt_events = 0
        self.tasks_preempted = 0
        self.work_lost = 0.0

        # fault-injection state (inert without an *enabled* FaultModel; a
        # zero-probability model is normalized away, matching the DES).
        # Running payloads cannot be killed, so a threaded hedge loser runs
        # to completion and is suppressed at commit; hedging therefore
        # requires idempotent payloads (both copies may execute fully).
        if faults is not None and not faults.enabled:
            faults = None
        self.faults = faults
        self._fx = (FaultState(faults, recovery or RecoveryPolicy())
                    if faults is not None else None)
        self._inflight: dict[int, _Assigned] = {}   # tid -> leader-started rec
        self._timers: list[threading.Timer] = []    # pending retry backoffs
        self._straggler: Optional[threading.Thread] = None
        self._dead_workers: list[int] = []
        # duck-typed repro.runtime.ft.Supervisor (kept untyped: importing
        # repro.runtime from repro.core would be circular); workers beat
        # its heartbeat every pull-loop iteration and drain() polls check()
        self.supervisor = supervisor

    def _now(self) -> float:
        return 0.0 if self.t0 is None else time.perf_counter() - self.t0

    # -- submission -----------------------------------------------------------
    def _wake(self, task: Task, waker_core: int) -> None:
        with self.work_cv:
            self._wake_locked(task, waker_core)
            self.work_cv.notify_all()

    def _wake_locked(self, task: Task, waker_core: int) -> None:
        core = self.kernel.wake(task, waker_core)
        if not self._core_up[core]:
            # a leader committing its grace-window payload on a revoked
            # partition wakes dependents — they must land on a live core
            live = self.kernel.live_cores()
            rng = self.sched.rng
            core = live[rng.randrange(len(live))] if len(live) > 1 else live[0]
        self.queues.push(task, core)
        self.outstanding += 1

    def submit(self, dag: DAG) -> None:
        if self.t0 is None:
            self.t0 = time.perf_counter()
        if self._fx is not None:
            # same deterministic BFS numbering as the DES, so both engines
            # inject identical faults on the same DAG (cross-engine parity)
            self._fx.register_dag(dag)
        for root in dag.roots:
            self._wake(root, waker_core=0)

    # -- worker ---------------------------------------------------------------
    def _pull(self, core: int) -> Optional[_Assigned]:
        with self.lock:
            # 1. own AQ head (down cores still finish work already placed
            #    on them — the grace window)
            if self.aq[core]:
                rec = self.aq[core][0]
                rec.started = True
                return rec
            if not self._core_up[core]:
                return None
            while True:
                # 2. own WSQ: oldest HIGH first under priority dequeue,
                #    else newest LOW (plain work-stealing LIFO)
                task = self.queues.pop_local(core)
                stolen = False
                if task is None:
                    # 3. steal: most-loaded victim, seeded tie-break, FIFO
                    #    end, re-run of the place search at the thief
                    victim = self.queues.pick_victim(core, self.sched.rng)
                    if victim < 0:
                        return None
                    task = self.queues.steal_pop(victim)
                    stolen = True
                if (self._fx is not None
                        and (task.hedge_of or task).committed):
                    # the losing copy of a hedged pair, parked in a WSQ
                    # when the winner committed, resolves at pop
                    self.outstanding -= 1
                    self.work_cv.notify_all()
                    continue
                if stolen:
                    self.kernel.on_steal(task)
                if self.batching is not None and task.batch_key is not None:
                    # coalesce same-key queued LOW work from the queue the
                    # leader came out of (members were pushed beside it)
                    self.kernel.form_dispatch(task,
                                              victim if stolen else core)
                return self._assign(task, core)

    def _assign(self, task: Task, core: int) -> _Assigned:
        # caller holds self.lock
        place = self.kernel.choose_place(task, core)
        rec = _Assigned(task, place)
        for c in place.cores:
            self.aq[c].append(rec)
        self.work_cv.notify_all()
        head = self.aq[core][0]
        head.started = True
        return head

    def _execute(self, rec: _Assigned, core: int) -> None:
        is_leader = core == rec.place.leader
        rec.barrier.wait()        # all members rendezvous
        if is_leader:
            t_start = self._now()
            task = rec.task
            task.t_start = t_start
            fault = None
            if self._fx is not None:
                if task.hedge_of is None:
                    # hedge duplicates run clean (they exist to escape a
                    # degraded place); originals draw per-attempt faults
                    fault = self._fx.draw(task, t_start)
                with self.lock:
                    self._inflight[task.tid] = rec
            ret = None
            if task.payload is not None:
                task.revoke_signal = rec.revoked
                try:
                    ret = task.payload(rec.place.width, *task.args)
                except Exception as e:      # a raising payload must never
                    rec.error = e           # kill the leader thread: the
                                            # members would block forever
                finally:
                    task.revoke_signal = None
            if rec.error is None and task.batch_members:
                # queue-coalesced batch members execute inside the leader's
                # dispatch (real wall time; the commit feeds the total into
                # the batched type's PTT entry)
                for m in task.batch_members:
                    if m.payload is None:
                        continue
                    try:
                        m.payload(rec.place.width, *m.args)
                    except Exception as e:
                        rec.error = e
                        break
            factor = max((self.slowdown.get(c, 1.0) for c in rec.place.cores),
                         default=1.0)
            if factor > 1.0:
                dur = self._now() - t_start
                time.sleep(dur * (factor - 1.0))
            if fault is not None and rec.error is None:
                if fault.kind == "slow":
                    # the place silently degrades from frac onward: the
                    # remaining (1-frac) of the work runs factor x slower
                    dur = self._now() - t_start
                    time.sleep(dur * (1.0 - fault.frac)
                               * (fault.factor - 1.0))
                    with self.lock:
                        self.metrics.faults_failslow += 1
                else:
                    rec.fault = fault       # fail-stop: execution failed
            rec.partial = self._partial_fraction(rec, ret)
            rec.done.set()
        else:
            rec.done.wait()
        rec.barrier.wait()
        if is_leader:
            if self._fx is not None:
                with self.lock:
                    self._inflight.pop(rec.task.tid, None)
            if rec.error is not None or rec.fault is not None:
                self._fail(rec)
            elif rec.partial is None:
                self._commit(rec)
            else:
                self._requeue_preempted(rec)

    @staticmethod
    def _partial_fraction(rec: _Assigned, ret) -> Optional[float]:
        """A cooperative payload answering a revocation signal returns the
        fraction of its *outstanding* work it completed (float in [0, 1));
        anything else — including payloads that never look at the signal —
        means the task ran to completion."""
        if (rec.revoked.is_set() and isinstance(ret, float)
                and 0.0 <= ret < 1.0):
            return ret
        return None

    def _requeue_preempted(self, rec: _Assigned) -> None:
        """A checkpointed (or killed-and-restarted) payload: account its
        progress and hand the task back to the scheduler over the live
        view.  ``outstanding`` is untouched — the task is still pending."""
        task = rec.task
        if self._fx is not None and (task.hedge_of or task).committed:
            # a checkpointing hedge loser: the winner already committed
            # the logical task, so the checkpoint is worthless
            self._suppress(rec)
            return
        dur = self._now() - task.t_start
        with self.work_cv:
            for c in rec.place.cores:
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            if self._ckpt:
                # completed fraction of this attempt carries over; the
                # payload reads task.resume_frac on its next execution.
                # The resume penalty folds in here as extra outstanding
                # work, mirroring the DES charging full*(resume_frac +
                # penalty) at the next start (a near-zero-progress
                # checkpoint costs slightly more than its remainder, in
                # both engines).
                penalty = (self.preemption.resume_penalty
                           if self.preemption is not None else 0.0)
                task.resume_frac = (task.resume_frac * (1.0 - rec.partial)
                                    + penalty)
            else:
                self.work_lost += dur
            task.preempt_count += 1
            self.tasks_preempted += 1
            self.queues.push(task, self.kernel.requeue_displaced(task))
            self.work_cv.notify_all()

    def _commit(self, rec: _Assigned) -> None:
        task = rec.task
        src = task              # the logical task (successors, sojourn)
        if self._fx is not None:
            with self.lock:
                logical = task.hedge_of or task
                if logical.committed:
                    won = False
                else:
                    # first copy wins; nudge the loser's cooperative
                    # payload via the existing revocation channel (it
                    # cannot be killed — it suppresses at its own commit)
                    logical.committed = True
                    won = True
                    other = (logical if task.hedge_of is not None
                             else task.hedge_dup)
                    if other is not None and task.hedge_of is not None:
                        self.metrics.hedge_wins += 1
                    if other is not None:
                        loser = self._inflight.get(other.tid)
                        if loser is not None:
                            loser.revoked.set()
            if not won:
                self._suppress(rec)
                return
            src = task.hedge_of or task
        task.t_end = self._now()
        task.place = rec.place
        observed = task.t_end - task.t_start
        members = task.batch_members or ()
        with self.lock:
            # feedback rides the runtime lock: an online reshard() swaps
            # the plane's shard routing under this same lock, and the
            # routing read (kernels[shard_of_core[leader]]) must not
            # interleave with the swap
            if members:
                self.kernel.batch_feedback(task, rec.place, observed)
            else:
                self.kernel.ptt_feedback(task, rec.place, observed)
            for c in rec.place.cores:
                # remove this record from each member AQ (it is at/near head)
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            self.metrics.record(TaskRecord(
                type_name=task.type.name, priority=int(task.priority),
                leader=rec.place.leader, width=rec.place.width,
                t_ready=src.t_ready, t_start=task.t_start, t_end=task.t_end))
            if members:
                base = task.type.batch_base or task.type.name
                self.metrics.batches.append((task.type.name, tuple(sorted(
                    [base] + [m.type.name for m in members]))))
                for m in members:
                    m.t_start, m.t_end, m.place = (task.t_start, task.t_end,
                                                   rec.place)
        for ready in self.kernel.commit_successors(src, lock=self.lock):
            self._wake(ready, rec.place.leader)
        for m in members:
            for ready in self.kernel.commit_successors(m, lock=self.lock):
                self._wake(ready, rec.place.leader)
        with self.work_cv:
            self.outstanding -= 1 + len(members)
            self.work_cv.notify_all()

    # -- fault recovery (see ``core/faults.py``) ------------------------------
    def _fail(self, rec: _Assigned) -> None:
        """A failed execution — real payload exception or injected
        fail-stop.  Same recovery as the DES: PTT-penalize the failing
        place, retry after a seeded backoff, or fail permanently once the
        attempt budget is spent.  Hedge copies never retry."""
        task = rec.task
        dur = self._now() - task.t_start
        with self.lock:                 # vs reshard(): see _commit
            self.kernel.discharge(task)  # fault_feedback also discharges,
                                         # but a real payload exception with
                                         # no fault model must not leak load
            if self._fx is not None:
                self.kernel.fault_feedback(task, rec.place, dur,
                                           self._fx.policy.fail_penalty)
        with self.work_cv:
            for c in rec.place.cores:
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            if rec.fault is not None:
                self.metrics.faults_failstop += 1
                # the strike point was at frac of the work; only that
                # share of the wall time is work actually lost
                self.metrics.work_lost_faults_s += dur * rec.fault.frac
            else:
                # a real payload exception rides the same recovery path
                # but is not an *injected* fault — it is surfaced instead
                self.metrics.work_lost_faults_s += dur
                self.metrics.errors.append(
                    f"task {task.tid} ({task.type.name}) payload raised "
                    f"{type(rec.error).__name__}: {rec.error}")
            task.fault_count += 1
            if task.hedge_of is not None:
                # a speculative duplicate died; the original carries on
                task.hedge_of.hedge_dup = None
                self.outstanding -= 1
                self.work_cv.notify_all()
                return
            if task.hedge_dup is not None and not task.committed:
                # the original died with its duplicate still in flight —
                # leave recovery to the copy on the healthier place
                self.outstanding -= 1
                self.work_cv.notify_all()
                return
            can_retry = (self._fx is not None
                         and task.fault_count <= self._fx.policy.max_retries)
            if not can_retry:
                self.metrics.failed_tasks += 1
                self.metrics.errors.append(
                    f"task {task.tid} ({task.type.name}) failed permanently "
                    f"after {task.fault_count - 1} retries")
                # a batched dispatch (payload exception path; fault
                # injection is excluded up front) resolves its members too
                self.outstanding -= 1 + len(task.batch_members or ())
                self.work_cv.notify_all()
                return
            self.metrics.retries += 1
            timer = threading.Timer(self._fx.backoff(task), self._retry,
                                    args=(task,))
            timer.daemon = True
            self._timers.append(timer)
            timer.start()

    def _retry(self, task: Task) -> None:
        """Backoff expired: hand the failed task back to the scheduler
        over the live view (its failing place now PTT-penalized)."""
        with self.work_cv:
            if task.committed or self.stop:
                # the hedge twin won while we backed off, or the runtime
                # is shutting down — either way this copy resolves here
                self.outstanding -= 1
                self.work_cv.notify_all()
                return
            self.queues.push(task, self.kernel.requeue_displaced(task))
            self.work_cv.notify_all()

    def _suppress(self, rec: _Assigned) -> None:
        """The losing copy of a hedged pair ran to completion (or
        checkpointed) after the winner committed — running payloads
        cannot be killed, so the loser is dropped here and its wall time
        accounted as the hedge premium."""
        with self.lock:                 # vs reshard(): see _commit
            self.kernel.discharge(rec.task)
        dur = self._now() - rec.task.t_start
        with self.work_cv:
            for c in rec.place.cores:
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            self.metrics.work_hedged_s += dur
            self.outstanding -= 1
            self.work_cv.notify_all()

    def _straggler_driver(self) -> None:
        """Monitor thread: flag executions past ``k`` x their PTT
        expectation; launch a speculative duplicate for flagged HIGH
        tasks on the PTT-best place disjoint from the straggler's (the
        DES schedules exact straggle events instead of polling)."""
        pol = self._fx.policy
        while True:
            time.sleep(pol.straggler_poll_s)
            with self.lock:
                if self.stop:
                    return
                now = self._now()
                inflight = list(self._inflight.values())
            for rec in inflight:
                task = rec.task
                if (rec.done.is_set() or rec.straggle_flagged
                        or task.hedge_of is not None):
                    continue
                with self.lock:         # vs reshard(): see _commit
                    exp = self.kernel.expected_duration(task, rec.place)
                if exp <= 0.0 or now - task.t_start < pol.straggler_k * exp:
                    continue
                rec.straggle_flagged = True
                with self.lock:
                    self.metrics.stragglers += 1
                if (not pol.hedge or task.priority != Priority.HIGH
                        or task.hedge_launched or task.committed):
                    continue
                with self.lock:         # vs reshard(): see _commit
                    place = self.kernel.hedge_place(task,
                                                    set(rec.place.cores),
                                                    self._fx.hedge_rng)
                if place is None:
                    continue
                with self.work_cv:
                    if task.committed or task.hedge_launched:
                        continue
                    task.hedge_launched = True
                    dup = Task(type=task.type, priority=task.priority,
                               payload=task.payload)
                    dup.hedge_of = task
                    dup.bound_place = place   # honored at dequeue
                    task.hedge_dup = dup
                    dup.t_ready = now
                    self.metrics.hedges_launched += 1
                    self.outstanding += 1
                    self.queues.push(dup, place.leader)
                    self.work_cv.notify_all()

    def _worker(self, core: int) -> None:
        try:
            self._worker_loop(core)
        except BaseException as e:          # surface, never die silently:
            with self.work_cv:              # drain() reports the death
                self._dead_workers.append(core)
                self.metrics.errors.append(
                    f"worker {core} died: {type(e).__name__}: {e}")
                self.work_cv.notify_all()

    def _worker_loop(self, core: int) -> None:
        sup = self.supervisor
        while True:
            if sup is not None:
                sup.heartbeat.beat(core)
            with self.lock:
                if self.stop:
                    return
            rec = self._pull(core)
            if rec is None:
                with self.work_cv:
                    if self.stop or (self.outstanding == 0
                                     and not self._accepting):
                        return
                    self.work_cv.wait(timeout=self.idle_sleep)
                continue
            if not rec.done.is_set() or core == rec.place.leader:
                self._execute(rec, core)

    # -- wall-clock preemption ------------------------------------------------
    def _preemption_driver(self) -> None:
        """Timer thread: fire revoke/restore edges at their wall-clock
        offsets from run start (restores sort before revokes at equal
        times, like the DES event queue)."""
        edges = sorted(
            [(t0, 1, i) for i, (_, t0, _) in enumerate(self.preemption.episodes)]
            + [(t1, 0, i) for i, (_, _, t1) in enumerate(self.preemption.episodes)])
        for t, is_revoke, eidx in edges:
            while not self.stop:
                dt = t - self._now()
                if dt <= 0:
                    break
                time.sleep(min(dt, 0.01))
            if self.stop:
                return
            with self.work_cv:
                if is_revoke:
                    self._revoke_locked(eidx)
                else:
                    self._restore_locked(eidx)
                self.work_cv.notify_all()

    def _revoke_locked(self, eidx: int) -> None:
        cores = self.preemption.cores_of(eidx, self.topo)
        self._down_cores.update(cores)
        self.kernel.set_availability(frozenset(self._down_cores))
        for c in cores:
            self._core_up[c] = False
        self.preempt_events += 1
        displaced: list[Task] = []
        # placed-but-unstarted assignments lose their place (no member has
        # entered the barrier, so cancelling cannot strand anyone); started
        # ones get the cooperative revocation signal and their grace window
        seen: set[int] = set()
        down_set = set(cores)
        for c in cores:
            for rec in self.aq[c]:
                if rec.started:
                    rec.revoked.set()
                elif not rec.cancelled:
                    rec.cancelled = True
                    if rec.task.tid not in seen:
                        seen.add(rec.task.tid)
                        displaced.append(rec.task)
            kept = [r for r in self.aq[c] if not r.cancelled]
            self.aq[c].clear()
            self.aq[c].extend(kept)
        # a sub-pod revocation may leave a cancelled record's copies in
        # *live* siblings' AQs — prune them there too
        if seen:
            for c in set(self.topo.partition_of(cores[0]).cores) - down_set:
                if any(r.cancelled for r in self.aq[c]):
                    kept = [r for r in self.aq[c] if not r.cancelled]
                    self.aq[c].clear()
                    self.aq[c].extend(kept)
        # ready tasks drain in steal order; HIGH tasks re-place first
        displaced.extend(self.queues.drain_wsq(cores))
        high, low = split_by_priority(displaced)
        for task in high:
            self.queues.push(task, self.kernel.requeue_displaced(task))
        for task in low:
            self.queues.push(task, self.kernel.requeue_displaced(task))

    def _restore_locked(self, eidx: int) -> None:
        self._down_cores.difference_update(
            self.preemption.cores_of(eidx, self.topo))
        self.kernel.set_availability(frozenset(self._down_cores))
        for c in self.preemption.cores_of(eidx, self.topo):
            self._core_up[c] = True

    # -- cross-shard rebalancing ----------------------------------------------
    def _rebalance_driver(self) -> None:
        """Timer thread: run one deterministic rebalance round (the same
        :class:`~.shards.GlobalRebalancer` plan the DES executes) every
        ``rebalance_period_s`` wall seconds; migrated tasks land on their
        destination shard immediately — the overhead here is the real
        time the round takes under the lock."""
        period = self.sharding.rebalance_period_s
        t_next = self._now() + period
        while not self.stop:
            dt = t_next - self._now()
            if dt > 0:
                time.sleep(min(dt, 0.02))
                continue
            with self.work_cv:
                if self.stop:
                    return
                if self.outstanding > 0:
                    for task, dst in self.kernel.rebalancer.plan_round():
                        self.queues.push(task,
                                         self.kernel.migrate_in(task, dst))
                    self.work_cv.notify_all()
            t_next = self._now() + period

    def reshard(self, pods_per_shard: int) -> int:
        """Online re-sharding (sharded control plane only): regroup the
        pods into shards of ``pods_per_shard`` mid-run and land the
        rebalancer's catch-up migration round immediately.  Returns the
        number of tasks migrated by that round."""
        if getattr(self.kernel, "n_shards", 1) <= 1:
            raise ValueError("reshard() requires a sharded control plane")
        with self.work_cv:
            moves = self.kernel.reshard(pods_per_shard)
            for task, dst in moves:
                self.queues.push(task, self.kernel.migrate_in(task, dst))
            self.work_cv.notify_all()
        return len(moves)

    # -- run ------------------------------------------------------------------
    def _launch(self) -> None:
        if self._started:
            return
        self._started = True
        if self.t0 is None:
            self.t0 = time.perf_counter()
        self._threads = [
            threading.Thread(target=self._worker, args=(c,), daemon=True)
            for c in range(self.topo.n_cores)]
        for th in self._threads:
            th.start()
        if self.preemption is not None and self.preemption.episodes:
            self._timer = threading.Thread(target=self._preemption_driver,
                                           daemon=True)
            self._timer.start()
        if (getattr(self.kernel, "n_shards", 1) > 1
                and self.sharding.rebalance_period_s > 0.0):
            self._rebalance_thread = threading.Thread(
                target=self._rebalance_driver, daemon=True)
            self._rebalance_thread.start()
        if self._fx is not None:
            self._straggler = threading.Thread(target=self._straggler_driver,
                                               daemon=True)
            self._straggler.start()

    def start(self) -> None:
        """Open-loop mode: launch workers now and keep accepting
        submissions until :meth:`drain`."""
        self._accepting = True
        self._launch()

    def drain(self, timeout: float = 120.0) -> RunMetrics:
        """Stop accepting work, wait for the queues to empty (or the
        deadline), shut the workers down and return the metrics.  A
        worker-thread death or a timeout is *surfaced* in
        ``metrics.errors`` — an empty list is the "this run is
        trustworthy" signal; partial data never returns silently."""
        deadline = time.monotonic() + timeout
        step = 0
        with self.work_cv:
            self._accepting = False
            self.work_cv.notify_all()
            while self.outstanding > 0 and time.monotonic() < deadline:
                if self._dead_workers:
                    # a dead worker strands its barrier partners: no
                    # progress is coming, so bail out now, not at timeout
                    break
                self.work_cv.wait(timeout=0.05)
                if self.supervisor is not None:
                    step += 1
                    self.supervisor.check(step)
            if self.outstanding > 0:
                self.metrics.errors.append(
                    f"drain incomplete: {self.outstanding} tasks still "
                    f"outstanding"
                    + (f", workers {sorted(self._dead_workers)} dead"
                       if self._dead_workers else ""))
            self.stop = True
            self.work_cv.notify_all()
        for t in self._timers:
            t.cancel()              # pending retry backoffs die with the run
        for th in self._threads:
            th.join(timeout=5.0)
        if self._timer is not None:
            # a revoke edge racing the end of the run must land (or bail
            # on stop) *before* end_run clears the availability mask —
            # otherwise it would re-poison sched.live for a later run
            self._timer.join(timeout=5.0)
        if self._straggler is not None:
            self._straggler.join(timeout=5.0)
        if self._rebalance_thread is not None:
            self._rebalance_thread.join(timeout=5.0)
        if self.supervisor is not None:
            self.supervisor.check(step + 1)
            self.metrics.recovery_events.extend(
                f"{e.kind}@{e.step}: {e.detail}"
                for e in self.supervisor.events)
        self.kernel.end_run()
        self.metrics.finish(self._now())
        self.metrics.preempt_events = self.preempt_events
        self.metrics.tasks_preempted = self.tasks_preempted
        self.metrics.work_lost_s = self.work_lost
        if getattr(self.kernel, "n_shards", 1) > 1:
            self.metrics.migrations = self.kernel.migrations
            self.metrics.overflow_migrations = self.kernel.overflow_migrations
            self.metrics.rebalance_rounds = self.kernel.rebalance_rounds
            self.metrics.migrated_load_s = self.kernel.migrated_load_s
            self.metrics.reshard_rounds = self.kernel.reshard_rounds
        return self.metrics

    def run(self, timeout: float = 120.0) -> RunMetrics:
        """Batch mode: run everything already submitted to completion."""
        self._launch()
        return self.drain(timeout=timeout)


def run_threaded(dag: DAG, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 preemption: Optional[PreemptionModel] = None,
                 faults: Optional[FaultModel] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 supervisor=None,
                 sharding: Optional[ShardingSpec] = None,
                 batching: Optional[BatchingConfig] = None,
                 timeout: float = 120.0) -> RunMetrics:
    rt = ThreadedRuntime(scheduler, slowdown=slowdown, preemption=preemption,
                         faults=faults, recovery=recovery,
                         supervisor=supervisor, sharding=sharding,
                         batching=batching)
    rt.submit(dag)
    return rt.run(timeout=timeout)
