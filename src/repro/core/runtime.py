"""Real threaded executor — the XiTAO analogue running actual payloads.

Unlike the simulator, nothing here uses cost models: workers execute the
task's ``payload(width)`` callable (typically a jitted JAX kernel), measure
wall time, and feed the *measured* time into the PTT.  Scheduling decisions
are exactly the same ``Scheduler`` object used by the simulator.

Interference can be injected for tests/demos via ``slowdown``: a mapping
core -> factor; a worker on a slowed core sleeps ``duration*(factor-1)``
after the payload, emulating a co-runner stealing cycles.  (On this
container there is a single physical CPU, so *physical* contention cannot
demonstrate asymmetry; injected slowdown exercises the identical code
paths the scheduler would see on real hardware.)

Molded execution: the leader runs the payload; member cores block on the
task barrier for its duration (XiTAO's simplification: "each entry of the
PTT keeps track of the execution time of the task, as observed by the
leader core").
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Iterable, Optional

from .dag import DAG
from .metrics import RunMetrics, TaskRecord
from .schedulers import Scheduler
from .task import Task


class _Assigned:
    __slots__ = ("task", "place", "barrier", "started", "done")

    def __init__(self, task, place):
        self.task = task
        self.place = place
        self.barrier = threading.Barrier(place.width)
        self.started = False
        self.done = threading.Event()


class ThreadedRuntime:
    def __init__(self, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 idle_sleep: float = 1e-4):
        self.sched = scheduler
        self.topo = scheduler.topology
        self.slowdown = dict(slowdown or {})
        self.idle_sleep = idle_sleep
        n = self.topo.n_cores
        self.wsq: list[deque[Task]] = [deque() for _ in range(n)]
        self.aq: list[deque[_Assigned]] = [deque() for _ in range(n)]
        self.lock = threading.Lock()
        self.work_cv = threading.Condition(self.lock)
        self.outstanding = 0
        self.t0 = 0.0
        self.metrics = RunMetrics(n_cores=n)
        self.stop = False

    # -- submission -----------------------------------------------------------
    def _wake(self, task: Task, waker_core: int) -> None:
        task.t_ready = time.perf_counter() - self.t0
        target = self.sched.place_on_wake(task, waker_core)
        with self.work_cv:
            self.wsq[waker_core if target is None else target].append(task)
            self.outstanding += 1
            self.work_cv.notify_all()

    def submit(self, dag: DAG) -> None:
        self.t0 = time.perf_counter()
        for root in dag.roots:
            self._wake(root, waker_core=0)

    # -- worker ---------------------------------------------------------------
    def _pull(self, core: int) -> Optional[_Assigned]:
        with self.lock:
            # 1. own AQ head
            if self.aq[core]:
                return self.aq[core][0]
            # 2. own WSQ (LIFO)
            if self.wsq[core]:
                task = self.wsq[core].pop()
                return self._assign(task, core)
            # 3. steal (most-loaded victim, FIFO end, re-search place)
            victims = sorted(range(self.topo.n_cores),
                             key=lambda v: -len(self.wsq[v]))
            for v in victims:
                if v == core:
                    continue
                for i, t in enumerate(self.wsq[v]):
                    if self.sched.may_steal(t):
                        del self.wsq[v][i]
                        t.bound_place = None
                        return self._assign(t, core)
        return None

    def _assign(self, task: Task, core: int) -> Optional[_Assigned]:
        # caller holds self.lock
        place = self.sched.place_on_dequeue(task, core)
        rec = _Assigned(task, place)
        for c in place.cores:
            self.aq[c].append(rec)
        self.work_cv.notify_all()
        return self.aq[core][0]

    def _execute(self, rec: _Assigned, core: int) -> None:
        is_leader = core == rec.place.leader
        rid = rec.barrier.wait()        # all members rendezvous
        if is_leader:
            t_start = time.perf_counter() - self.t0
            rec.task.t_start = t_start
            if rec.task.payload is not None:
                rec.task.payload(rec.place.width)
            factor = max((self.slowdown.get(c, 1.0) for c in rec.place.cores),
                         default=1.0)
            if factor > 1.0:
                dur = (time.perf_counter() - self.t0) - t_start
                time.sleep(dur * (factor - 1.0))
            rec.done.set()
        else:
            rec.done.wait()
        rec.barrier.wait()
        if is_leader:
            self._commit(rec)

    def _commit(self, rec: _Assigned) -> None:
        task = rec.task
        task.t_end = time.perf_counter() - self.t0
        task.place = rec.place
        observed = task.t_end - task.t_start
        self.sched.ptt.for_type(task.type.name).update(rec.place, observed)
        with self.lock:
            for c in rec.place.cores:
                # remove this record from each member AQ (it is at/near head)
                try:
                    self.aq[c].remove(rec)
                except ValueError:
                    pass
            self.metrics.record(TaskRecord(
                type_name=task.type.name, priority=int(task.priority),
                leader=rec.place.leader, width=rec.place.width,
                t_ready=task.t_ready, t_start=task.t_start, t_end=task.t_end))
        for child in task.children:
            with self.lock:
                child.n_deps -= 1
                ready = child.n_deps == 0
            if ready:
                self._wake(child, rec.place.leader)
        new_tasks = task.on_commit(task) if task.on_commit else []
        for nt in new_tasks:
            if nt.n_deps == 0:
                self._wake(nt, rec.place.leader)
        with self.work_cv:
            self.outstanding -= 1
            self.work_cv.notify_all()

    def _worker(self, core: int) -> None:
        while True:
            with self.lock:
                if self.stop:
                    return
            rec = self._pull(core)
            if rec is None:
                with self.work_cv:
                    if self.stop or self.outstanding == 0:
                        return
                    self.work_cv.wait(timeout=self.idle_sleep)
                continue
            if not rec.done.is_set() or core == rec.place.leader:
                self._execute(rec, core)

    # -- run ------------------------------------------------------------------
    def run(self, timeout: float = 120.0) -> RunMetrics:
        threads = [threading.Thread(target=self._worker, args=(c,), daemon=True)
                   for c in range(self.topo.n_cores)]
        for th in threads:
            th.start()
        deadline = time.monotonic() + timeout
        with self.work_cv:
            while self.outstanding > 0 and time.monotonic() < deadline:
                self.work_cv.wait(timeout=0.05)
            self.stop = True
            self.work_cv.notify_all()
        for th in threads:
            th.join(timeout=5.0)
        self.metrics.finish(time.perf_counter() - self.t0)
        return self.metrics


def run_threaded(dag: DAG, scheduler: Scheduler, *,
                 slowdown: Optional[dict[int, float]] = None,
                 timeout: float = 120.0) -> RunMetrics:
    rt = ThreadedRuntime(scheduler, slowdown=slowdown)
    rt.submit(dag)
    return rt.run(timeout=timeout)
