"""Sharded control plane: hierarchical per-pod scheduling kernels under a
global rebalancer.

The flat :class:`~.lifecycle.SchedulingKernel` makes every scheduling
decision through one scheduler over the whole machine.  That is exactly
the paper's XiTAO shape — and it stops scaling when the machine is a
*fleet*: one PTT argmin sweeps every place in the system per HIGH wake,
one steal scan walks every core, and (once scheduler overhead is modeled
at all) every decision serializes through a single logical decision
server.  This module splits the control plane:

* each **shard** — a consecutive group of ``pods_per_shard`` partitions —
  owns a full :class:`~.lifecycle.SchedulingKernel` over a *cloned*
  scheduler (its own PTT bank and decision streams) whose
  :class:`~.places.LiveView` permanently fences it to the shard's cores,
  so wake/dequeue searches sweep only local places and never race other
  shards' decisions;
* all shards share one :class:`~.queues.WorkQueues` whose *steal groups*
  fence the victim scans (a thief only victimizes its own shard), so the
  per-core queue structures the execution engines index stay exactly as
  they were;
* a :class:`GlobalRebalancer` periodically moves *queued* work between
  shards on load imbalance — HIGH tasks first, priced in the same
  PTT-estimated-seconds currency as queue-aware placement — and wake-time
  *overflow* redirects route new work away from a drowning shard
  synchronously.

``ShardedControlPlane`` duck-types the full kernel interface, so both
execution engines (``simulator.py``, ``runtime.py``) drive it through the
methods they already call.  Decision *latency* is an engine concern: the
DES models per-shard single-server decision queues and charges
``ShardingSpec.decision_s`` per local wake (the flat kernel is then one
saturating server, the sharded plane N of them — the crossover
``bench_scale`` sweeps); the threaded runtime's overhead is real wall
time and needs no model.

``make_control_plane`` degenerates to the *plain* kernel whenever the
grouping yields a single shard, so ``sharding=None`` and
one-shard-zero-overhead specs are literally the flat code path —
bit-identical, which the golden pins and the equivalence pin check.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from .lifecycle import SchedulingKernel
from .places import ExecutionPlace
from .queues import WorkQueues
from .schedulers import Scheduler
from .task import Task, TaskType

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ShardingSpec:
    """How to shard the control plane and what its decisions cost.

    ``pods_per_shard`` groups consecutive partitions into shards (a value
    >= the partition count means one shard — the flat kernel).  The
    ``*_s`` fields are *modeled* scheduler overheads, applied by the DES
    only: ``decision_s`` per local wake decision (each shard is a
    single-server decision queue), ``rebalance_decision_s`` per rebalance
    round, ``migration_s`` per migrated task (both added to the migrated
    task's re-arrival time).  ``rebalance_period_s`` spaces rebalance
    rounds (0 disables them); ``imbalance_ratio`` is the hottest/coldest
    outstanding-seconds ratio that triggers migration;
    ``overflow_ratio`` (0 disables) redirects a wake away from its shard
    when that shard's backlog exceeds the fleet mean by the ratio;
    ``max_migrations_per_round`` caps one round's moves.

    Two further (default-off) rebalance triggers deepen the policy
    beyond total-load imbalance:

    * ``high_pressure_ratio`` (0 disables; else >= 1) — criticality
      pressure: a shard whose queued *HIGH* seconds exceed the ratio
      times the live-shard mean HIGH backlog sheds HIGH tasks to the
      shard with the least HIGH backlog, even when total load looks
      balanced.  HIGH tasks gate the DAG, so a HIGH pile-up delays the
      critical path invisibly to the total-load trigger.
    * ``ptt_divergence_ratio`` (0 disables; else >= 1) — per-shard PTTs
      are learned independently; when the slowest-learned shard's mean
      best measured estimate (over task types every live shard has
      explored) exceeds the ratio times the fastest-learned shard's,
      queued work shifts toward the faster shard, which will drain it
      sooner regardless of current queue lengths.

    Both triggers share ``max_migrations_per_round`` with the imbalance
    pass and draw no randomness, so ``plan_round`` stays a deterministic
    pure function of queue + PTT state (the cross-engine parity pin).
    """

    pods_per_shard: int = 1
    decision_s: float = 0.0
    rebalance_period_s: float = 0.0
    rebalance_decision_s: float = 0.0
    migration_s: float = 0.0
    imbalance_ratio: float = 2.0
    overflow_ratio: float = 0.0
    max_migrations_per_round: int = 8
    high_pressure_ratio: float = 0.0
    ptt_divergence_ratio: float = 0.0

    def __post_init__(self) -> None:
        if self.pods_per_shard < 1:
            raise ValueError(f"pods_per_shard {self.pods_per_shard} < 1")
        for f in ("decision_s", "rebalance_period_s", "rebalance_decision_s",
                  "migration_s", "overflow_ratio"):
            v = getattr(self, f)
            if not (0.0 <= v and math.isfinite(v)):
                raise ValueError(f"bad {f} {v!r}")
        if not (1.0 <= self.imbalance_ratio and
                math.isfinite(self.imbalance_ratio)):
            raise ValueError(
                f"imbalance_ratio {self.imbalance_ratio!r} must be >= 1")
        for f in ("high_pressure_ratio", "ptt_divergence_ratio"):
            v = getattr(self, f)
            if not (math.isfinite(v) and (v == 0.0 or v >= 1.0)):
                raise ValueError(f"{f} {v!r} must be 0 (off) or >= 1")
        if self.max_migrations_per_round < 1:
            raise ValueError("max_migrations_per_round must be >= 1")


class GlobalRebalancer:
    """Deterministic cross-shard migration planning, shared verbatim by
    both engines (the DES runs it at ``rebalance`` events, the threaded
    runtime on its timer thread) so migration *decisions* are a pure
    function of queue state.

    One round runs up to three deterministic passes under one shared
    move budget (``max_migrations_per_round``):

    1. **load imbalance** — repeatedly move the head of the hottest
       shard's most-backlogged WSQ — HIGH-first via
       :meth:`WorkQueues.migrate_pop` — to the coldest shard, until the
       hottest/coldest outstanding-seconds ratio drops under
       ``imbalance_ratio`` or the hot shard runs out of queued work;
    2. **criticality pressure** (``high_pressure_ratio`` > 0) — move
       queued HIGH tasks off any shard whose HIGH backlog exceeds the
       ratio times the live-shard mean, toward the least-HIGH-loaded
       shard;
    3. **PTT divergence** (``ptt_divergence_ratio`` > 0) — when the
       slowest-learned shard's mean best measured PTT estimate (over
       the task types every live shard has explored) exceeds the ratio
       times the fastest-learned shard's, shift its queued work to the
       faster shard while it remains the more loaded of the two.

    Ties break toward the lowest shard/core index; no randomness is
    drawn, so plans are a pure function of queue + PTT state shared
    verbatim by both engines.
    """

    def __init__(self, plane: "ShardedControlPlane"):
        self.plane = plane

    def _pop_from(self, shard: int, by_core: np.ndarray) -> Optional[Task]:
        """Pop one migratable task from ``shard``'s most-backlogged core
        as measured by ``by_core`` (total or HIGH-only queued seconds);
        None when nothing is queued there."""
        cp = self.plane
        cands = [c for c in cp.shard_cores[shard] if by_core[c] > _EPS]
        if not cands:
            return None
        src = max(cands, key=lambda c: (by_core[c], -c))
        return cp.queues.migrate_pop(src)

    def plan_round(self) -> list[tuple[Task, int]]:
        """Pop the tasks to migrate this round; returns ``(task,
        destination shard)`` pairs.  The popped tasks are in no queue
        until the engine lands them via :meth:`ShardedControlPlane.
        migrate_in` (after its modeled migration latency, if any)."""
        cp = self.plane
        spec = cp.spec
        live = [s for s in range(cp.n_shards) if not cp.shard_dead(s)]
        if len(live) < 2:
            return []
        cp.rebalance_rounds += 1
        loads = cp.shard_loads()
        qs = cp.queues.queued_s
        moves: list[tuple[Task, int]] = []
        budget = spec.max_migrations_per_round

        # pass 1 — total-load imbalance
        while budget > 0:
            hot = max(live, key=lambda s: (loads[s], -s))
            cold = min(live, key=lambda s: (loads[s], s))
            if hot == cold or \
                    loads[hot] <= spec.imbalance_ratio * (loads[cold] + _EPS):
                break
            task = self._pop_from(hot, qs)
            if task is None:
                break               # the hot shard's excess is all running
            moves.append((task, cold))
            loads[hot] -= task.load_est
            loads[cold] += task.load_est
            cp.migrated_load_s += task.load_est
            budget -= 1

        # pass 2 — criticality pressure (HIGH backlog per shard)
        qhs = cp.queues.queued_high_s
        if spec.high_pressure_ratio > 0.0 and budget > 0 and qhs is not None:
            high = np.array([qhs[list(cp.shard_cores[s])].sum()
                             for s in range(cp.n_shards)])
            while budget > 0:
                mean = float(high[live].mean())
                hot = max(live, key=lambda s: (high[s], -s))
                cold = min(live, key=lambda s: (high[s], s))
                if hot == cold or high[hot] <= high[cold] + _EPS or \
                        high[hot] <= spec.high_pressure_ratio * (mean + _EPS):
                    break
                # the source core has queued HIGH work, so migrate_pop
                # (HIGH-first) is guaranteed to pop a HIGH task
                task = self._pop_from(hot, qhs)
                if task is None:
                    break
                moves.append((task, cold))
                est = task.load_est
                high[hot] -= est
                high[cold] += est
                loads[hot] -= est
                loads[cold] += est
                cp.migrated_load_s += est
                budget -= 1

        # pass 3 — PTT divergence (learned-speed asymmetry)
        if spec.ptt_divergence_ratio > 0.0 and budget > 0:
            per_shard = []
            for s in live:
                bank = cp.kernels[s].sched.ptt
                per_shard.append({name: tbl.best_explored()
                                  for name, tbl in bank})
            shared = sorted(set.intersection(*[
                {n for n, v in d.items() if v is not None}
                for d in per_shard]) if per_shard else set())
            if shared:
                score = {s: sum(d[n] for n in shared) / len(shared)
                         for s, d in zip(live, per_shard)}
                src = max(live, key=lambda s: (score[s], -s))
                dst = min(live, key=lambda s: (score[s], s))
                if src != dst and score[src] > \
                        spec.ptt_divergence_ratio * (score[dst] + _EPS):
                    # drain toward the faster-learned shard, but never
                    # past the point where the slow shard is the less
                    # loaded of the two (no flapping)
                    while budget > 0 and loads[src] > loads[dst] + _EPS:
                        task = self._pop_from(src, qs)
                        if task is None:
                            break
                        moves.append((task, dst))
                        loads[src] -= task.load_est
                        loads[dst] += task.load_est
                        cp.migrated_load_s += task.load_est
                        budget -= 1
        return moves


class ShardedControlPlane:
    """N per-shard kernels + shared queues + the rebalancer, presenting
    the single-kernel interface both execution engines drive.

    Construction clones the top scheduler once per shard (own PTT bank,
    own decision streams — seeded from the top RNG so different run seeds
    give different shard streams) and fences each clone with the interned
    live view that excludes every non-shard core.  Revocation composes
    with the fence through the same mechanism: :meth:`set_availability`
    rebuilds each shard's view as ``non-shard cores ∪ down cores``; a
    fully-revoked shard is marked dead and wake/migration routing skips
    it until a restore brings it back.
    """

    track_load = True           # sharding needs the load currency

    # set by the DES when decision latency is modeled: seconds of wake
    # decisions queued at shard ``s``'s decision server.  Control-plane
    # backlog is part of a shard's load — without it the overflow and
    # rebalance logic are blind to the very bottleneck being modeled (the
    # threaded runtime's decision cost is real wall time, so its pending
    # decisions are always zero and this stays None).
    decision_backlog: Optional[Callable[[int], float]] = None

    def __init__(self, scheduler: Scheduler, *, now: Callable[[], float],
                 sharding: ShardingSpec):
        topo = scheduler.topology
        parts = topo.partitions
        pps = sharding.pods_per_shard
        n_shards = (len(parts) + pps - 1) // pps
        if n_shards < 2:
            raise ValueError("single-shard groupings take the flat kernel "
                             "(use make_control_plane)")
        self.spec = sharding
        self.sched = scheduler
        self.now = now
        self._all_cores = tuple(range(topo.n_cores))
        self._all_core_set = frozenset(self._all_cores)
        self._place_lw = [(p.leader, p.width) for p in topo.places()]
        self.shard_of_core = [0] * topo.n_cores

        self.queues = WorkQueues(
            topo.n_cores, priority_dequeue=scheduler.priority_dequeue,
            steal_high=scheduler.steal_high, track_load=True,
            groups=[0] * topo.n_cores)
        # continuous batching (mirrors SchedulingKernel.batching): the
        # engines set this; form_dispatch below reads it
        self.batching = None
        self._reshard_generation = 0
        self._set_grouping(pps)
        scheduler.begin_run()
        self._down_cores: frozenset = frozenset()
        self._dead = [False] * n_shards
        self.rebalancer = GlobalRebalancer(self)

        # migration telemetry (copied into RunMetrics by the engines)
        self.migrations = 0
        self.overflow_migrations = 0
        self.rebalance_rounds = 0
        self.migrated_load_s = 0.0
        self.reshard_rounds = 0

    def _set_grouping(self, pps: int) -> None:
        """(Re)build the shard grouping for ``pods_per_shard=pps``: shard
        membership tables, the shared queues' steal groups (mutated in
        place — the engines hold references), the per-shard kernels over
        freshly cloned schedulers, and their fence views.  Used at
        construction and by :meth:`reshard`."""
        topo = self.sched.topology
        parts = topo.partitions
        n_shards = (len(parts) + pps - 1) // pps
        self.n_shards = n_shards
        self.shard_parts = tuple(
            tuple(range(i * pps, min((i + 1) * pps, len(parts))))
            for i in range(n_shards))
        self.shard_cores = tuple(
            tuple(c for pi in ps for c in parts[pi].cores)
            for ps in self.shard_parts)
        for s, cs in enumerate(self.shard_cores):
            for c in cs:
                self.shard_of_core[c] = s
        self._shard_core_idx = [np.array(cs, dtype=np.int64)
                                for cs in self.shard_cores]
        self.queues.groups[:] = self.shard_of_core
        self._base_view = tuple(
            topo.live_view_cores(self._all_core_set - frozenset(cs))
            for cs in self.shard_cores)
        gen = self._reshard_generation
        tag = f"r{gen}:" if gen else ""
        self.kernels: list[SchedulingKernel] = []
        for s in range(n_shards):
            clone = self.sched.clone(
                f"shard:{tag}{s}:{self.sched.rng.random()}")
            k = SchedulingKernel(clone, now=self.now, queues=self.queues)
            clone.live = self._base_view[s]
            self.kernels.append(k)

    def reshard(self, pods_per_shard: int) -> list[tuple[Task, int]]:
        """Online re-sharding: regroup the fleet's pods into shards of
        ``pods_per_shard`` mid-run (pods joined, or a long-revoked pod is
        being consolidated into a live neighbor's shard) and return the
        rebalancer's catch-up migration round — ``(task, destination
        shard)`` pairs the engine lands via :meth:`migrate_in` — so
        queued work orphaned on the old grouping's cold corners moves
        under the new one.

        Every per-core structure (WSQs, AQs, queued-seconds vectors) is
        untouched; only shard *membership* changes.  New shard kernels
        are cloned deterministically from the top scheduler's stream
        (cold PTTs — the rebalancer's divergence trigger and plain
        exploration re-learn them; an accepted cost, documented in
        DESIGN.md).  In-flight run charges transfer to the new owner of
        each charged core so load accounting stays exact."""
        if pods_per_shard < 1:
            raise ValueError(f"pods_per_shard {pods_per_shard} < 1")
        parts = self.sched.topology.partitions
        if (len(parts) + pods_per_shard - 1) // pods_per_shard < 2:
            raise ValueError("re-sharding to a single shard is not "
                             "supported (the flat kernel cannot be "
                             "swapped in mid-run)")
        old_kernels = self.kernels
        self._reshard_generation += 1
        self.spec = dataclasses.replace(self.spec,
                                        pods_per_shard=pods_per_shard)
        self._set_grouping(pods_per_shard)
        self._dead = [False] * self.n_shards
        # in-flight charges follow their cores to the new owning shard
        for k in old_kernels:
            for tid, (cores, est) in k._run_charges.items():
                nk = self.kernels[self.shard_of_core[cores[0]]]
                nk._run_charges[tid] = (cores, est)
                for c in cores:
                    nk._running_s[c] += est
        for k in self.kernels:
            k.batching = self.batching
        self.set_availability(self._down_cores)
        self.reshard_rounds += 1
        return self.rebalancer.plan_round()

    # -- shard state ---------------------------------------------------------
    def shard_dead(self, s: int) -> bool:
        return self._dead[s]

    def load_per_core(self) -> np.ndarray:
        """Per-core outstanding estimated seconds (queued + running),
        summed across every shard's running charges."""
        load = self.queues.queued_s.copy()
        for k in self.kernels:
            load += k._running_s
        return np.maximum(load, 0.0)

    def shard_loads(self) -> np.ndarray:
        """Per-shard outstanding estimated seconds — the imbalance and
        overflow currency.  Includes the shard's modeled decision-server
        backlog when the DES provides one (see ``decision_backlog``)."""
        load = self.load_per_core()
        out = np.array([load[idx].sum() for idx in self._shard_core_idx])
        if self.decision_backlog is not None:
            out += np.array([self.decision_backlog(s)
                             for s in range(self.n_shards)])
        return out

    def _coldest_live_shard(self, loads: Optional[np.ndarray] = None) -> int:
        if loads is None:
            loads = self.shard_loads()
        live = [s for s in range(self.n_shards) if not self._dead[s]]
        return min(live, key=lambda s: (loads[s], s))

    def _entry_core(self, s: int) -> int:
        """Deterministic representative core for work routed *into* shard
        ``s`` from outside: its least-loaded live core (lowest index on
        ties) — no randomness, so both engines route identically."""
        load = self.load_per_core()
        cands = [c for c in self.shard_cores[s] if c not in self._down_cores]
        return min(cands, key=lambda c: (load[c], c))

    # -- wake / requeue (lifecycle steps 1-2) --------------------------------
    def wake(self, task: Task, waker_core: int) -> int:
        s = self.shard_of_core[waker_core]
        if self._dead[s]:
            s = self._coldest_live_shard()
            waker_core = self._entry_core(s)
        elif self.spec.overflow_ratio > 0.0:
            loads = self.shard_loads()
            live = [i for i in range(self.n_shards) if not self._dead[i]]
            mean = float(loads[live].mean()) if live else 0.0
            if (len(live) > 1
                    and loads[s] > self.spec.overflow_ratio * (mean + _EPS)):
                t = self._coldest_live_shard(loads)
                if t != s and loads[t] < loads[s]:
                    s = t
                    waker_core = self._entry_core(s)
                    self.overflow_migrations += 1
        return self.kernels[s].wake(task, waker_core)

    def requeue_displaced(self, task: Task,
                          waker: Optional[int] = None) -> int:
        """Revocation/fault re-placement: the waker core is drawn from the
        *global* live set with the top scheduler's RNG — one draw per
        task, same as the flat kernel — then the owning shard redoes the
        wake-time decision over its surviving places."""
        if waker is None:
            live = self.live_cores()
            rng = self.sched.rng
            waker = (live[rng.randrange(len(live))] if len(live) > 1
                     else live[0])
        return self.kernels[self.shard_of_core[waker]].requeue_displaced(
            task, waker=waker)

    def migrate_in(self, task: Task, shard: int) -> int:
        """Land a migrated task on ``shard``: the old binding is void (it
        names a source-shard place), the destination shard redoes the
        wake-time decision from its least-loaded live core.  ``t_ready``
        is *not* restamped — migration must not hide queueing delay from
        the sojourn metrics."""
        if self._dead[shard]:
            shard = self._coldest_live_shard()
        task.bound_place = None
        k = self.kernels[shard]
        waker = self._entry_core(shard)
        target = k.sched.place_on_wake(task, waker)
        core = waker if target is None else target
        k._stamp_load_est(task, core)
        self.migrations += 1
        return core

    def live_cores(self) -> tuple[int, ...]:
        view = self.sched.live
        return self._all_cores if view is None else view.cores

    # -- dequeue / steal (steps 3-5) -----------------------------------------
    def on_steal(self, task: Task) -> None:
        task.bound_place = None

    def choose_place(self, task: Task, worker_core: int) -> ExecutionPlace:
        return self.kernels[self.shard_of_core[worker_core]].choose_place(
            task, worker_core)

    def form_dispatch(self, task: Task, core: int) -> Task:
        """Continuous batching at the dequeue boundary (see
        :meth:`SchedulingKernel.form_dispatch`) — queue coalescing is
        per-core, so sharding changes nothing about it."""
        cfg = self.batching
        if cfg is None or task.batch_key is None:
            return task
        existing = task.batch_members or []
        room = cfg.max_batch - 1 - len(existing)
        if room <= 0:
            return task
        members = self.queues.coalesce_batch(core, task.batch_key, room)
        if members:
            task.batch_members = existing + members
            base = task.type
            if base.batch_base is not None:
                base = members[0].type
            task.type = base.batched(1 + len(task.batch_members),
                                     cfg.member_cost)
        return task

    def batch_feedback(self, task: Task, place: ExecutionPlace,
                       observed: float) -> None:
        """One PTT observation on the batch-bucketed type at the owning
        shard, plus idempotent member discharges (see
        :meth:`SchedulingKernel.batch_feedback`)."""
        self.ptt_feedback(task, place, observed)
        if task.batch_members:
            for m in task.batch_members:
                self.discharge(m)

    # -- load accounting ------------------------------------------------------
    def estimate_seconds(self, task_type: TaskType,
                         place: ExecutionPlace) -> float:
        return self.kernels[self.shard_of_core[place.leader]] \
            .estimate_seconds(task_type, place)

    def discharge(self, task: Task) -> None:
        for k in self.kernels:          # each discharge is idempotent O(1)
            k.discharge(task)

    def place_load(self) -> np.ndarray:
        """Fleet-wide per-place outstanding seconds (observability; each
        shard's own searches read its kernel's view)."""
        load = self.load_per_core()
        out = np.empty(len(self._place_lw))
        for i, (leader, width) in enumerate(self._place_lw):
            out[i] = (load[leader] if width == 1
                      else load[leader:leader + width].max())
        return out

    def backlog_signal(self) -> float:
        live = self.live_cores()
        load = self.load_per_core()
        return max(float(load[list(live)].sum()), 0.0) / max(len(live), 1)

    def prime_ptt(self, task_type: TaskType, estimate: float = None) -> int:
        return sum(k.prime_ptt(task_type, estimate) for k in self.kernels)

    # -- commit (step 8) ------------------------------------------------------
    def observe_simulated(self, task_type: TaskType, duration: float) -> float:
        """Measurement noise is a property of the environment, not the
        shard: draws come from the top scheduler's stream (same model as
        :meth:`SchedulingKernel.observe_simulated`)."""
        rng = self.sched.rng
        noise = rng.gauss(1.0, task_type.noise) if task_type.noise else 1.0
        observed = duration * min(max(noise, 0.5), 2.0)
        if task_type.spike_prob and rng.random() < task_type.spike_prob:
            observed *= task_type.spike_mag
        return observed

    def ptt_feedback(self, task: Task, place: ExecutionPlace,
                     observed: float) -> None:
        self.kernels[self.shard_of_core[place.leader]].ptt_feedback(
            task, place, observed)

    def commit_successors(self, task: Task, lock=None):
        return self.kernels[0].commit_successors(task, lock=lock)

    # -- fault recovery -------------------------------------------------------
    def expected_duration(self, task: Task, place: ExecutionPlace) -> float:
        return self.kernels[self.shard_of_core[place.leader]] \
            .expected_duration(task, place)

    def fault_feedback(self, task: Task, place: ExecutionPlace,
                       elapsed: float, penalty: float) -> None:
        self.kernels[self.shard_of_core[place.leader]].fault_feedback(
            task, place, elapsed, penalty)

    def hedge_place(self, task: Task, exclude_cores, rng) -> \
            Optional[ExecutionPlace]:
        """Fleet-wide PTT-best live place disjoint from the straggler's
        cores — each candidate scored by its *owning shard's* table
        (unexplored 0.0 first, ties prefer narrow, residual ties from the
        fault ``rng``), mirroring :meth:`PTT.best` semantics."""
        live = set(self.live_cores())
        best_key, cands = None, []
        for p in self.sched.topology.places():
            if not live.issuperset(p.cores) \
                    or exclude_cores.intersection(p.cores):
                continue
            tbl = self.kernels[self.shard_of_core[p.leader]] \
                .sched.ptt.for_type(task.type.name)
            key = (tbl.get(p), p.width)
            if best_key is None or key < best_key:
                best_key, cands = key, [p]
            elif key == best_key:
                cands.append(p)
        if not cands:
            return None
        if len(cands) > 1 and rng is not None:
            return cands[rng.randrange(len(cands))]
        return cands[0]

    # -- availability ---------------------------------------------------------
    def set_availability(self, down_cores: frozenset) -> None:
        """Compose revocation with the shard fences: the top scheduler
        gets the global view (requeue routing reads it); each live shard
        gets ``non-shard ∪ down``; a fully-down shard is dead until
        restored (its view is left stale — nothing routes to it)."""
        topo = self.sched.topology
        self._down_cores = down_cores
        self.sched.live = (None if not down_cores
                           else topo.live_view_cores(down_cores))
        for s, k in enumerate(self.kernels):
            cs = frozenset(self.shard_cores[s])
            if cs <= down_cores:
                self._dead[s] = True
                continue
            self._dead[s] = False
            fence = self._all_core_set - cs
            k.sched.live = topo.live_view_cores(fence | down_cores)

    def end_run(self) -> None:
        self.sched.live = None
        self._down_cores = frozenset()
        self._dead = [False] * self.n_shards
        for s, k in enumerate(self.kernels):
            k.sched.live = self._base_view[s]


def make_control_plane(scheduler: Scheduler, *, now: Callable[[], float],
                       sharding: Optional[ShardingSpec] = None):
    """The engines' one constructor: the plain flat kernel for
    ``sharding=None`` *and* for any grouping that yields a single shard
    (``pods_per_shard >= partition count``) — that degeneracy is the
    semantics-preservation pin: a one-shard zero-overhead sharded run is
    the flat code path, bit for bit."""
    if sharding is None or \
            sharding.pods_per_shard >= len(scheduler.topology.partitions):
        return SchedulingKernel(scheduler, now=now)
    return ShardedControlPlane(scheduler, now=now, sharding=sharding)
