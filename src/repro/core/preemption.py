"""Preemptible capacity: seeded revoke/restore episode models.

Cloud accelerator fleets exhibit a harsher form of dynamic asymmetry than
DVFS or co-runners: capacity is *revoked outright*.  A TPU pod slice is
reclaimed by the scheduler above you, a preemptible VM gets its 30-second
notice, a maintenance event takes an ICI domain down — and the work that
was running there has to land somewhere else (cf. Mage, arXiv:1804.06462:
online schedulers must handle resources disappearing mid-run).  This
module generates the *when*; the discrete-event simulator applies the
*what* (see ``simulator.py``):

* at **revoke** time all running tasks on the affected partition are
  killed (``preempt="restart"``: their progress is lost) or checkpointed
  (``preempt="checkpoint"``: progress carries over, minus a
  ``resume_penalty`` fraction of the task's duration paid on resume), the
  partition's WSQs and AQs are drained back to the scheduler, and every
  displaced task is re-placed on the surviving partitions — HIGH tasks
  re-bound first, so criticality-awareness is measurable under
  revocation;
* at **restore** time the partition's cores re-enter the dispatch loop
  (they steal their way back to work).

Episodes are generated at *partition* granularity — a pod slice, an ICI
domain, a socket — matching how real revocations arrive.  Two seeded
generators:

* :func:`pod_slice_preemption` — each partition runs an independent
  renewal process (exponential up/down intervals), the memoryless
  baseline;
* :func:`mmpp_preemption` — MMPP-style *correlated* revocations: one
  hidden calm/storm modulating chain is shared by every partition and
  scales the revocation rate, so revokes cluster in time across
  partitions (the maintenance-wave / spot-reclaim signature) while each
  partition keeps its own draw stream.

Episodes that would take the *last* live partition down are pruned at
generation time, so the simulated machine always retains capacity and
every DAG completes.  Everything is a pure function of ``(seed, params)``
— multi-run cells stay bit-reproducible for any worker count.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Optional, Sequence

from .interference import mmpp_on_off, mmpp_state_timeline, renewal_on_off
from .places import Topology

PREEMPT_MODES = ("restart", "checkpoint")


@dataclasses.dataclass(frozen=True)
class PreemptionModel:
    """A fixed, seeded schedule of per-partition revoke/restore episodes.

    ``episodes`` holds ``(partition index, t_revoke, t_restore)`` triples
    sorted by revoke time; episodes of one partition never overlap, and no
    instant has every partition revoked (see :func:`prune_full_outages`).
    ``preempt`` selects what happens to running tasks at revoke time;
    ``resume_penalty`` (checkpoint mode only) is the extra work paid on
    resume, as a fraction of the task's full duration at its new place.
    ``notice`` is the revocation *notice window* (seconds): running tasks
    keep executing for that long after the revoke edge and are only
    killed/checkpointed at its expiry — the spot-VM 30-second-notice
    shape, and the DES analogue of the threaded engine's grace window
    (running payloads there cannot be killed at all).  Queued work always
    drains immediately and nothing new starts on a revoked partition;
    ``notice=0`` (the default) preempts instantaneously, bit-identical to
    models without the field.

    ``subsets`` (optional, parallel to ``episodes``) gives each episode a
    *sub-pod* granularity: entry i is either None (the whole partition —
    the classic shape) or a tuple of absolute core ids inside partition
    ``episodes[i][0]`` to revoke, leaving its siblings live (a partial
    :class:`~.places.LiveView`).  An empty ``subsets`` means every episode
    is whole-partition, so all existing 3-tuple consumers are untouched.
    """

    episodes: tuple[tuple[int, float, float], ...]
    preempt: str = "restart"
    resume_penalty: float = 0.05
    notice: float = 0.0
    subsets: tuple[Optional[tuple[int, ...]], ...] = ()

    def __post_init__(self) -> None:
        if self.preempt not in PREEMPT_MODES:
            raise ValueError(f"preempt must be one of {PREEMPT_MODES}, "
                             f"got {self.preempt!r}")
        if not (0.0 <= self.resume_penalty and
                math.isfinite(self.resume_penalty)):
            raise ValueError(f"bad resume_penalty {self.resume_penalty!r}")
        if not (0.0 <= self.notice and math.isfinite(self.notice)):
            raise ValueError(f"bad notice {self.notice!r}")
        if self.subsets and len(self.subsets) != len(self.episodes):
            raise ValueError(
                f"subsets has {len(self.subsets)} entries for "
                f"{len(self.episodes)} episodes")
        prev_t0 = -1.0
        last_end: dict[int, float] = {}
        for pidx, t0, t1 in self.episodes:
            if not (0.0 <= t0 < t1):
                raise ValueError(f"bad episode window [{t0}, {t1})")
            if t0 < prev_t0:
                raise ValueError("episodes must be sorted by revoke time")
            if t0 < last_end.get(pidx, 0.0):
                raise ValueError(
                    f"overlapping episodes for partition {pidx}")
            prev_t0 = t0
            last_end[pidx] = t1

    def episodes_for(self, pidx: int) -> tuple[tuple[float, float], ...]:
        return tuple((t0, t1) for p, t0, t1 in self.episodes if p == pidx)

    def cores_of(self, eidx: int, topology: Topology) -> tuple[int, ...]:
        """The cores episode ``eidx`` revokes: its subset if one was named,
        else every core of its partition."""
        pidx = self.episodes[eidx][0]
        sub = self.subsets[eidx] if self.subsets else None
        if sub is not None:
            part = topology.partitions[pidx]
            for c in sub:
                if not part.start <= c < part.start + part.size:
                    raise ValueError(
                        f"episode {eidx}: core {c} outside partition "
                        f"{part.name} [{part.start}, "
                        f"{part.start + part.size})")
            return tuple(sub)
        return topology.partitions[pidx].cores

    @property
    def n_episodes(self) -> int:
        return len(self.episodes)


def prune_full_outages(episodes: Sequence[tuple[int, float, float]],
                       n_partitions: int) -> tuple[tuple[int, float, float], ...]:
    """Drop every episode whose revoke would leave *zero* live partitions.

    Sweep the revoke edges in time order, tracking how many kept episodes
    are still in force (restores at exactly the revoke instant count as
    restored — outage windows are half-open [t0, t1)).  Because the down
    set only grows at revoke edges, refusing the n-th concurrent outage is
    sufficient to guarantee at least one partition is live at all times.
    """
    if n_partitions < 1:
        raise ValueError("need at least one partition")
    ordered = sorted(episodes, key=lambda e: (e[1], e[0], e[2]))
    out: list[tuple[int, float, float]] = []
    active: list[float] = []        # min-heap of kept episodes' restore times
    for pidx, t0, t1 in ordered:
        while active and active[0] <= t0:
            heapq.heappop(active)
        if len(active) >= n_partitions - 1:
            continue                # would revoke the last live partition
        heapq.heappush(active, t1)
        out.append((pidx, t0, t1))
    return tuple(out)


def _partition_indices(topology: Topology,
                       partitions: Optional[Sequence[int]]) -> tuple[int, ...]:
    n = len(topology.partitions)
    if partitions is None:
        return tuple(range(n))
    idxs = tuple(partitions)
    for i in idxs:
        if not 0 <= i < n:
            raise ValueError(f"partition index {i} outside 0..{n - 1}")
    return idxs


def pod_slice_preemption(topology: Topology, *, seed: int, t_end: float,
                         mean_up: float, mean_down: float,
                         partitions: Optional[Sequence[int]] = None,
                         preempt: str = "restart",
                         resume_penalty: float = 0.05,
                         notice: float = 0.0) -> PreemptionModel:
    """Independent per-partition revoke/restore renewal episodes.

    Each preemptible partition alternates exponential up intervals (mean
    ``mean_up`` seconds between revocations) and outages (mean
    ``mean_down`` seconds), generated until ``t_end`` (must be finite — it
    bounds the episode count).  Each partition draws from its own stream
    derived from ``(seed, partition name)``, so adding or filtering
    partitions never shifts another partition's episodes.  ``partitions``
    restricts which partition indices are preemptible (default: all).
    """
    if not math.isfinite(t_end) or t_end <= 0.0:
        raise ValueError("pod_slice_preemption needs a finite positive t_end")
    episodes: list[tuple[int, float, float]] = []
    for i in _partition_indices(topology, partitions):
        rng = random.Random(f"preempt:{seed}:{topology.partitions[i].name}")
        for t0, t1 in renewal_on_off(rng, t_start=0.0, t_end=t_end,
                                     mean_on=mean_down, mean_off=mean_up):
            episodes.append((i, t0, t1))
    return PreemptionModel(
        prune_full_outages(episodes, len(topology.partitions)),
        preempt=preempt, resume_penalty=resume_penalty, notice=notice)


def sub_slice_preemption(topology: Topology, *, seed: int, t_end: float,
                         mean_up: float, mean_down: float, frac: float = 0.5,
                         partitions: Optional[Sequence[int]] = None,
                         preempt: str = "restart",
                         resume_penalty: float = 0.05,
                         notice: float = 0.0) -> PreemptionModel:
    """Sub-pod revocation episodes: the renewal timing of
    :func:`pod_slice_preemption`, but each episode takes only a seeded
    contiguous run of ``frac`` of its partition's cores (at least one, at
    most all-but-one — the partition always keeps a live core, so no
    full-outage pruning is ever needed).  The live view during such an
    episode is *partial*: siblings keep dispatching while the searches
    mask out every place that touches a revoked core."""
    if not math.isfinite(t_end) or t_end <= 0.0:
        raise ValueError("sub_slice_preemption needs a finite positive t_end")
    if not 0.0 < frac < 1.0:
        raise ValueError(f"frac {frac!r} outside (0, 1) — use "
                         f"pod_slice_preemption for whole partitions")
    rows: list[tuple[tuple[int, float, float], tuple[int, ...]]] = []
    for i in _partition_indices(topology, partitions):
        part = topology.partitions[i]
        if part.size < 2:
            continue                 # nothing strictly-sub-pod to take
        rng = random.Random(f"preempt-sub:{seed}:{part.name}")
        k = max(1, min(part.size - 1, round(frac * part.size)))
        for t0, t1 in renewal_on_off(rng, t_start=0.0, t_end=t_end,
                                     mean_on=mean_down, mean_off=mean_up):
            off = rng.randrange(part.size - k + 1)
            cores = tuple(range(part.start + off, part.start + off + k))
            rows.append(((i, t0, t1), cores))
    rows.sort(key=lambda r: (r[0][1], r[0][0], r[0][2]))
    return PreemptionModel(tuple(r[0] for r in rows), preempt=preempt,
                           resume_penalty=resume_penalty, notice=notice,
                           subsets=tuple(r[1] for r in rows))


def mmpp_preemption(topology: Topology, *, seed: int, t_end: float,
                    mean_calm: float, mean_storm: float,
                    mean_up_calm: float, mean_up_storm: float,
                    mean_down: float,
                    partitions: Optional[Sequence[int]] = None,
                    preempt: str = "restart",
                    resume_penalty: float = 0.05,
                    notice: float = 0.0) -> PreemptionModel:
    """MMPP-style correlated revocations.

    One hidden calm/storm modulating chain (exponential sojourns of mean
    ``mean_calm`` / ``mean_storm`` seconds, seeded from ``seed`` alone) is
    shared by every preemptible partition; while it is calm a partition's
    revocations arrive with mean gap ``mean_up_calm``, during a storm with
    mean gap ``mean_up_storm`` (typically much shorter).  Outage lengths
    draw from ``mean_down`` regardless of state.  Because the chain is
    shared, revocations *cluster across partitions* — several pods go down
    in the same storm — which is the regime where criticality-aware
    re-binding earns its keep.  Per-partition draws still come from
    per-partition streams, so the construction is order-independent.
    """
    if not math.isfinite(t_end) or t_end <= 0.0:
        raise ValueError("mmpp_preemption needs a finite positive t_end")
    state_rng = random.Random(f"preempt-mmpp-state:{seed}")
    timeline = mmpp_state_timeline(state_rng, t_end=t_end,
                                   mean_calm=mean_calm,
                                   mean_storm=mean_storm)
    episodes: list[tuple[int, float, float]] = []
    for i in _partition_indices(topology, partitions):
        rng = random.Random(f"preempt-mmpp:{seed}:{topology.partitions[i].name}")
        for t0, t1 in mmpp_on_off(rng, timeline, t_end=t_end,
                                  mean_on=mean_down,
                                  mean_off_calm=mean_up_calm,
                                  mean_off_storm=mean_up_storm):
            episodes.append((i, t0, t1))
    return PreemptionModel(
        prune_full_outages(episodes, len(topology.partitions)),
        preempt=preempt, resume_penalty=resume_penalty, notice=notice)
