"""The seven scheduler configurations (paper Table 1 + Algorithm 1).

| name   | asymmetry | moldability | priority placement        |
|--------|-----------|-------------|---------------------------|
| RWS    | n/a       | no          | n/a (stealable)           |
| RWSM-C | n/a       | yes (cost)  | resource cost, local      |
| FA     | fixed     | no          | statically fastest cores  |
| FAM-C  | fixed     | yes (cost)  | fastest partition + cost  |
| DA     | dynamic   | no          | global min time, width 1  |
| DAM-C  | dynamic   | yes (cost)  | global min time*width     |
| DAM-P  | dynamic   | yes (cost)  | global min time           |

Two decision points, mirroring XiTAO's task lifetime (paper Fig. 3):

* ``place_on_wake``   — when a predecessor commits and the task becomes
  ready: HIGH tasks get a *binding* decision (and are pushed to the chosen
  leader's queue, un-stealable except under RWS); LOW tasks stay on the
  waker's queue.
* ``place_on_dequeue`` — when a worker (owner or thief) pulls a LOW task:
  the width is (re)chosen by local search (paper steps 4-5 re-visit the
  PTT after a steal).

PTT tie-break modes
-------------------
Equal PTT predictions (ubiquitous early in a run, when every entry is the
"unexplored" 0.0) are broken uniformly at random.  By default
(``ptt_tiebreak="shared"``) those draws come from the scheduler's main RNG
— the same stream that drives measurement noise, spike injection, and
steal-victim shuffles.  That coupling makes runs *globally* sensitive to
any local perturbation: one extra or missing draw (e.g. a measurement
spike that changes whether a tie occurs) shifts every subsequent draw in
the run, which is how RWSM-C/P6-class cells end up bistable — the same
configuration lands in one of two basins of the PTT explore-exploit trap
depending on irrelevant draw-sequence details.  ``ptt_tiebreak="seeded"``
gives placement tie-breaks their own deterministic seeded stream (derived
from the scheduler seed), so tie-break decisions depend only on the
sequence of tie situations and perturbations stay local.  Golden tests pin
trap-prone cells in seeded mode.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np

from .places import ExecutionPlace, LiveView, Topology
from .ptt import PTTBank
from .task import Priority, Task


@dataclasses.dataclass
class Scheduler:
    name: str
    topology: Topology
    ptt: PTTBank
    rng: random.Random

    moldable: bool = False
    dynamic: bool = False            # uses PTT to find *where* (vs static)
    fixed_asym: bool = False         # static notion of fast cores (FA/FAM-C)
    high_target_cost: bool = True    # DAM-C (cost) vs DAM-P (performance)
    steal_high: bool = False         # only RWS-family steals HIGH tasks
    priority_dequeue: bool = True    # serve HIGH first from own WSQ
    # dedicated RNG for PTT-search tie-breaks ("seeded" mode); None = draw
    # from the shared scheduler RNG (see module docstring)
    tiebreak_rng: Optional[random.Random] = None
    # forced-revisit escape hatch for the PTT explore-exploit trap: with
    # probability ``revisit_eps`` a placement search returns the *stalest*
    # candidate (least-recently-updated PTT entry — a poisoned entry's
    # signature) instead of the argmin, so one bad measurement can't shun
    # a place forever.  Draws come from a dedicated seeded stream so the
    # measurement-noise/steal and tie-break streams are untouched; with
    # ``revisit_rng`` None (the default) this path costs nothing and
    # behavior is bit-identical to pre-escape-hatch runs.
    revisit_eps: float = 0.0
    revisit_rng: Optional[random.Random] = None
    # capacity availability under preemption: None = every partition live
    # (the zero-cost default — all search paths are untouched).  The
    # simulator assigns a :class:`~.places.LiveView` at revoke/restore
    # edges; every wake-time search is then restricted to live places, and
    # FA/FAM-C fall back to the statically fastest *live* partition.
    # Dequeue-time local searches only need a mask when the view is
    # *partial* (sub-pod revocation): the dispatching worker is live, but
    # its wider local places may contain down sibling cores.
    live: Optional[LiveView] = None
    # Queue-aware placement: every PTT placement search minimizes
    # ``ptt_estimate + queue_penalty * outstanding(place)`` where
    # ``outstanding`` is the per-place estimated seconds of queued+running
    # work, read through ``load_view`` (a callable installed by the
    # :class:`~.lifecycle.SchedulingKernel` that owns the accounting).
    # ``queue_penalty=0.0`` (the default) never calls ``load_view`` and is
    # bit-identical to load-oblivious placement.  ``track_load`` turns on
    # the kernel's accounting without the penalty (observability only).
    queue_penalty: float = 0.0
    track_load: bool = False
    load_view: Optional[object] = None
    # Placement scoring backend hook (``placement_backend="jax"``): a
    # callable ``(vals, load, penalty) -> score`` handed to every PTT
    # search.  None (the numpy default) leaves all search fast paths
    # byte-for-byte untouched — goldens are pinned on that path.
    score_fn: Optional[object] = None
    _fa_rr: int = dataclasses.field(default=0, init=False)  # FA round-robin
    # per-type PTT handle cache (same objects as the bank's): the wake /
    # dequeue hot paths do one C-level dict get instead of a method call
    _tbl_cache: dict = dataclasses.field(default_factory=dict, init=False)

    @property
    def search_rng(self) -> random.Random:
        return self.tiebreak_rng if self.tiebreak_rng is not None else self.rng

    def _load_penalty(self):
        """(per-place load vector, penalty) for the placement searches —
        ``(None, 0.0)`` unless queue-aware placement is on, which keeps the
        default searches bit-identical to load-oblivious builds."""
        if self.queue_penalty > 0.0 and self.load_view is not None:
            return self.load_view(), self.queue_penalty
        return None, 0.0

    def begin_run(self) -> None:
        """Reset per-run scheduling state.  PTT contents deliberately
        persist across runs (they are the online model); the FA/FAM-C
        round-robin cursor must not — a reused scheduler otherwise starts
        round-robin where the previous run left off, making back-to-back
        runs irreproducible.  ``live`` is left alone here: a mask applied
        *before* the run (PodMonitor.apply_to) must survive engine
        construction; engines clear it at end-of-run instead (see
        ``SchedulingKernel.end_run``)."""
        self._fa_rr = 0

    def _force_revisit(self) -> bool:
        return (self.revisit_rng is not None
                and self.revisit_rng.random() < self.revisit_eps)

    def _local_indices(self, core: int) -> Optional[np.ndarray]:
        """Local-search candidate override for ``core``: None (the exact
        unmasked path) unless the live view is *partial* — a sub-pod
        revocation can leave a live worker whose wider local places
        contain down sibling cores, so those places are filtered out.
        The worker's width-1 place is always live, so never empty."""
        live = self.live
        if live is None or not live.partial:
            return None
        idx = self.topology.local_place_indices(core)
        return idx[np.isin(idx, live.place_idx)]

    def clone(self, stream: str) -> "Scheduler":
        """An independent scheduler with the same policy flags but its own
        PTT bank and decision streams (seeded from ``stream``) — one per
        control-plane shard.  Availability and load views reset; the
        owning kernel re-installs them."""
        return dataclasses.replace(
            self,
            ptt=PTTBank(self.topology, **self.ptt.ptt_kwargs),
            rng=random.Random(stream),
            tiebreak_rng=(random.Random(f"tiebreak:{stream}")
                          if self.tiebreak_rng is not None else None),
            revisit_rng=(random.Random(f"revisit:{stream}")
                         if self.revisit_rng is not None else None),
            live=None, load_view=None)

    # -- wake-time placement -------------------------------------------------
    def place_on_wake(self, task: Task, waker_core: int) -> Optional[int]:
        """Return the core whose WSQ receives the task (None = waker's).
        For HIGH tasks this may also set ``task.bound_place``."""
        if task.priority != Priority.HIGH:
            return None                      # LOW: local queue of the waker
        live = self.live
        if self.fixed_asym:
            # FA/FAM-C: strictly map to the statically fastest partition
            # (the fastest *live* one while capacity is revoked; ties keep
            # topology order, matching fastest_static_partition).
            if live is None:
                part = self.topology.fastest_static_partition()
                core = part.start + self._fa_rr % part.size
            else:
                # fastest *live* partition; round-robin over its live
                # cores only (a sub-pod revocation may leave it partial)
                part = min(live.partitions, key=lambda p: p.static_rank)
                cs = live.cores_of(part)
                core = cs[self._fa_rr % len(cs)]
            self._fa_rr += 1
            if self.moldable:
                # FAM-C: cost-minimizing width inside the fast partition
                # (the local-search candidates of ``core`` are exactly the
                # aligned places of each valid width containing it).
                tbl = self.ptt.for_type(task.type.name)
                lidx = self._local_indices(core)
                if self._force_revisit():
                    task.bound_place = tbl.stalest(
                        self.topology.local_place_indices(core)
                        if lidx is None else lidx,
                        rng=self.revisit_rng)
                else:
                    load, pen = self._load_penalty()
                    task.bound_place = tbl.local_search(
                        core, cost=True, rng=self.search_rng,
                        load=load, penalty=pen, idx=lidx,
                        score_fn=self.score_fn)
            else:
                task.bound_place = self.topology.place_at(core, 1)
            return task.bound_place.leader
        if self.dynamic:
            tname = task.type.name
            tbl = self._tbl_cache.get(tname)
            if tbl is None:
                tbl = self._tbl_cache[tname] = self.ptt.for_type(tname)
            # _force_revisit / _load_penalty inlined: both are
            # None-guarded no-ops in the default configuration, and this
            # is the hottest placement call in the DES
            rr = self.revisit_rng
            if not self.moldable:
                # DA: fastest single core (global search, width locked to 1).
                if rr is not None and rr.random() < self.revisit_eps:
                    task.bound_place = tbl.stalest(
                        self.topology.width1_place_indices if live is None
                        else live.width1_idx,
                        rng=rr)
                else:
                    if self.queue_penalty > 0.0 and self.load_view is not None:
                        load, pen = self.load_view(), self.queue_penalty
                    else:
                        load, pen = None, 0.0
                    sf = self.score_fn
                    if sf is None:
                        task.bound_place = tbl.width1_search(
                            cost=False, rng=self.search_rng,
                            idx=None if live is None else live.width1_idx,
                            load=load, penalty=pen)
                    else:
                        task.bound_place = tbl.width1_search(
                            cost=False, rng=self.search_rng,
                            idx=None if live is None else live.width1_idx,
                            load=load, penalty=pen, score_fn=sf)
            else:
                # Algorithm 1 lines 6-12: global search, cost (DAM-C) or
                # pure performance (DAM-P).
                if rr is not None and rr.random() < self.revisit_eps:
                    task.bound_place = tbl.stalest(
                        None if live is None else live.place_idx,
                        rng=rr)
                else:
                    if self.queue_penalty > 0.0 and self.load_view is not None:
                        load, pen = self.load_view(), self.queue_penalty
                    else:
                        load, pen = None, 0.0
                    sf = self.score_fn
                    if sf is None:
                        task.bound_place = tbl.global_search(
                            cost=self.high_target_cost, rng=self.search_rng,
                            idx=None if live is None else live.place_idx,
                            load=load, penalty=pen)
                    else:
                        task.bound_place = tbl.global_search(
                            cost=self.high_target_cost, rng=self.search_rng,
                            idx=None if live is None else live.place_idx,
                            load=load, penalty=pen, score_fn=sf)
            return task.bound_place.leader
        return None                          # RWS/RWSM-C: no special handling

    # -- dequeue-time placement ----------------------------------------------
    def place_on_dequeue(self, task: Task, worker_core: int) -> ExecutionPlace:
        """Final execution place chosen by the worker that will run it."""
        if task.bound_place is not None:
            return task.bound_place
        if not self.moldable:
            return self.topology.place_at(worker_core, 1)
        # Algorithm 1 lines 3-5: local search minimizing TM(c,w)*width.
        tname = task.type.name
        tbl = self._tbl_cache.get(tname)
        if tbl is None:
            tbl = self._tbl_cache[tname] = self.ptt.for_type(tname)
        live = self.live
        lidx = (None if live is None or not live.partial
                else self._local_indices(worker_core))
        rr = self.revisit_rng
        if rr is not None and rr.random() < self.revisit_eps:
            return tbl.stalest(self.topology.local_place_indices(worker_core)
                               if lidx is None else lidx,
                               rng=rr)
        sf = self.score_fn
        if self.queue_penalty > 0.0 and self.load_view is not None:
            return tbl.local_search(
                worker_core, cost=True, rng=self.search_rng,
                load=self.load_view(), penalty=self.queue_penalty, idx=lidx,
                score_fn=sf)
        if sf is not None:
            return tbl.local_search(worker_core, cost=True,
                                    rng=self.search_rng, idx=lidx,
                                    score_fn=sf)
        if lidx is None:
            return tbl.local_search_cost(worker_core, self.search_rng)
        return tbl.local_search(worker_core, cost=True, rng=self.search_rng,
                                idx=lidx)

    def may_steal(self, task: Task) -> bool:
        return self.steal_high or task.priority != Priority.HIGH


def make_scheduler(name: str, topology: Topology, *, seed: int = 0,
                   ptt_new_weight: float = 1.0, ptt_old_weight: float = 4.0,
                   ptt_tiebreak: str = "shared",
                   ptt_revisit: float = 0.0,
                   queue_penalty: float = 0.0,
                   track_load: bool = False,
                   placement_backend: str = "numpy") -> Scheduler:
    """Factory for the paper's seven configurations (Table 1).

    ``ptt_tiebreak`` selects where PTT-search tie-breaks draw from:
    ``"shared"`` (paper-faithful default) uses the scheduler's main RNG;
    ``"seeded"`` uses a dedicated deterministic stream derived from
    ``seed``, decoupling placement tie-breaks from the measurement-noise
    and steal streams (see module docstring).

    ``ptt_revisit`` (off at 0.0, the paper-faithful default) enables the
    explore-exploit escape hatch: each PTT placement search returns the
    stalest candidate instead of the argmin with this probability, so a
    poisoned entry is eventually re-measured.  Draws use a dedicated
    stream seeded from ``seed``; 0.0 is bit-identical to builds without
    the hatch.

    ``queue_penalty`` (off at 0.0, the paper-faithful default) makes every
    PTT placement search queue-aware: the score becomes ``ptt_estimate +
    queue_penalty * outstanding_seconds(place)``, so bursts of concurrent
    HIGH wakes spread instead of herding onto one argmin place.  0.0 is
    bit-identical to load-oblivious placement.  ``track_load`` enables the
    kernel's outstanding-work accounting without the penalty term.

    ``placement_backend`` selects who computes the placement score
    vector: ``"numpy"`` (default — the exact golden-pinned path) or
    ``"jax"``, which routes it through a jitted kernel (see
    :mod:`.placement_jax` for the bitwise caveats).  The argmin
    tie-break tail is host-side either way, so the RNG draw sequence is
    backend-independent; with ``queue_penalty == 0`` the jax backend is
    bit-identical to numpy.  Requires jax; raises ``ImportError``
    otherwise rather than silently falling back.
    """
    bank = PTTBank(topology, new_weight=ptt_new_weight, old_weight=ptt_old_weight)
    rng = random.Random(seed)
    if ptt_tiebreak == "shared":
        tiebreak_rng = None
    elif ptt_tiebreak == "seeded":
        # string seeding hashes via sha512 — stable across processes and
        # Python versions, unlike hash() of a tuple
        tiebreak_rng = random.Random(f"ptt-tiebreak:{seed}")
    else:
        raise ValueError(f"unknown ptt_tiebreak {ptt_tiebreak!r} "
                         "(expected 'shared' or 'seeded')")
    if not 0.0 <= ptt_revisit < 1.0:
        raise ValueError(f"ptt_revisit {ptt_revisit!r} outside [0, 1)")
    revisit_rng = (random.Random(f"ptt-revisit:{seed}")
                   if ptt_revisit > 0.0 else None)
    if queue_penalty < 0.0:
        raise ValueError(f"queue_penalty {queue_penalty!r} must be >= 0")
    if placement_backend == "numpy":
        score_fn = None
    elif placement_backend == "jax":
        from .placement_jax import make_score_fn
        score_fn = make_score_fn()
    else:
        raise ValueError(f"unknown placement_backend {placement_backend!r} "
                         "(expected 'numpy' or 'jax')")
    n = name.upper()
    common = dict(topology=topology, ptt=bank, rng=rng,
                  tiebreak_rng=tiebreak_rng, revisit_eps=ptt_revisit,
                  revisit_rng=revisit_rng, queue_penalty=queue_penalty,
                  track_load=track_load, score_fn=score_fn)
    if n == "RWS":
        # priority-oblivious: plain LIFO dequeue, HIGH stealable
        return Scheduler("RWS", steal_high=True, priority_dequeue=False,
                         **common)
    if n == "RWSM-C":
        # extends RWS: still no priority awareness in queues or stealing
        return Scheduler("RWSM-C", moldable=True, steal_high=True,
                         priority_dequeue=False, **common)
    if n == "FA":
        return Scheduler("FA", fixed_asym=True, **common)
    if n == "FAM-C":
        return Scheduler("FAM-C", fixed_asym=True, moldable=True, **common)
    if n == "DA":
        return Scheduler("DA", dynamic=True, **common)
    if n == "DAM-C":
        return Scheduler("DAM-C", dynamic=True, moldable=True,
                         high_target_cost=True, **common)
    if n == "DAM-P":
        return Scheduler("DAM-P", dynamic=True, moldable=True,
                         high_target_cost=False, **common)
    raise ValueError(f"unknown scheduler {name!r}")


ALL_SCHEDULERS = ("RWS", "RWSM-C", "FA", "FAM-C", "DA", "DAM-C", "DAM-P")
