"""DAG builders (paper §2, §4.2.2).

* ``synthetic_dag`` — the paper's synthetic benchmark: layers of P
  same-type tasks (P = DAG parallelism); exactly one task per layer is
  HIGH priority and releases the next layer when it commits.
* ``mixed_dag`` — heterogeneous-mix variant: the layers cycle through
  several task *types* (e.g. matmul / copy / stencil), each layer keeping
  its own critical task, so one DAG stresses every per-type PTT at once
  (cf. the mixed-workload motivation of arXiv:1905.00673).
* ``kmeans_dag`` — K-means as a *dynamic* DAG: each iteration spawns map
  tasks + one HIGH-priority reduce task whose commit inserts the next
  iteration's tasks at runtime.
* ``heat_dag`` — distributed 2D Heat: per node per iteration, stencil
  compute tasks (LOW) + ghost-cell exchange tasks (HIGH, paper §4.2.2:
  "Due to the criticality of such communication, these MPI tasks are
  marked as high priority").
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional, Sequence

from .task import (Priority, Task, TaskType, kmeans_map_type,
                   kmeans_reduce_type, mpi_exchange_type, stencil_type)


@dataclasses.dataclass
class DAG:
    """Root tasks + total count (total includes dynamically inserted tasks
    only after they are inserted; ``expected_total`` is for reporting)."""

    roots: list[Task]
    expected_total: int

    def all_tasks(self) -> list[Task]:
        """Breadth-first enumeration of the *static* portion of the DAG,
        deduplicated: a node reachable along several paths (a diamond's
        join) appears exactly once, at its first-discovered depth.  Order
        is deterministic — roots in submission order, then each level in
        parent order, children in ``add_child`` order."""
        seen: dict[int, Task] = {}
        queue = deque(self.roots)
        while queue:
            t = queue.popleft()
            if t.tid in seen:
                continue
            seen[t.tid] = t
            queue.extend(t.children)
        return list(seen.values())


def _layered_dag(type_of_layer: Callable[[int], TaskType], *,
                 parallelism: int, total_tasks: int) -> DAG:
    """Shared layered-DAG skeleton: layer i holds ``parallelism`` tasks of
    ``type_of_layer(i)`` (the final layer holds the remainder when
    ``total_tasks`` is not a multiple — never silently dropped), the first
    task of each layer is the critical HIGH task and releases the next
    layer when it commits."""
    if parallelism < 1 or total_tasks < parallelism:
        raise ValueError("need total_tasks >= parallelism >= 1")
    roots: list[Task] = []
    prev_critical: Optional[Task] = None
    built, layer = 0, 0
    while built < total_tasks:
        width = min(parallelism, total_tasks - built)
        task_type = type_of_layer(layer)
        layer_tasks = [Task(task_type) for _ in range(width)]
        layer_tasks[0].priority = Priority.HIGH      # the critical task
        if prev_critical is None:
            roots.extend(layer_tasks)
        else:
            for t in layer_tasks:
                prev_critical.add_child(t)
        prev_critical = layer_tasks[0]
        built += width
        layer += 1
    return DAG(roots, built)


def synthetic_dag(task_type: TaskType, *, parallelism: int,
                  total_tasks: int) -> DAG:
    """Paper §4.2.2: each layer has P tasks of the same type; one is marked
    critical; its completion releases the next P tasks.  DAG parallelism =
    total/longest-path = P.  A non-divisible ``total_tasks`` emits a final
    partial layer (``expected_total`` always equals ``total_tasks``)."""
    return _layered_dag(lambda _layer: task_type, parallelism=parallelism,
                        total_tasks=total_tasks)


def mixed_dag(task_types: Sequence[TaskType], *, parallelism: int,
              total_tasks: int) -> DAG:
    """Heterogeneous-mix synthetic DAG: layer i holds ``parallelism``
    tasks of ``task_types[i % len(task_types)]`` — interleaved e.g.
    matmul / copy / stencil layers — with the same per-layer criticality
    structure as :func:`synthetic_dag` (first task of every layer is HIGH
    and gates the next layer).  Because each task type owns its own PTT,
    one run exercises several trace tables and the schedulers must keep
    per-type placement models current simultaneously."""
    types = tuple(task_types)
    if not types:
        raise ValueError("mixed_dag needs at least one task type")
    return _layered_dag(lambda layer: types[layer % len(types)],
                        parallelism=parallelism, total_tasks=total_tasks)


def chain_dag(task_type: TaskType, length: int) -> DAG:
    """A single serial chain — the co-running application's shape."""
    head = Task(task_type)
    cur = head
    for _ in range(length - 1):
        cur = cur.add_child(Task(task_type))
    return DAG([head], length)


def decode_pool_dag(prefill_type: TaskType, decode_type: TaskType, *,
                    n_requests: int, steps: int,
                    batch_key: Optional[str] = "decode") -> DAG:
    """Serving-shaped DAG for the queue-level continuous-batching path:
    ``n_requests`` independent chains, each a HIGH prefill releasing
    ``steps`` LOW decode tasks marked with ``batch_key``.  At any instant
    each chain has at most one ready decode step, so the tasks queued
    under the shared key across chains are exactly the coalescible set —
    the same population the serving engine's DecodeBatcher sees.
    ``batch_key=None`` builds the identical DAG with coalescing off (the
    control for degeneracy tests)."""
    if n_requests < 1 or steps < 0:
        raise ValueError("need n_requests >= 1 and steps >= 0")
    roots: list[Task] = []
    for _ in range(n_requests):
        head = Task(prefill_type, priority=Priority.HIGH)
        cur = head
        for _ in range(steps):
            nxt = Task(decode_type, priority=Priority.LOW)
            nxt.batch_key = batch_key
            cur = cur.add_child(nxt)
        roots.append(head)
    return DAG(roots, n_requests * (1 + steps))


def kmeans_dag(*, n_points: int = 200_000, dims: int = 16, k: int = 8,
               n_chunks: int = 32, iterations: int = 80,
               on_iteration: Optional[Callable[[int], None]] = None) -> DAG:
    """K-means as a dynamic DAG (paper §4.2.2 + §5.4): loop partitions
    become dynamically scheduled map tasks; the reduce task carries the
    largest work unit and is HIGH priority; committing it *inserts* the
    next iteration (dynamic DAG growth via ``on_commit``)."""
    map_type = kmeans_map_type(n_points // n_chunks, dims, k)
    red_type = kmeans_reduce_type(k, dims, n_chunks)

    def make_iteration(it: int) -> list[Task]:
        maps = [Task(map_type) for _ in range(n_chunks)]
        reduce_t = Task(red_type, priority=Priority.HIGH)
        for m in maps:
            m.add_child(reduce_t)

        def commit_hook(_task: Task, _it: int = it) -> list[Task]:
            if on_iteration is not None:
                on_iteration(_it)
            if _it + 1 < iterations:
                return make_iteration(_it + 1)
            return []

        reduce_t.on_commit = commit_hook
        return maps                       # maps are the ready roots

    return DAG(make_iteration(0), iterations * (n_chunks + 1))


def heat_dag(*, nodes: int = 4, tiles_per_node: int = 20, tile: int = 1024,
             iterations: int = 60, boundary_kb: float = 256.0) -> DAG:
    """Distributed 2D Heat (paper §4.2.2, Fig. 10): iterative stencil over a
    row-partitioned grid.  Per node and iteration: ``tiles_per_node``
    stencil tasks (LOW) + one boundary-exchange task per neighbor (HIGH).
    The stencil tasks of iteration i+1 on node n are gated by node n's own
    exchanges of iteration i *and* by each neighbor's exchange directed at
    n (explicitly keyed by destination node below — the old list-index
    gating encoded the direction implicitly in creation order); compute
    tasks gate their own node's exchanges."""
    st = stencil_type(tile)
    ex = mpi_exchange_type(boundary_kb)

    roots: list[Task] = []
    # prev iteration's exchange tasks, keyed by destination neighbor:
    # prev_ex[n][m] is node n's ghost-cell send *toward node m*
    prev_ex: list[dict[int, Task]] = [{} for _ in range(nodes)]
    total = 0
    for it in range(iterations):
        cur_compute: list[list[Task]] = []
        for n in range(nodes):
            comp = [Task(st) for _ in range(tiles_per_node)]
            total += len(comp)
            if it == 0:
                roots.extend(comp)
            else:
                # stencil of iter i depends on own exchanges of i-1 plus
                # the neighbors' exchanges directed at this node
                gates = list(prev_ex[n].values())
                if n > 0:
                    gates.append(prev_ex[n - 1][n])
                if n + 1 < nodes:
                    gates.append(prev_ex[n + 1][n])
                for g in gates:
                    for c in comp:
                        g.add_child(c)
            cur_compute.append(comp)
        cur_ex: list[dict[int, Task]] = []
        for n in range(nodes):
            exs = {nb: Task(ex, priority=Priority.HIGH)
                   for nb in (n - 1, n + 1) if 0 <= nb < nodes}
            total += len(exs)
            for c in cur_compute[n]:
                for e in exs.values():
                    c.add_child(e)
            cur_ex.append(exs)
        prev_ex = cur_ex
    return DAG(roots, total)
