"""DAG builders (paper §2, §4.2.2).

* ``synthetic_dag`` — the paper's synthetic benchmark: layers of P
  same-type tasks (P = DAG parallelism); exactly one task per layer is
  HIGH priority and releases the next layer when it commits.
* ``kmeans_dag`` — K-means as a *dynamic* DAG: each iteration spawns map
  tasks + one HIGH-priority reduce task whose commit inserts the next
  iteration's tasks at runtime.
* ``heat_dag`` — distributed 2D Heat: per node per iteration, stencil
  compute tasks (LOW) + ghost-cell exchange tasks (HIGH, paper §4.2.2:
  "Due to the criticality of such communication, these MPI tasks are
  marked as high priority").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from .task import (Priority, Task, TaskType, kmeans_map_type,
                   kmeans_reduce_type, mpi_exchange_type, stencil_type)


@dataclasses.dataclass
class DAG:
    """Root tasks + total count (total includes dynamically inserted tasks
    only after they are inserted; ``expected_total`` is for reporting)."""

    roots: list[Task]
    expected_total: int

    def all_tasks(self) -> list[Task]:
        """BFS enumeration of the *static* portion of the DAG."""
        seen: dict[int, Task] = {}
        stack = list(self.roots)
        while stack:
            t = stack.pop()
            if t.tid in seen:
                continue
            seen[t.tid] = t
            stack.extend(t.children)
        return list(seen.values())


def synthetic_dag(task_type: TaskType, *, parallelism: int,
                  total_tasks: int) -> DAG:
    """Paper §4.2.2: each layer has P tasks of the same type; one is marked
    critical; its completion releases the next P tasks.  DAG parallelism =
    total/longest-path = P."""
    if parallelism < 1 or total_tasks < parallelism:
        raise ValueError("need total_tasks >= parallelism >= 1")
    n_layers = total_tasks // parallelism
    roots: list[Task] = []
    prev_critical: Optional[Task] = None
    for layer in range(n_layers):
        layer_tasks = [Task(task_type) for _ in range(parallelism)]
        layer_tasks[0].priority = Priority.HIGH      # the critical task
        if prev_critical is None:
            roots.extend(layer_tasks)
        else:
            for t in layer_tasks:
                prev_critical.add_child(t)
        prev_critical = layer_tasks[0]
    return DAG(roots, n_layers * parallelism)


def chain_dag(task_type: TaskType, length: int) -> DAG:
    """A single serial chain — the co-running application's shape."""
    head = Task(task_type)
    cur = head
    for _ in range(length - 1):
        cur = cur.add_child(Task(task_type))
    return DAG([head], length)


def kmeans_dag(*, n_points: int = 200_000, dims: int = 16, k: int = 8,
               n_chunks: int = 32, iterations: int = 80,
               on_iteration: Optional[Callable[[int], None]] = None) -> DAG:
    """K-means as a dynamic DAG (paper §4.2.2 + §5.4): loop partitions
    become dynamically scheduled map tasks; the reduce task carries the
    largest work unit and is HIGH priority; committing it *inserts* the
    next iteration (dynamic DAG growth via ``on_commit``)."""
    map_type = kmeans_map_type(n_points // n_chunks, dims, k)
    red_type = kmeans_reduce_type(k, dims, n_chunks)

    def make_iteration(it: int) -> list[Task]:
        maps = [Task(map_type) for _ in range(n_chunks)]
        reduce_t = Task(red_type, priority=Priority.HIGH)
        for m in maps:
            m.add_child(reduce_t)

        def commit_hook(_task: Task, _it: int = it) -> list[Task]:
            if on_iteration is not None:
                on_iteration(_it)
            if _it + 1 < iterations:
                return make_iteration(_it + 1)
            return []

        reduce_t.on_commit = commit_hook
        return maps                       # maps are the ready roots

    return DAG(make_iteration(0), iterations * (n_chunks + 1))


def heat_dag(*, nodes: int = 4, tiles_per_node: int = 20, tile: int = 1024,
             iterations: int = 60, boundary_kb: float = 256.0) -> DAG:
    """Distributed 2D Heat (paper §4.2.2, Fig. 10): iterative stencil over a
    row-partitioned grid.  Per node and iteration: ``tiles_per_node``
    stencil tasks (LOW) + one boundary-exchange task per neighbor (HIGH).
    The exchange tasks of iteration i gate iteration i+1 of *both*
    neighboring nodes; compute tasks gate their own node's exchanges."""
    st = stencil_type(tile)
    ex = mpi_exchange_type(boundary_kb)

    roots: list[Task] = []
    # prev iteration's per-node exchange tasks (to wire cross-node deps)
    prev_ex: list[list[Task]] = [[] for _ in range(nodes)]
    prev_compute: list[list[Task]] = [[] for _ in range(nodes)]
    total = 0
    for it in range(iterations):
        cur_compute: list[list[Task]] = []
        for n in range(nodes):
            comp = [Task(st) for _ in range(tiles_per_node)]
            total += len(comp)
            if it == 0:
                roots.extend(comp)
            else:
                # stencil of iter i depends on own + neighbor exchanges of i-1
                gates = list(prev_ex[n])
                if n > 0:
                    gates += [prev_ex[n - 1][-1]] if prev_ex[n - 1] else []
                if n + 1 < nodes:
                    gates += [prev_ex[n + 1][0]] if prev_ex[n + 1] else []
                for g in gates:
                    for c in comp:
                        g.add_child(c)
            cur_compute.append(comp)
        cur_ex: list[list[Task]] = []
        for n in range(nodes):
            n_neigh = (1 if n > 0 else 0) + (1 if n + 1 < nodes else 0)
            exs = [Task(ex, priority=Priority.HIGH) for _ in range(n_neigh)]
            total += len(exs)
            for c in cur_compute[n]:
                for e in exs:
                    c.add_child(e)
            cur_ex.append(exs)
        prev_ex, prev_compute = cur_ex, cur_compute
    return DAG(roots, total)
