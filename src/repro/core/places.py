"""Execution places and resource partitions (paper §2).

An *execution place* is a tuple ``(leader core, resource width)``: the task
runs on cores ``[leader, leader + width)``.  A *resource partition* is a set
of cores sharing a resource domain (an L2 cluster on the TX2, a socket on
Haswell, an ICI domain / pod slice on TPU).  Valid widths are per-partition
and places are width-aligned within their partition, mirroring XiTAO.

The :class:`Topology` additionally pre-computes dense index arrays over its
place list (leaders, widths, per-core local-search candidates, width-1
subset) so the PTT searches can run as vectorized argmins instead of
per-place Python loops, and interns the :class:`ExecutionPlace` objects so
the simulator hot path never re-allocates them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExecutionPlace:
    """(leader core, width) — cores [leader, leader+width)."""

    leader: int
    width: int

    @functools.cached_property
    def cores(self) -> tuple[int, ...]:
        return tuple(range(self.leader, self.leader + self.width))

    def __repr__(self) -> str:  # matches the paper's (Cx, w) notation
        return f"(C{self.leader},{self.width})"


@dataclasses.dataclass(frozen=True)
class ResourcePartition:
    """A contiguous set of cores sharing a resource domain.

    ``kind`` identifies the hardware class (e.g. "denver", "a57", "haswell",
    "pod") — task base costs are defined per kind.  ``static_rank`` orders
    partitions by *static* (design-time) speed: rank 0 is the statically
    fastest; this is what the FA/FAM-C schedulers key on.
    """

    name: str
    kind: str
    start: int
    size: int
    widths: tuple[int, ...]
    static_rank: int = 0
    bw_domain: str = ""     # shared-memory-bandwidth domain ("" = own name)

    @property
    def domain(self) -> str:
        return self.bw_domain or self.name

    def __post_init__(self) -> None:
        for w in self.widths:
            if w <= 0 or w > self.size or self.size % w:
                raise ValueError(f"invalid width {w} for partition size {self.size}")

    @property
    def cores(self) -> tuple[int, ...]:
        return tuple(range(self.start, self.start + self.size))

    def places(self) -> Iterator[ExecutionPlace]:
        """All width-aligned execution places in this partition."""
        for w in self.widths:
            for leader in range(self.start, self.start + self.size, w):
                yield ExecutionPlace(leader, w)

    def place_containing(self, core: int, width: int) -> ExecutionPlace:
        """The aligned place of ``width`` that contains ``core``."""
        if width not in self.widths:
            raise ValueError(f"width {width} not valid for {self.name}")
        off = (core - self.start) // width * width
        return ExecutionPlace(self.start + off, width)


@dataclasses.dataclass(frozen=True)
class LiveView:
    """The surviving fraction of a topology while some capacity is revoked
    (pod-slice preemption, maintenance events) or fenced off (a control-
    plane shard restricted to its own pods).

    Precomputed index arrays mirror the Topology's dense search metadata so
    the PTT searches can run masked argmins over live places only.  A place
    is live iff *all* its cores are live — for partition-granular down-sets
    (how full revocations arrive) that reduces to the leader test, but
    sub-pod revocations may take a core subset and leave its partition
    partially up (``partial``).  Views are interned per down-set on the
    Topology (:meth:`Topology.live_view` /
    :meth:`Topology.live_view_cores`), so revoke/restore churn never
    re-allocates them.
    """

    place_idx: "np.ndarray"           # indices into topology.places()
    width1_idx: "np.ndarray"          # the width-1 subset of place_idx
    partitions: tuple[ResourcePartition, ...]   # >=1 live core, topo order
    cores: tuple[int, ...]            # live cores, in topology order
    part_cores: tuple[tuple[int, ...], ...] = ()  # live cores per partition
    partial: bool = False             # some live partition is missing cores
    core_set: frozenset = frozenset()  # O(1) membership over ``cores``

    def cores_of(self, partition: ResourcePartition) -> tuple[int, ...]:
        """Live cores of ``partition`` (must be in ``partitions``)."""
        return self.part_cores[self.partitions.index(partition)]


class Topology:
    """A machine: an ordered list of resource partitions over cores 0..N-1."""

    def __init__(self, partitions: Sequence[ResourcePartition]):
        self.partitions = tuple(partitions)
        cores: list[int] = []
        for p in self.partitions:
            cores.extend(p.cores)
        if sorted(cores) != list(range(len(cores))):
            raise ValueError("partitions must tile cores 0..N-1 exactly")
        self.n_cores = len(cores)
        self._part_of = {c: p for p in self.partitions for c in p.cores}
        self._places = tuple(pl for p in self.partitions for pl in p.places())
        self.max_width = max(w for p in self.partitions for w in p.widths)

        # dense search metadata (vectorized PTT argmins + place interning)
        self._place_idx = {(pl.leader, pl.width): i
                           for i, pl in enumerate(self._places)}
        self.place_leaders = np.array([pl.leader for pl in self._places],
                                      dtype=np.int64)
        self.place_widths = np.array([pl.width for pl in self._places],
                                     dtype=np.int64)
        self.place_widths_f = self.place_widths.astype(np.float64)
        self.width1_place_indices = np.flatnonzero(self.place_widths == 1)
        self._local_idx: dict[int, np.ndarray] = {}
        self._live_views: dict[frozenset, LiveView] = {}
        self._live_views_cores: dict[frozenset, LiveView] = {}

    def partition_of(self, core: int) -> ResourcePartition:
        return self._part_of[core]

    def places(self) -> tuple[ExecutionPlace, ...]:
        return self._places

    def place_at(self, leader: int, width: int) -> ExecutionPlace:
        """The interned (shared) place object for ``(leader, width)``."""
        return self._places[self._place_idx[(leader, width)]]

    def place_index(self, leader: int, width: int) -> int:
        return self._place_idx[(leader, width)]

    def local_places(self, core: int) -> list[ExecutionPlace]:
        """Places containing ``core`` — the *local search* candidates (one
        per valid width of the core's partition, leader kept aligned)."""
        places = self._places
        return [places[i] for i in self.local_place_indices(core)]

    def local_place_indices(self, core: int) -> np.ndarray:
        """Indices (into ``places()``) of the local-search candidates."""
        idx = self._local_idx.get(core)
        if idx is None:
            part = self.partition_of(core)
            idx = np.array(
                [self.place_index(pl.leader, pl.width)
                 for pl in (part.place_containing(core, w) for w in part.widths)],
                dtype=np.int64)
            self._local_idx[core] = idx
        return idx

    def fastest_static_partition(self) -> ResourcePartition:
        return min(self.partitions, key=lambda p: p.static_rank)

    def live_view(self, down_partitions: frozenset) -> LiveView:
        """The :class:`LiveView` with the partitions at indices
        ``down_partitions`` revoked.  Views are interned per down-set, so
        repeated revoke/restore cycles through the same configurations hit
        the cache.  Raises if *every* partition would be down — episode
        generation prunes such windows, and the schedulers need somewhere
        to place work."""
        view = self._live_views.get(down_partitions)
        if view is None:
            n = len(self.partitions)
            for i in down_partitions:
                if not 0 <= i < n:
                    raise ValueError(f"partition index {i} outside 0..{n - 1}")
            down_cores = frozenset(c for i in down_partitions
                                   for c in self.partitions[i].cores)
            view = self.live_view_cores(down_cores)
            self._live_views[down_partitions] = view
        return view

    def live_view_cores(self, down_cores: frozenset) -> LiveView:
        """Core-granular :class:`LiveView`: the cores in ``down_cores`` are
        revoked; a partition stays listed while it has at least one live
        core (``partial`` flags views where some listed partition is
        incomplete).  Partition-granular down-sets produce the exact same
        arrays :meth:`live_view` always built, so full-partition callers
        are behavior-identical through this path."""
        view = self._live_views_cores.get(down_cores)
        if view is None:
            for c in down_cores:
                if not 0 <= c < self.n_cores:
                    raise ValueError(
                        f"core {c} outside 0..{self.n_cores - 1}")
            live_parts, part_cores = [], []
            for p in self.partitions:
                cs = tuple(c for c in p.cores if c not in down_cores)
                if cs:
                    live_parts.append(p)
                    part_cores.append(cs)
            if not live_parts:
                raise ValueError("cannot revoke every partition")
            live_cores = tuple(c for cs in part_cores for c in cs)
            core_up = np.zeros(self.n_cores, dtype=bool)
            core_up[list(live_cores)] = True
            # a place is live iff all its cores are — places never cross
            # partitions, so for full-partition down-sets this is exactly
            # the old leader test
            down_cum = np.concatenate(([0], np.cumsum(~core_up)))
            idx = np.flatnonzero(
                down_cum[self.place_leaders + self.place_widths]
                == down_cum[self.place_leaders])
            w1 = idx[self.place_widths[idx] == 1]
            partial = any(len(cs) != p.size
                          for cs, p in zip(part_cores, live_parts))
            view = LiveView(idx, w1, tuple(live_parts), live_cores,
                            tuple(part_cores), partial,
                            frozenset(live_cores))
            self._live_views_cores[down_cores] = view
        return view

    def __repr__(self) -> str:
        inner = ", ".join(f"{p.name}[{p.start}:{p.start + p.size}]" for p in self.partitions)
        return f"Topology({inner})"


# ---------------------------------------------------------------------------
# Platform presets used throughout the paper's evaluation + the TPU target.
# ---------------------------------------------------------------------------

def tx2() -> Topology:
    """NVIDIA Jetson TX2: 2 Denver cores (fast, widths 1/2) + 4 ARM A57
    cores (widths 1/2/4), each cluster with its own shared L2 (paper §2)."""
    return Topology([
        ResourcePartition("denver", "denver", 0, 2, (1, 2), static_rank=0,
                          bw_domain="lpddr4"),
        ResourcePartition("a57", "a57", 2, 4, (1, 2, 4), static_rank=1,
                          bw_domain="lpddr4"),
    ])


def tx2_xl(clusters: int = 4) -> Topology:
    """Synthetic scaled-up TX2-class SoC: ``clusters`` pairs of (2-core
    Denver, 4-core A57) clusters, each pair sharing an LPDDR4-style memory
    domain.  Not a real device — a stress topology for the scheduler sweeps
    (6 x clusters cores, same asymmetry structure as the TX2)."""
    parts = []
    for i in range(clusters):
        base = 6 * i
        parts.append(ResourcePartition(
            f"denver{i}", "denver", base, 2, (1, 2), static_rank=0,
            bw_domain=f"lpddr4_{i}"))
        parts.append(ResourcePartition(
            f"a57_{i}", "a57", base + 2, 4, (1, 2, 4), static_rank=1,
            bw_domain=f"lpddr4_{i}"))
    return Topology(parts)


def _divisor_widths(size: int) -> tuple[int, ...]:
    return tuple(w for w in (1, 2, 4, 5, 8, 10, 16) if w <= size and size % w == 0)


def haswell(sockets: int = 2, cores_per_socket: int = 10) -> Topology:
    """Dual-socket 10-core Intel 2650v3 node (paper §4.2.1) — statically
    symmetric, so all partitions share static_rank 0."""
    parts = [
        ResourcePartition(
            f"socket{s}", "haswell", s * cores_per_socket, cores_per_socket,
            _divisor_widths(cores_per_socket), static_rank=0,
        )
        for s in range(sockets)
    ]
    return Topology(parts)


def haswell_cluster(nodes: int = 4, sockets: int = 2, cores_per_socket: int = 10) -> Topology:
    """4-node Haswell cluster (80 cores) used for the distributed 2D Heat."""
    parts = []
    for n in range(nodes):
        for s in range(sockets):
            start = (n * sockets + s) * cores_per_socket
            parts.append(ResourcePartition(
                f"n{n}s{s}", "haswell", start, cores_per_socket,
                _divisor_widths(cores_per_socket), static_rank=0,
            ))
    return Topology(parts)


# Static speed ranks of the TPU pod generations (rank 0 = fastest): what
# the FA/FAM-C schedulers key on in a mixed-generation fleet.
_POD_RANKS = {"pod": 0, "pod_v4": 1}


def tpu_pod_slices(pods: int = 2, slices_per_pod: int = 16,
                   kinds: Optional[Sequence[str]] = None) -> Topology:
    """TPU adaptation: each 'core' is a pod *slice* (an ICI-connected group
    of chips); a partition is a pod.  Valid widths are powers of two —
    moldability = how many slices a dispatched program spans.

    ``kinds`` assigns a generation per pod (default: all current-gen
    ``"pod"``).  A mixed fleet — e.g. ``("pod", "pod_v4", "pod_v4")``, one
    current-gen pod plus older v4 pods at roughly half its rates — is the
    statically *asymmetric* cloud configuration the preemption benchmarks
    sweep: revoking the fast pod forces criticality-aware schedulers to
    fall back to the statically-next-best live pods."""
    if kinds is None:
        kinds = ("pod",) * pods
    if len(kinds) != pods:
        raise ValueError(f"kinds has {len(kinds)} entries for {pods} pods")
    for k in kinds:
        if k not in _POD_RANKS:
            raise ValueError(f"unknown pod kind {k!r}; "
                             f"known: {', '.join(sorted(_POD_RANKS))}")
    widths = tuple(w for w in (1, 2, 4, 8, 16)
                   if w <= slices_per_pod and slices_per_pod % w == 0)
    parts = [
        ResourcePartition(f"pod{p}", kinds[p], p * slices_per_pod,
                          slices_per_pod, widths,
                          static_rank=_POD_RANKS[kinds[p]])
        for p in range(pods)
    ]
    return Topology(parts)
