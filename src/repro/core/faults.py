"""Task-level fault injection + criticality-aware recovery policies.

The interference and preemption layers degrade *platforms* (slowdown
profiles, whole-pod revocation); this module degrades *tasks*.  Two fault
classes, both seeded and engine-agnostic:

* **fail-stop** — a task dies at a seeded fraction of its work.  In the
  DES the execution is cut at that fraction of the assigned work; in the
  threaded engine the same decision marks the execution failed after the
  payload runs (a Python frame cannot be killed mid-flight, so the wall
  time is the lost work) and *real* payload exceptions feed the identical
  path.
* **fail-slow** — the task's place silently degrades mid-execution: from
  a seeded work fraction onward the execution proceeds at ``1/factor``
  rate (DES) or is stretched by ``factor`` (threaded).  Nothing fails, so
  retry policies are blind to it — this is the regime straggler hedging
  exists for.

Faults are drawn per *execution attempt* from a dedicated stream
``random.Random(f"fault:{seed}:{fault_seq}:{attempt}")`` — a pure
function of the model seed, the task's deterministic DAG position
(:meth:`FaultState.register_dag`), and how many times it has already
failed.  Both engines therefore inject the *same* faults on the same DAG
(modulo the MMPP timeline, which reads each engine's own clock), which is
what the cross-engine parity test pins.  None of the draws touch the
scheduler's streams, so attaching a zero-probability model (or none) is
bit-identical to a build without the subsystem.

Recovery (driven by the engines, policy here):

* **retry with backoff** — a fail-stop victim re-enters the kernel's
  ``requeue_displaced`` path after a seeded exponential backoff, with the
  failing place PTT-penalized (:meth:`SchedulingKernel.fault_feedback`)
  so the re-placement avoids it; per-task attempt budget
  ``max_retries``, beyond which the failure is permanent and surfaced in
  ``RunMetrics``.
* **straggler hedging** — an execution running past ``straggler_k`` x
  the PTT-expected duration for its (type, place) is flagged; flagged
  HIGH tasks get a speculative duplicate on the PTT-best place that
  shares no core with the original.  First commit wins; the loser is
  cancelled (DES: killed outright; threaded: nudged via the existing
  cooperative ``revoke_signal`` channel) and its work lands in
  ``work_hedged_s``.  LOW tasks are never hedged — criticality knowledge
  is exactly what makes speculation affordable.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Optional

from .dag import DAG
from .interference import mmpp_state_timeline
from .task import Task


@dataclasses.dataclass(frozen=True)
class Fault:
    """One armed fault decision for one execution attempt."""
    kind: str           # "stop" | "slow"
    frac: float         # fraction of the assigned work at which it strikes
    factor: float = 1.0  # rate divisor from the strike point on (fail-slow)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded per-task fault injection (see module docstring).

    ``p_fail`` / ``p_slow`` are per-execution-attempt probabilities of a
    fail-stop / fail-slow fault; at most one fires per attempt (fail-stop
    is drawn first).  ``fail_window`` / ``slow_window`` bound the uniform
    work fraction at which the fault strikes.  ``max_task_failures``
    bounds the fail-stop *injections* per task, so a retried task
    eventually runs clean and every DAG completes under a sufficient
    retry budget.  ``timeline`` (from :func:`mmpp_faults`) modulates both
    probabilities by ``storm_mult`` during storm segments, the correlated
    fault-burst signature; empty means constant rates.
    """

    seed: int
    p_fail: float = 0.0
    p_slow: float = 0.0
    slow_factor: float = 4.0
    fail_window: tuple[float, float] = (0.2, 0.9)
    slow_window: tuple[float, float] = (0.1, 0.6)
    max_task_failures: int = 2
    timeline: tuple[tuple[float, int], ...] = ()
    storm_mult: float = 1.0

    def __post_init__(self) -> None:
        for name in ("p_fail", "p_slow"):
            p = getattr(self, name)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"{name}={p!r} outside [0, 1]")
        if not (self.slow_factor >= 1.0 and math.isfinite(self.slow_factor)):
            raise ValueError(f"slow_factor must be >= 1, got {self.slow_factor!r}")
        for name in ("fail_window", "slow_window"):
            lo, hi = getattr(self, name)
            if not (0.0 < lo <= hi < 1.0):
                raise ValueError(f"{name}={(lo, hi)!r} must satisfy 0<lo<=hi<1")
        if self.max_task_failures < 0:
            raise ValueError("max_task_failures must be >= 0")
        if self.storm_mult < 0.0:
            raise ValueError("storm_mult must be >= 0")

    @property
    def enabled(self) -> bool:
        """False for a zero-probability model: engines treat it exactly
        like ``None`` (the bit-identity guarantee the golden pins check)."""
        return self.p_fail > 0.0 or self.p_slow > 0.0

    def mult_at(self, t: float) -> float:
        """Probability multiplier in force at time ``t`` (1.0 when calm
        or with no timeline)."""
        tl = self.timeline
        if not tl:
            return 1.0
        i = bisect.bisect_right(tl, (t, 2)) - 1
        if i < 0:
            return 1.0
        return self.storm_mult if tl[i][1] else 1.0


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What the engines do about injected (and real) task failures.

    ``backoff_base``/``backoff_cap`` are seconds on the engine's own
    clock (virtual for the DES — benchmark sweeps scale them to the
    calibrated makespan).  ``fail_penalty`` multiplies the failing
    place's PTT observation so the retry re-places elsewhere.
    ``straggler_k`` flags executions past ``k`` x the PTT expectation;
    ``hedge`` enables speculative duplicates for flagged HIGH tasks.
    ``straggler_poll_s`` is the threaded engine's monitor period (the DES
    schedules exact straggle events instead).
    """

    max_retries: int = 5
    backoff_base: float = 1e-3
    backoff_cap: float = 0.05
    fail_penalty: float = 3.0
    straggler_k: float = 3.0
    hedge: bool = False
    straggler_poll_s: float = 5e-3

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not (self.backoff_base >= 0.0 and self.backoff_cap >= 0.0):
            raise ValueError("backoff must be >= 0")
        if self.fail_penalty < 1.0:
            raise ValueError("fail_penalty must be >= 1")
        if self.straggler_k <= 1.0:
            raise ValueError("straggler_k must be > 1")
        if self.straggler_poll_s <= 0.0:
            raise ValueError("straggler_poll_s must be > 0")


class FaultState:
    """Per-run mutable companion of a (frozen) :class:`FaultModel`:
    assigns deterministic fault sequence numbers and performs the pure
    per-attempt draws.  Engines own exactly one per run."""

    def __init__(self, model: FaultModel, policy: RecoveryPolicy):
        self.model = model
        self.policy = policy
        self._next_seq = 0
        # hedging decisions (alternative-place tie-breaks) draw from their
        # own stream — never the scheduler's, or attaching a fault model
        # would perturb every placement decision after the first hedge
        self.hedge_rng = random.Random(f"fault-hedge:{model.seed}")

    def register_dag(self, dag: DAG) -> None:
        """Assign fault sequence numbers in the DAG's deterministic BFS
        order (``DAG.all_tasks``), so both engines — whose global task
        ids differ — inject identical faults at identical DAG positions.
        Dynamically created tasks (``on_commit`` children, hedge
        duplicates) get lazy numbers in creation order instead."""
        for task in dag.all_tasks():
            if task.fault_seq is None:
                task.fault_seq = self._next_seq
                self._next_seq += 1

    def seq_for(self, task: Task) -> int:
        if task.fault_seq is None:
            task.fault_seq = self._next_seq
            self._next_seq += 1
        return task.fault_seq

    def draw(self, task: Task, t: float) -> Optional[Fault]:
        """Arm (or not) a fault for this execution attempt — a pure
        function of (model seed, task's fault_seq, task's failure count);
        ``t`` only selects the MMPP modulation segment."""
        m = self.model
        rng = random.Random(f"fault:{m.seed}:{self.seq_for(task)}:"
                            f"{task.fault_count}")
        # fixed draw order regardless of parameters, so enabling fail-slow
        # never shifts which tasks fail-stop under the same seed
        u_stop = rng.random()
        u_slow = rng.random()
        f_stop = rng.uniform(*m.fail_window)
        f_slow = rng.uniform(*m.slow_window)
        mult = m.mult_at(t)
        if (m.p_fail > 0.0 and task.fault_count < m.max_task_failures
                and u_stop < min(1.0, m.p_fail * mult)):
            return Fault("stop", f_stop)
        if m.p_slow > 0.0 and u_slow < min(1.0, m.p_slow * mult):
            return Fault("slow", f_slow, m.slow_factor)
        return None

    def backoff(self, task: Task) -> float:
        """Seeded exponential backoff before retry number
        ``task.fault_count`` (already incremented by the failure):
        ``base * 2^(n-1)``, jittered uniformly in [0.5x, 1.5x], capped."""
        p = self.policy
        n = max(task.fault_count, 1)
        rng = random.Random(f"fault-backoff:{self.model.seed}:"
                            f"{self.seq_for(task)}:{n}")
        d = p.backoff_base * (2.0 ** (n - 1)) * (0.5 + rng.random())
        return min(d, p.backoff_cap)


def task_faults(*, seed: int, p_fail: float = 0.0, p_slow: float = 0.0,
                slow_factor: float = 4.0,
                fail_window: tuple[float, float] = (0.2, 0.9),
                slow_window: tuple[float, float] = (0.1, 0.6),
                max_task_failures: int = 2) -> FaultModel:
    """Independent per-attempt faults at constant rates (the memoryless
    baseline, and the only mode with exact cross-engine draw parity —
    no clock-dependent modulation)."""
    return FaultModel(seed=seed, p_fail=p_fail, p_slow=p_slow,
                      slow_factor=slow_factor, fail_window=fail_window,
                      slow_window=slow_window,
                      max_task_failures=max_task_failures)


def mmpp_faults(*, seed: int, t_end: float, mean_calm: float,
                mean_storm: float, storm_mult: float = 8.0,
                p_fail: float = 0.0, p_slow: float = 0.0,
                slow_factor: float = 4.0,
                fail_window: tuple[float, float] = (0.2, 0.9),
                slow_window: tuple[float, float] = (0.1, 0.6),
                max_task_failures: int = 2) -> FaultModel:
    """MMPP-correlated fault bursts: one hidden calm/storm chain (the
    same construction as ``mmpp_preemption``, seeded from
    ``f"fault-mmpp-state:{seed}"``) multiplies both fault probabilities
    by ``storm_mult`` during storms, so faults cluster in time — the
    correlated-degradation signature.  Probabilities are evaluated at
    each execution's *start* time on the engine's clock."""
    state_rng = random.Random(f"fault-mmpp-state:{seed}")
    timeline = tuple(mmpp_state_timeline(state_rng, t_end=t_end,
                                         mean_calm=mean_calm,
                                         mean_storm=mean_storm))
    return FaultModel(seed=seed, p_fail=p_fail, p_slow=p_slow,
                      slow_factor=slow_factor, fail_window=fail_window,
                      slow_window=slow_window,
                      max_task_failures=max_task_failures,
                      timeline=timeline, storm_mult=storm_mult)
