"""Optional ``jax.jit`` backend for the placement scoring kernel.

Every PTT search accepts a ``score_fn`` hook that computes the
queue-aware score vector ``ptt + queue_penalty * load`` over the
candidate places (see ``PTT._best_from_indices``); the argmin/tie-break
tail stays host-side so the RNG draw sequence is backend-independent.
This module provides that hook as a jitted jax kernel, selected with
``make_scheduler(..., placement_backend="jax")``.

Backend caveats (DESIGN.md §"Array-native event core"):

* Goldens are pinned on the numpy backend.  With ``queue_penalty == 0``
  the score is the identity over the PTT column, so this backend is
  bit-identical to numpy (pinned by ``tests/test_schedulers.py``).
  With a penalty the kernel computes in float32 unless the process
  enables ``jax_enable_x64`` (never set here — it is process-global and
  would silently retype every other jax user), and XLA is free to fuse
  the multiply-add; scores can therefore differ from numpy's float64
  in the last ulp and break ties differently.  Statistical results
  agree; bitwise goldens only hold for ``placement_backend="numpy"``.
* On a CPU-only host the numpy path is faster for the tiny (tens of
  places) score vectors of paper topologies — the jax backend exists
  for API parity with accelerator-resident sweeps where the PTT bank
  lives on device and the score never leaves it.

jax is imported lazily so the default numpy backend never pays for (or
requires) the dependency.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

_kernel = None      # jitted (vals, load, penalty) -> vals + penalty * load


def have_jax() -> bool:
    """True when jax is importable (the backend can be constructed)."""
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


def _build():
    global _kernel
    if _kernel is None:
        import jax

        @jax.jit
        def _score(vals, load, penalty):
            return vals + penalty * load

        _kernel = _score
    return _kernel


def make_score_fn() -> Callable[[np.ndarray, Optional[np.ndarray], float],
                                np.ndarray]:
    """Build the jitted score hook.

    Raises ``ImportError`` when jax is unavailable — callers
    (``make_scheduler``) surface that as a configuration error rather
    than silently falling back, so a sweep never mixes backends.
    """
    if not have_jax():
        raise ImportError(
            "placement_backend='jax' requires jax; install it or use "
            "the default placement_backend='numpy'")
    kernel = _build()

    def score_fn(vals: np.ndarray, load: Optional[np.ndarray],
                 penalty: float) -> np.ndarray:
        if load is None:
            # no queue penalty -> the score IS the PTT column; returning
            # it unchanged is exact (and keeps this backend bit-identical
            # to numpy whenever queue-aware placement is off)
            return vals
        return np.asarray(kernel(vals, load, penalty))

    return score_fn
