"""Interference scenarios (paper §5): co-running applications and DVFS.

Two mechanisms, matching how the paper injects dynamic asymmetry:

* ``SpeedProfile`` — per-core piecewise-constant speed multipliers with
  explicit breakpoints.  DVFS square waves (paper §5.2: Denver cluster
  alternating 2035 MHz / 345 MHz with a 5s+5s period) are built this way.

* ``BackgroundApp`` — a co-running application modeled as an endless chain
  of tasks pinned to specific cores, *outside* the scheduler's control.
  It time-shares its cores with foreground tasks (OS CFS ~ 50/50) and, for
  streaming kernels, pressures the partition's shared memory bandwidth.
  This mirrors §5.1's single-chain matmul / copy co-runners on core 0 and
  §5.4's 5-core interferer on one socket.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Sequence

from .task import TaskType


class SpeedProfile:
    """speed(core, t) -> multiplier; piecewise constant in t."""

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        # per core: sorted list of (t_start, speed); implicit (0.0, 1.0) head
        self._segs: list[list[tuple[float, float]]] = [[(0.0, 1.0)] for _ in range(n_cores)]

    def set_constant(self, cores: Sequence[int], speed: float) -> "SpeedProfile":
        for c in cores:
            self._segs[c] = [(0.0, speed)]
        return self

    def add_square_wave(self, cores: Sequence[int], *, period: float,
                        lo: float, hi: float = 1.0, t_end: float = 1e6,
                        hi_first: bool = True) -> "SpeedProfile":
        """DVFS-style alternation: hi for period/2, lo for period/2, ..."""
        for c in cores:
            segs = []
            t, phase_hi = 0.0, hi_first
            while t < t_end:
                segs.append((t, hi if phase_hi else lo))
                t += period / 2
                phase_hi = not phase_hi
            self._segs[c] = segs
        return self

    def add_window(self, cores: Sequence[int], t0: float, t1: float,
                   speed: float) -> "SpeedProfile":
        """Override speed on [t0, t1) (e.g. an interference episode that
        starts a few iterations in, paper §5.4)."""
        for c in cores:
            old = self._segs[c]
            new: list[tuple[float, float]] = []
            for i, (ts, sp) in enumerate(old):
                te = old[i + 1][0] if i + 1 < len(old) else float("inf")
                # segment before window
                if ts < t0:
                    new.append((ts, sp))
                # overlap with window
                if te > t0 and ts < t1:
                    new.append((max(ts, t0), speed))
                # segment tail after window
                if te > t1 and ts < te and te != float("inf") or ts >= t1:
                    if ts >= t1:
                        new.append((ts, sp))
                    elif te > t1:
                        new.append((t1, sp))
            # normalize: sort, dedupe by time keeping last
            new.sort()
            dedup: list[tuple[float, float]] = []
            for ts, sp in new:
                if dedup and dedup[-1][0] == ts:
                    dedup[-1] = (ts, sp)
                else:
                    dedup.append((ts, sp))
            self._segs[c] = dedup
        return self

    def speed(self, core: int, t: float) -> float:
        segs = self._segs[core]
        i = bisect.bisect_right(segs, (t, float("inf"))) - 1
        return segs[max(i, 0)][1]

    def breakpoints(self, horizon: float) -> list[float]:
        """All speed-change instants in (0, horizon] — DES event times."""
        pts = {ts for segs in self._segs for ts, _ in segs if 0.0 < ts <= horizon}
        return sorted(pts)


@dataclasses.dataclass(frozen=True)
class BackgroundApp:
    """An endless chain of ``task_type`` tasks pinned to ``cores``.

    ``t_start``/``t_end`` bound the episode.  Each pinned core runs one
    background stream (the paper's co-runner is a single chain on core 0;
    the Haswell experiment uses 5 cores of one socket).

    A foreground task time-sharing a pinned core runs at
    ``speed/(1+n_bg) * (1-thrash)``: the OS gives it a fair share and the
    co-runner additionally evicts its private-cache working set."""

    task_type: TaskType
    cores: tuple[int, ...]
    t_start: float = 0.0
    t_end: float = float("inf")
    thrash: float = 0.35

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_end


# -- canonical paper scenarios ----------------------------------------------

def corun_chain(task_type: TaskType, core: int = 0, *, t_start: float = 0.0,
                t_end: float = float("inf")) -> BackgroundApp:
    """Paper §5.1: a single task chain (matmul or copy kernels) on core 0
    that persists for the whole execution."""
    return BackgroundApp(task_type, (core,), t_start, t_end)


def corun_socket(task_type: TaskType, cores: Sequence[int], *,
                 t_start: float = 0.0, t_end: float = float("inf")) -> BackgroundApp:
    """Paper §5.4: interfering matmul kernels on 5 cores of one socket."""
    return BackgroundApp(task_type, tuple(cores), t_start, t_end)


def dvfs_denver(n_cores: int = 6, *, period: float = 10.0,
                hi_mhz: float = 2035.0, lo_mhz: float = 345.0) -> SpeedProfile:
    """Paper §5.2: Denver cluster (cores 0-1 on TX2) alternates between the
    highest and lowest frequency, 5 s each."""
    prof = SpeedProfile(n_cores)
    prof.add_square_wave((0, 1), period=period, lo=lo_mhz / hi_mhz)
    return prof
