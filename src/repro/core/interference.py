"""Interference scenarios (paper §5): co-running applications and DVFS.

Dynamic asymmetry is injected through two mechanisms, matching the paper:

* **Speed profiles** — per-core piecewise-constant speed multipliers.  The
  abstract interface (:class:`SpeedProfileBase`) is two queries: ``speed
  (core, t)`` and ``next_breakpoint(t)`` — the *lazy pull* contract the
  discrete-event engine schedules from (one outstanding breakpoint event
  at a time; nothing is ever enumerated up front).  Implementations:

  - :class:`SpeedProfile` — explicit sorted segment lists per core.  The
    general-purpose container: windows (§5.4 episodes), constants, and
    materialized square waves compose freely on it.
  - :class:`PeriodicProfile` — a repeating pattern of (duration, speed)
    phases evaluated *in closed form*: ``speed``/``next_breakpoint`` are
    O(pattern) arithmetic, no segments are ever materialized, so a DVFS
    square wave spanning a 1e6 s horizon costs O(1) memory instead of the
    ~200k segments per core the materialized form needs.  When the phase
    boundaries are exact in floating point (e.g. the Denver 5 s + 5 s
    wave), its breakpoints and speeds are bit-identical to the
    materialized equivalent — ``dvfs_denver`` returns one.
  - :class:`TraceProfile` — replayed per-core speed traces (recorded or
    synthesized; :func:`random_walk_trace` builds a seeded synthetic one).

* ``BackgroundApp`` — a co-running application modeled as an endless chain
  of tasks pinned to specific cores, *outside* the scheduler's control.
  It time-shares its cores with foreground tasks (OS CFS ~ 50/50) and, for
  streaming kernels, pressures the partition's shared memory bandwidth.
  This mirrors §5.1's single-chain matmul / copy co-runners on core 0 and
  §5.4's 5-core interferer on one socket.  :func:`burst_episodes` builds
  bursty on/off co-runner episodes from a seeded arrival process.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Mapping, Optional, Sequence

from .task import TaskType


class SpeedProfileBase:
    """Abstract per-core speed multiplier, piecewise constant in t.

    The simulator consumes profiles through exactly two queries:

    * ``speed(core, t)`` — the multiplier in force at time ``t``;
    * ``next_breakpoint(t)`` — the earliest instant strictly after ``t``
      at which *any* core's speed changes, or ``None`` if there is none.

    ``next_breakpoint`` is the lazy pull model: the engine keeps a single
    outstanding speed event and asks for the next one only when it fires,
    so profiles never need to enumerate their breakpoints eagerly.
    """

    n_cores: int

    def speed(self, core: int, t: float) -> float:
        raise NotImplementedError

    def speeds_at(self, t: float) -> list[float]:
        """Every core's multiplier at ``t`` in one call.  The DES pulls
        this on each speed breakpoint (the whole vector is re-derived at
        once at a cohort boundary), so profiles can specialize the bulk
        query; the default — and the contract any override must keep —
        is element-wise identical to looping :meth:`speed`."""
        return [self.speed(c, t) for c in range(self.n_cores)]

    def next_breakpoint(self, t: float) -> Optional[float]:
        raise NotImplementedError

    def breakpoints(self, horizon: float) -> list[float]:
        """All speed-change instants in (0, horizon], eagerly (diagnostic /
        test helper — the engine itself only ever pulls).  ``horizon`` must
        be finite: an unbounded periodic profile has infinitely many."""
        if not math.isfinite(horizon):
            raise ValueError(f"breakpoints() needs a finite horizon, "
                             f"got {horizon!r}")
        out: list[float] = []
        t = 0.0
        while True:
            nb = self.next_breakpoint(t)
            if nb is None or nb > horizon:
                return out
            out.append(nb)
            t = nb


class SpeedProfile(SpeedProfileBase):
    """Explicit segment lists: speed(core, t) via bisect over sorted
    (t_start, speed) pairs with an implicit (0.0, 1.0) head."""

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        # per core: sorted list of (t_start, speed); implicit (0.0, 1.0) head
        self._segs: list[list[tuple[float, float]]] = [[(0.0, 1.0)] for _ in range(n_cores)]
        self._bps: Optional[list[float]] = None   # merged cache, built lazily

    def set_constant(self, cores: Sequence[int], speed: float) -> "SpeedProfile":
        for c in cores:
            self._segs[c] = [(0.0, speed)]
        self._bps = None
        return self

    def add_square_wave(self, cores: Sequence[int], *, period: float,
                        lo: float, hi: float = 1.0, t_end: float = 1e6,
                        hi_first: bool = True) -> "SpeedProfile":
        """DVFS-style alternation: hi for period/2, lo for period/2, ...
        materialized as explicit segments (the last phase started before
        ``t_end`` persists).  Prefer :meth:`PeriodicProfile.square_wave`
        for long horizons — same semantics, closed form."""
        for c in cores:
            segs = []
            t, phase_hi = 0.0, hi_first
            while t < t_end:
                segs.append((t, hi if phase_hi else lo))
                t += period / 2
                phase_hi = not phase_hi
            self._segs[c] = segs
        self._bps = None
        return self

    def add_window(self, cores: Sequence[int], t0: float, t1: float,
                   speed: float) -> "SpeedProfile":
        """Override speed on [t0, t1) (e.g. an interference episode that
        starts a few iterations in, paper §5.4).  At ``t1`` the profile
        resumes whatever speed was previously in force there — including
        over the final (infinite) segment."""
        if not 0.0 <= t0 < t1:
            raise ValueError(f"bad window [{t0}, {t1})")
        for c in cores:
            old = self._segs[c]
            new = [(ts, sp) for ts, sp in old if ts < t0]
            new.append((t0, speed))
            if t1 != float("inf"):
                i = bisect.bisect_right(old, (t1, float("inf"))) - 1
                new.append((t1, old[max(i, 0)][1]))   # pre-window speed resumes
                new.extend((ts, sp) for ts, sp in old if ts > t1)
            self._segs[c] = new
        self._bps = None
        return self

    def speed(self, core: int, t: float) -> float:
        segs = self._segs[core]
        i = bisect.bisect_right(segs, (t, float("inf"))) - 1
        return segs[max(i, 0)][1]

    def speeds_at(self, t: float) -> list[float]:
        # constant cores (the untouched majority in sparse profiles) skip
        # the bisect; multi-segment cores compute the same double speed()
        # would, keeping the base-class element-wise contract
        key = (t, float("inf"))
        return [segs[0][1] if len(segs) == 1
                else segs[max(bisect.bisect_right(segs, key) - 1, 0)][1]
                for segs in self._segs]

    def _merged_bps(self) -> list[float]:
        if self._bps is None:
            self._bps = sorted({ts for segs in self._segs
                                for ts, _ in segs if ts > 0.0})
        return self._bps

    def next_breakpoint(self, t: float) -> Optional[float]:
        bps = self._merged_bps()
        i = bisect.bisect_right(bps, t)
        return bps[i] if i < len(bps) else None

    def breakpoints(self, horizon: float) -> list[float]:
        bps = self._merged_bps()
        return bps[:bisect.bisect_right(bps, horizon)]


@dataclasses.dataclass(frozen=True)
class _Pattern:
    """One repeating per-core pattern: phase j covers
    [q*period + offsets[j], q*period + offsets[j+1]) at speeds[j].
    ``last_start`` is the start of the final phase generated before
    ``t_end`` (that phase persists forever, mirroring the materialized
    square wave); None means the pattern repeats unbounded."""

    offsets: tuple[float, ...]
    speeds: tuple[float, ...]
    period: float
    t_end: float
    last_start: Optional[float]


class PeriodicProfile(SpeedProfileBase):
    """Closed-form repeating speed pattern — no segment materialization.

    Each core carries an optional :class:`_Pattern`; ``speed`` and
    ``next_breakpoint`` are O(pattern length) arithmetic (floor-divide into
    the current period + bisect over the within-period phase offsets), so
    construction and memory are independent of the horizon.  Breakpoints
    are generated as ``q*period + offset``: when those products are exact
    in floating point (dyadic phase lengths such as the Denver 5 s + 5 s
    wave) the breakpoint/speed sequence is bit-identical to the segment-
    materialized :meth:`SpeedProfile.add_square_wave` equivalent.
    """

    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self._pat: list[Optional[_Pattern]] = [None] * n_cores
        self._distinct: list[_Pattern] = []   # deduped; kept by set_pattern

    def set_pattern(self, cores: Sequence[int],
                    phases: Sequence[tuple[float, float]], *,
                    t_end: float = 1e6) -> "PeriodicProfile":
        """Repeat ``phases`` — (duration, speed) pairs — from t=0.  New
        phases start only strictly before ``t_end``; the last one started
        persists forever (the semantics of the materialized square wave)."""
        if not phases:
            raise ValueError("empty pattern")
        offsets, speeds, acc = [], [], 0.0
        for dur, sp in phases:
            if dur <= 0.0:
                raise ValueError(f"non-positive phase duration {dur}")
            offsets.append(acc)
            speeds.append(sp)
            acc += dur
        pat = _Pattern(tuple(offsets), tuple(speeds), acc, t_end,
                       self._last_start(tuple(offsets), acc, t_end))
        for c in cores:
            self._pat[c] = pat
        # rebuild the deduped pattern list here (mutations are rare) so
        # next_breakpoint never rescans all cores on the hot path;
        # _Pattern is a frozen dataclass, so value equality collapses
        # per-partition copies of the same wave into one scan entry
        seen: list[_Pattern] = []
        for p in self._pat:
            if p is not None and p not in seen:
                seen.append(p)
        self._distinct = seen
        return self

    @classmethod
    def square_wave(cls, n_cores: int, cores: Sequence[int], *,
                    period: float, lo: float, hi: float = 1.0,
                    t_end: float = 1e6,
                    hi_first: bool = True) -> "PeriodicProfile":
        """Closed-form equivalent of :meth:`SpeedProfile.add_square_wave`."""
        half = period / 2
        first, second = (hi, lo) if hi_first else (lo, hi)
        return cls(n_cores).set_pattern(
            cores, ((half, first), (half, second)), t_end=t_end)

    @staticmethod
    def _last_start(offsets: tuple[float, ...], period: float,
                    t_end: float) -> Optional[float]:
        """Largest phase start strictly below t_end (None = unbounded)."""
        if t_end == float("inf"):
            return None
        q = math.floor(t_end / period)
        for qq in (q, q - 1):
            if qq < 0:
                continue
            base = qq * period
            for off in reversed(offsets):
                p = base + off
                if p < t_end:
                    return p
        return 0.0

    def _phase_at(self, pat: _Pattern, t: float) -> float:
        """Speed of the phase whose start is the largest generated
        breakpoint <= t.  Phase starts are enumerated as the exact float
        values ``qq*period + off`` — the same expressions
        ``next_breakpoint`` emits — so at a pulled breakpoint instant this
        always returns the *post*-flip speed.  (Reconstructing the
        within-period remainder arithmetically instead can round just
        below the offset at non-dyadic periods, silently losing flips.)"""
        q = math.floor(t / pat.period)
        if q * pat.period > t:            # fp guard: floor landed one high
            q -= 1
        for qq in (q + 1, q, q - 1):      # boundary values may round across
            if qq < 0:
                continue
            base = qq * pat.period
            for j in range(len(pat.offsets) - 1, -1, -1):
                if base + pat.offsets[j] <= t:
                    return pat.speeds[j]
        return pat.speeds[0]

    def speed(self, core: int, t: float) -> float:
        pat = self._pat[core]
        if pat is None:
            return 1.0
        if pat.last_start is not None and t > pat.last_start:
            t = pat.last_start            # final generated phase persists
        return self._phase_at(pat, t)

    def next_breakpoint(self, t: float) -> Optional[float]:
        nxt = None
        for pat in self._distinct:
            q = max(math.floor(t / pat.period), 0)
            if q * pat.period > t:
                q -= 1
            p = None
            for qq in (q, q + 1, q + 2):
                base = qq * pat.period
                for off in pat.offsets:
                    cand = base + off
                    if cand > t:
                        p = cand
                        break
                if p is not None:
                    break
            if p is None or p >= pat.t_end:
                continue
            if nxt is None or p < nxt:
                nxt = p
        return nxt


class TraceProfile(SpeedProfile):
    """Per-core speed traces replayed verbatim.

    ``traces`` maps core -> sequence of (t, speed) points with strictly
    increasing times; the core runs at the last point's speed from its
    time onward (and at 1.0 before the first point if it starts after 0).
    Cores without a trace run at 1.0 throughout.
    """

    def __init__(self, n_cores: int,
                 traces: Mapping[int, Sequence[tuple[float, float]]]):
        super().__init__(n_cores)
        for core, pts in traces.items():
            if not 0 <= core < n_cores:
                raise ValueError(f"trace core {core} outside 0..{n_cores - 1}")
            segs: list[tuple[float, float]] = []
            prev = -1.0
            for t, sp in pts:
                if t < 0.0 or t <= prev:
                    raise ValueError(
                        f"trace for core {core}: times must be "
                        f"non-negative and strictly increasing")
                if sp <= 0.0:
                    raise ValueError(f"trace for core {core}: speed {sp} <= 0")
                segs.append((float(t), float(sp)))
                prev = t
            if not segs:
                continue
            if segs[0][0] > 0.0:
                segs.insert(0, (0.0, 1.0))
            self._segs[core] = segs
        self._bps = None


def random_walk_trace(n_cores: int, cores: Sequence[int] = (), *,
                      seed: int, dt: float, t_end: float, lo: float = 0.2,
                      hi: float = 1.0, step: float = 0.15) -> TraceProfile:
    """Synthetic trace: each core's speed does a seeded bounded random walk
    in [lo, hi], one step every ``dt`` seconds until ``t_end``.  Stands in
    for recorded co-tenancy traces in the scenario sweeps; each core gets
    an independent stream derived from (seed, core) so the profile is
    reproducible point-for-point."""
    if not 0.0 < lo <= hi:
        raise ValueError(f"bad speed range [{lo}, {hi}]")
    if dt <= 0.0 or not math.isfinite(t_end):
        raise ValueError("random_walk_trace needs dt > 0 and a finite t_end")
    cores = tuple(cores) if cores else tuple(range(n_cores))
    traces = {}
    for c in cores:
        rng = random.Random(f"trace-walk:{seed}:{c}")
        sp = lo + (hi - lo) * rng.random()
        pts, k, t = [], 0, 0.0
        while t < t_end:
            pts.append((t, sp))
            sp = min(hi, max(lo, sp + rng.uniform(-step, step)))
            k += 1
            t = k * dt
        traces[c] = pts
    return TraceProfile(n_cores, traces)


@dataclasses.dataclass(frozen=True)
class BackgroundApp:
    """An endless chain of ``task_type`` tasks pinned to ``cores``.

    ``t_start``/``t_end`` bound the episode.  Each pinned core runs one
    background stream (the paper's co-runner is a single chain on core 0;
    the Haswell experiment uses 5 cores of one socket).

    A foreground task time-sharing a pinned core runs at
    ``speed/(1+n_bg) * (1-thrash)``: the OS gives it a fair share and the
    co-runner additionally evicts its private-cache working set."""

    task_type: TaskType
    cores: tuple[int, ...]
    t_start: float = 0.0
    t_end: float = float("inf")
    thrash: float = 0.35

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_end


# -- canonical paper scenarios ----------------------------------------------

def corun_chain(task_type: TaskType, core: int = 0, *, t_start: float = 0.0,
                t_end: float = float("inf")) -> BackgroundApp:
    """Paper §5.1: a single task chain (matmul or copy kernels) on core 0
    that persists for the whole execution."""
    return BackgroundApp(task_type, (core,), t_start, t_end)


def corun_socket(task_type: TaskType, cores: Sequence[int], *,
                 t_start: float = 0.0, t_end: float = float("inf")) -> BackgroundApp:
    """Paper §5.4: interfering matmul kernels on 5 cores of one socket."""
    return BackgroundApp(task_type, tuple(cores), t_start, t_end)


def renewal_on_off(rng: random.Random, *, t_start: float, t_end: float,
                   mean_on: float, mean_off: float) -> list[tuple[float, float]]:
    """Alternating seeded exponential off/on intervals: the two-state
    renewal process behind :func:`burst_episodes` and the independent
    pod-slice preemption episodes (``repro.core.preemption``).  Returns
    non-overlapping ``(t0, t1)`` busy windows in [t_start, t_end); the
    draw sequence is one ``expovariate`` per gap then one per episode, so
    the output is a pure function of the RNG state and the parameters."""
    if not math.isfinite(t_end):
        raise ValueError("renewal_on_off needs a finite t_end")
    if mean_on <= 0.0 or mean_off <= 0.0:
        raise ValueError("mean_on and mean_off must be positive")
    episodes: list[tuple[float, float]] = []
    t = t_start
    while True:
        t += rng.expovariate(1.0 / mean_off)     # idle gap
        if t >= t_end:
            return episodes
        e1 = min(t + rng.expovariate(1.0 / mean_on), t_end)
        episodes.append((t, e1))
        t = e1


def mmpp_state_timeline(rng: random.Random, *, t_end: float,
                        mean_calm: float,
                        mean_storm: float) -> list[tuple[float, int]]:
    """Modulating chain of an MMPP: seeded exponential sojourns alternating
    between state 0 (calm) and state 1 (storm), starting calm at t=0.
    Returns the (t, state) change points; consumers treat each state as in
    force until the next point (the last persists to ``t_end``)."""
    if not math.isfinite(t_end):
        raise ValueError("mmpp_state_timeline needs a finite t_end")
    if mean_calm <= 0.0 or mean_storm <= 0.0:
        raise ValueError("mean_calm and mean_storm must be positive")
    out = [(0.0, 0)]
    t, s = 0.0, 0
    while True:
        t += rng.expovariate(1.0 / (mean_calm if s == 0 else mean_storm))
        if t >= t_end:
            return out
        s ^= 1
        out.append((t, s))


def mmpp_on_off(rng: random.Random, timeline: Sequence[tuple[float, int]], *,
                t_end: float, mean_on: float, mean_off_calm: float,
                mean_off_storm: float) -> list[tuple[float, float]]:
    """On/off episodes whose *idle-gap* rate is modulated by ``timeline``
    (an MMPP state sequence from :func:`mmpp_state_timeline`): gaps draw
    exponential lengths with mean ``mean_off_calm`` or ``mean_off_storm``
    depending on the state in force, re-drawn (memorylessly) at each state
    change; episode lengths draw from ``mean_on`` regardless of state.
    With a shared timeline across several callers the episodes *cluster in
    time* — the correlated-burst / maintenance-wave signature."""
    if mean_on <= 0.0 or mean_off_calm <= 0.0 or mean_off_storm <= 0.0:
        raise ValueError("episode/gap means must be positive")
    episodes: list[tuple[float, float]] = []
    t, i = 0.0, 0
    while t < t_end:
        # walk modulation segments, drawing a fresh gap in each (the
        # exponential is memoryless, so re-drawing at a boundary is the
        # standard piecewise construction)
        while True:
            while i + 1 < len(timeline) and timeline[i + 1][0] <= t:
                i += 1
            seg_end = timeline[i + 1][0] if i + 1 < len(timeline) else t_end
            mean_off = (mean_off_calm if timeline[i][1] == 0
                        else mean_off_storm)
            gap = rng.expovariate(1.0 / mean_off)
            if t + gap < seg_end:
                t += gap
                break
            t = seg_end
            if t >= t_end:
                return episodes
        e1 = min(t + rng.expovariate(1.0 / mean_on), t_end)
        episodes.append((t, e1))
        t = e1
    return episodes


def burst_episodes(task_type: TaskType, cores: Sequence[int], *, seed: int,
                   t_end: float, mean_on: float, mean_off: float,
                   t_start: float = 0.0,
                   thrash: float = 0.35) -> tuple[BackgroundApp, ...]:
    """Bursty on/off co-runner: a seeded two-state renewal process.

    Idle gaps and busy episodes draw i.i.d. exponential lengths
    (``mean_off`` / ``mean_on`` seconds) via :func:`renewal_on_off`,
    materialized as a tuple of non-overlapping :class:`BackgroundApp`
    episodes over [t_start, t_end).  The episode list depends only on
    ``seed`` and the parameters, never on process state, so multi-run
    cells stay reproducible.  ``t_end`` must be finite (it bounds the
    episode count).
    """
    rng = random.Random(f"burst:{seed}")
    windows = renewal_on_off(rng, t_start=t_start, t_end=t_end,
                             mean_on=mean_on, mean_off=mean_off)
    return tuple(BackgroundApp(task_type, tuple(cores), t0, t1, thrash)
                 for t0, t1 in windows)


def mmpp_burst_episodes(task_type: TaskType, core_groups: Sequence[Sequence[int]],
                        *, seed: int, t_end: float, mean_on: float,
                        mean_calm: float, mean_storm: float,
                        mean_off_calm: float, mean_off_storm: float,
                        thrash: float = 0.35) -> tuple[BackgroundApp, ...]:
    """MMPP-*correlated* co-runner bursts across several core groups.

    One hidden calm/storm modulating chain (seeded from ``seed`` alone,
    :func:`mmpp_state_timeline`) is shared by every group in
    ``core_groups``; each group then draws its own on/off episodes from a
    per-group stream through :func:`mmpp_on_off` — frequent bursts while
    the shared chain is stormy (``mean_off_storm`` idle gaps, typically
    short), rare ones while calm.  Because the chain is shared, bursts
    *cluster in time across groups* — several pods get hammered in the
    same storm, which is the regime a sharded control plane's rebalancer
    has to survive (every shard hot at once looks balanced; one hot shard
    must drain).  Per-group draws come from per-group streams, so adding
    or removing a group never shifts another group's episodes.
    """
    if not math.isfinite(t_end) or t_end <= 0.0:
        raise ValueError("mmpp_burst_episodes needs a finite positive t_end")
    state_rng = random.Random(f"burst-mmpp-state:{seed}")
    timeline = mmpp_state_timeline(state_rng, t_end=t_end,
                                   mean_calm=mean_calm,
                                   mean_storm=mean_storm)
    apps: list[BackgroundApp] = []
    for g, cores in enumerate(core_groups):
        rng = random.Random(f"burst-mmpp:{seed}:{g}")
        for t0, t1 in mmpp_on_off(rng, timeline, t_end=t_end,
                                  mean_on=mean_on,
                                  mean_off_calm=mean_off_calm,
                                  mean_off_storm=mean_off_storm):
            apps.append(BackgroundApp(task_type, tuple(cores), t0, t1,
                                      thrash))
    apps.sort(key=lambda a: (a.t_start, a.cores, a.t_end))
    return tuple(apps)


def dvfs_denver(n_cores: int = 6, *, period: float = 10.0,
                hi_mhz: float = 2035.0, lo_mhz: float = 345.0) -> PeriodicProfile:
    """Paper §5.2: Denver cluster (cores 0-1 on TX2) alternates between the
    highest and lowest frequency, 5 s each.  Closed form: the 5 s phase
    boundaries are exact in floating point, so this is bit-identical to
    the formerly materialized ~200k-segment profile at zero construction
    cost."""
    return PeriodicProfile.square_wave(n_cores, (0, 1), period=period,
                                       lo=lo_mhz / hi_mhz)


class LoadCoupledGovernor(SpeedProfileBase):
    """A governor whose detune depends on partition *load* (scenario
    realism: power/thermal governors clamp harder exactly when a partition
    is busy, so the scheduler's own placement feeds back into the
    asymmetry it must ride out).

    Wraps any base profile; a partition with a fraction ``f`` of its cores
    occupied runs at ``base_speed * (1 - coupling * f)``.  The simulator
    detects the ``load_coupled`` marker and feeds per-partition busy-core
    counts through :meth:`set_busy` before every rate refresh, so the
    effective speed stays piecewise-constant between events (occupancy
    only changes at task start/finish events).  The threaded runtime has
    no cost models to couple into — this is a DES scenario mechanism.
    """

    load_coupled = True

    def __init__(self, base: SpeedProfileBase, topology, *,
                 coupling: float = 0.3):
        if not 0.0 <= coupling < 1.0:
            raise ValueError(f"coupling {coupling!r} outside [0, 1)")
        self.base = base
        self.n_cores = base.n_cores
        self.coupling = coupling
        self._part_size = [p.size for p in topology.partitions]
        self._pidx_of = [0] * topology.n_cores
        for pidx, part in enumerate(topology.partitions):
            for c in part.cores:
                self._pidx_of[c] = pidx
        self._busy_frac = [0.0] * len(self._part_size)

    def set_busy(self, busy_counts: Sequence[int]) -> bool:
        """Update per-partition occupancy; returns True when any fraction
        moved (the caller then refreshes every cached core speed)."""
        changed = False
        for pidx, n in enumerate(busy_counts):
            f = n / self._part_size[pidx]
            if f != self._busy_frac[pidx]:
                self._busy_frac[pidx] = f
                changed = True
        return changed

    def speed(self, core: int, t: float) -> float:
        return (self.base.speed(core, t)
                * (1.0 - self.coupling * self._busy_frac[self._pidx_of[core]]))

    def next_breakpoint(self, t: float) -> Optional[float]:
        # load-driven changes are injected by the engine at its own events;
        # only the base profile contributes *time*-driven breakpoints
        return self.base.next_breakpoint(t)


def governor_profile(topology, *, period: float = 10.0, lo: float = 0.25,
                     hi: float = 1.0, t_end: float = 1e6,
                     period_spread: float = 0.0,
                     kinds: Optional[Sequence[str]] = None,
                     stagger: bool = True) -> PeriodicProfile:
    """Per-partition DVFS governors: every resource partition runs its own
    square-wave governor over all of its cores.

    Neighboring partitions are phase-staggered (``stagger``: partition i
    starts hi/lo for even/odd i) so the machine is never uniformly slow,
    and ``period_spread`` detunes the periods (partition i uses
    ``period * (1 + period_spread * i)``) so governor edges drift apart
    instead of beating in lockstep — the bursty, never-repeating
    asymmetry pattern adaptive schedulers are supposed to ride out.
    ``kinds`` restricts governed partitions (e.g. only "denver" clusters).
    """
    prof = PeriodicProfile(topology.n_cores)
    governed = 0
    for part in topology.partitions:
        if kinds is not None and part.kind not in kinds:
            continue
        # stagger/detune by position among *governed* partitions, so a
        # kinds filter can't put the governed set back in lockstep
        p = period * (1.0 + period_spread * governed)
        half = p / 2
        hi_first = not (stagger and governed % 2)
        first, second = (hi, lo) if hi_first else (lo, hi)
        prof.set_pattern(part.cores, ((half, first), (half, second)),
                         t_end=t_end)
        governed += 1
    if not governed:
        raise ValueError(f"no partition matches kinds={kinds!r}")
    return prof
