"""Batched multi-run DES engine: sweep-level parallelism across host cores.

The paper's headline results are *grids* of independent discrete-event
runs — Fig. 4 is (3 kernels x 5 parallelism x 7 schedulers) cells, Fig. 8
is (4 tiles x 4 PTT weights), the sensitivity and throughput sweeps add
seeds and topologies on top.  A single run was made ~6x faster by the
incremental-dispatch engine; this module makes the *sweep* scale with the
host by fanning cells across a ``multiprocessing`` pool.

Design rules
------------
* **Declarative, spawn-safe specs.**  A :class:`RunSpec` cell names
  registry entries (task types, DAG builders, topologies, background
  apps, speed profiles) plus plain-data kwargs, so the whole grid is
  picklable under the ``spawn`` start method: no live ``Topology`` /
  ``random.Random`` / lambda objects ever cross the process boundary.
  ``spawn`` is used unconditionally (never ``fork``) so results cannot
  depend on parent-process state and the engine behaves identically on
  every platform.
* **Deterministic per-cell seeding.**  Every cell carries its own seed
  and is rebuilt from scratch inside whichever process runs it, so
  results are bit-identical for any ``workers`` value — including the
  in-process ``workers=1`` path — and any chunk layout.  (Global counters
  such as ``Task.tid`` differ between processes, but nothing in the
  engine's behavior depends on absolute tid values.)
* **Chunked distribution.**  Cells are handed to the pool in contiguous
  chunks (``len/(workers*4)`` by default) so a 100+-cell grid amortizes
  IPC without serializing the tail onto one worker.
* **Compact results.**  Workers reduce each :class:`~.metrics.RunMetrics`
  to a plain dict (makespan/throughput + requested collectors), so a
  32k-task run ships a few hundred bytes back, not 32k ``TaskRecord``\\ s.
* **Cached pool.**  The spawn pool is kept alive between ``run_cells``
  calls (spawning costs ~0.65 s/worker of fixed interpreter+import
  overhead per call otherwise) and torn down by :func:`shutdown_pool`
  (registered atexit).  Reuse cannot change results: every cell is
  rebuilt from its spec inside whichever worker runs it.

The benchmark harnesses (``benchmarks/bench_interference.py`` etc.) build
their grids out of these specs; see ``benchmarks/README.md`` for the
worker/seed semantics contract.
"""
from __future__ import annotations

import atexit
import dataclasses
import os
import time
from multiprocessing import get_context
from typing import Iterable, Optional, Sequence

from .dag import (DAG, decode_pool_dag, heat_dag, kmeans_dag, mixed_dag,
                  synthetic_dag)
from .faults import FaultModel, RecoveryPolicy, mmpp_faults, task_faults
from .interference import (BackgroundApp, LoadCoupledGovernor,
                           PeriodicProfile, SpeedProfile, SpeedProfileBase,
                           burst_episodes, corun_chain, corun_socket,
                           dvfs_denver, governor_profile, mmpp_burst_episodes,
                           random_walk_trace)
from .metrics import RunMetrics
from .places import (Topology, haswell, haswell_cluster, tpu_pod_slices, tx2,
                     tx2_xl)
from .preemption import (PreemptionModel, mmpp_preemption,
                         pod_slice_preemption, sub_slice_preemption)
from .schedulers import make_scheduler
from .shards import ShardingSpec
from .simulator import simulate
from .task import (TaskType, copy_type, kmeans_map_type, kmeans_reduce_type,
                   matmul_type, mpi_exchange_type, stencil_type)

# --------------------------------------------------------------------------
# Registries: every name a RunSpec may reference.  Specs are (name, kwargs)
# pairs; builders are looked up here inside the worker process.
# --------------------------------------------------------------------------

TASK_TYPES = {
    "matmul": matmul_type,
    "copy": copy_type,
    "stencil": stencil_type,
    "mpi_exchange": mpi_exchange_type,
    "kmeans_map": kmeans_map_type,
    "kmeans_reduce": kmeans_reduce_type,
}

TOPOLOGIES = {
    "tx2": tx2,
    "tx2_xl": tx2_xl,
    "haswell": haswell,
    "haswell_cluster": haswell_cluster,
    "tpu_pod_slices": tpu_pod_slices,
}


def _synthetic(task_type: TaskType, **kw) -> DAG:
    return synthetic_dag(task_type, **kw)


def _heat(task_type=None, **kw) -> DAG:          # heat builds its own types
    return heat_dag(**kw)


def _kmeans(task_type=None, **kw) -> DAG:
    return kmeans_dag(**kw)


def _mixed(task_types=(), **kw) -> DAG:
    # task_types is a tuple of (name, kwargs) pairs, resolved here so the
    # spec stays plain data (the singular task_type resolution only covers
    # one type)
    return mixed_dag([_build_task_type(t) for t in task_types], **kw)


def _decode_pool(task_types=(), **kw) -> DAG:
    # (prefill, decode) as (name, kwargs) pairs, mixed-dag idiom
    pre, dec = (_build_task_type(t) for t in task_types)
    return decode_pool_dag(pre, dec, **kw)


DAG_BUILDERS = {
    "synthetic": _synthetic,
    "heat": _heat,
    "kmeans": _kmeans,
    "mixed": _mixed,
    "decode_pool": _decode_pool,
}


def _bg_chain(task_type: TaskType, **kw) -> BackgroundApp:
    return corun_chain(task_type, **kw)


def _bg_socket(task_type: TaskType, cores: Sequence[int], **kw) -> BackgroundApp:
    return corun_socket(task_type, tuple(cores), **kw)


def _bg_bursty(task_type: TaskType, cores: Sequence[int],
               **kw) -> tuple[BackgroundApp, ...]:
    return burst_episodes(task_type, tuple(cores), **kw)


def _bg_mmpp_bursty(task_type: TaskType, core_groups: Sequence[Sequence[int]],
                    **kw) -> tuple[BackgroundApp, ...]:
    # MMPP-correlated bursts: one calm/storm timeline shared by all core
    # groups, so co-runner pressure clusters in time across the fleet.
    return mmpp_burst_episodes(task_type,
                               [tuple(g) for g in core_groups], **kw)


# Builders may return one BackgroundApp or a tuple of them (bursty
# episodes); run_cell flattens.
BACKGROUND_BUILDERS = {
    "chain": _bg_chain,
    "socket": _bg_socket,
    "bursty": _bg_bursty,
    "mmpp_bursty": _bg_mmpp_bursty,
}


# Speed builders receive the cell's built Topology (per-partition governors
# need the partition layout, everything else just reads n_cores).
def _speed_dvfs_denver(topo: Topology, **kw) -> SpeedProfileBase:
    return dvfs_denver(n_cores=topo.n_cores, **kw)


def _speed_square_wave(topo: Topology, cores: Sequence[int],
                       **kw) -> SpeedProfile:
    return SpeedProfile(topo.n_cores).add_square_wave(tuple(cores), **kw)


def _speed_constant(topo: Topology, cores: Sequence[int],
                    speed: float) -> SpeedProfile:
    return SpeedProfile(topo.n_cores).set_constant(tuple(cores), speed)


def _speed_periodic_square(topo: Topology, cores: Sequence[int],
                           **kw) -> PeriodicProfile:
    return PeriodicProfile.square_wave(topo.n_cores, tuple(cores), **kw)


def _speed_governor(topo: Topology, **kw) -> PeriodicProfile:
    return governor_profile(topo, **kw)


def _speed_governor_load(topo: Topology, *, coupling: float = 0.3,
                         **kw) -> SpeedProfileBase:
    # per-partition governors whose detune additionally deepens with the
    # partition's occupancy (see interference.LoadCoupledGovernor)
    return LoadCoupledGovernor(governor_profile(topo, **kw), topo,
                               coupling=coupling)


def _speed_trace_walk(topo: Topology, cores: Sequence[int] = (),
                      **kw) -> SpeedProfileBase:
    return random_walk_trace(topo.n_cores, tuple(cores), **kw)


SPEED_BUILDERS = {
    "dvfs_denver": _speed_dvfs_denver,
    "square_wave": _speed_square_wave,
    "constant": _speed_constant,
    "periodic_square": _speed_periodic_square,
    "governor": _speed_governor,
    "governor_load": _speed_governor_load,
    "trace_walk": _speed_trace_walk,
}


# Preemption builders receive the cell's built Topology (episodes are
# partition-granular and seeded per partition name).
def _pre_pod_slices(topo: Topology, **kw) -> PreemptionModel:
    return pod_slice_preemption(topo, **kw)


def _pre_mmpp(topo: Topology, **kw) -> PreemptionModel:
    return mmpp_preemption(topo, **kw)


def _pre_sub_slices(topo: Topology, **kw) -> PreemptionModel:
    return sub_slice_preemption(topo, **kw)


PREEMPTION_BUILDERS = {
    "pod_slices": _pre_pod_slices,
    "mmpp": _pre_mmpp,
    "sub_slices": _pre_sub_slices,
}


# Fault-model builders are topology-free (faults are drawn per task, not per
# partition) — they take only their own seeded kwargs.
def _faults_independent(**kw) -> FaultModel:
    return task_faults(**kw)


def _faults_mmpp(**kw) -> FaultModel:
    return mmpp_faults(**kw)


FAULT_BUILDERS = {
    "independent": _faults_independent,
    "mmpp": _faults_mmpp,
}

# Result collectors beyond the always-present makespan/throughput summary.
COLLECTORS = {
    "placement_counts": lambda m: m.placement_counts(),
    "high_placement_counts": lambda m: m.placement_counts(priority=1),
    "priority_placement": lambda m: m.priority_placement(),
    "per_core_worktime_s": lambda m: m.per_core_worktime(),
    "per_type_mean_duration_s": lambda m: m.per_type_mean_duration(),
    "preemption": lambda m: {"events": m.preempt_events,
                             "tasks_preempted": m.tasks_preempted,
                             "work_lost_s": round(m.work_lost_s, 9)},
    "migration": lambda m: {"migrations": m.migrations,
                            "overflow_migrations": m.overflow_migrations,
                            "rebalance_rounds": m.rebalance_rounds,
                            "migrated_load_s": round(m.migrated_load_s, 9)},
    "faults": lambda m: m.fault_summary(),
    "task_sojourn": lambda m: m.task_sojourn_stats(),
    # continuous batching: the exact multiset of fused-dispatch
    # compositions, sorted — bitwise-comparable across worker counts
    "batching": lambda m: {"n_batches": len(m.batches),
                           "compositions": sorted(m.batches)},
}


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One cell of a sweep grid — everything needed to reproduce one
    seeded DES run, expressed as registry names + plain kwargs.

    ``dag`` / ``topology`` / ``speed`` / ``preemption`` / ``faults`` are
    ``(name, kwargs)`` pairs; ``background`` is a tuple of such pairs.
    ``recovery`` is a plain kwargs dict for
    :class:`~.faults.RecoveryPolicy` (ignored without ``faults``).
    ``sharding`` is a tuple of ``(field, value)`` pairs for
    :class:`~.shards.ShardingSpec` (kept as pairs, not a dict, so the
    frozen spec stays hashable); ``None`` runs the flat kernel.
    DAG and background kwargs may contain a ``task_type`` entry that is
    itself a ``(name, kwargs)`` pair resolved through :data:`TASK_TYPES`
    (the mixed DAG builder takes a ``task_types`` tuple of such pairs).
    ``collect`` names extra :data:`COLLECTORS` to evaluate in the worker;
    ``measure_wall`` times the ``simulate`` call (wall seconds +
    simulated-tasks/s).
    ``sim_kwargs`` is a tuple of ``(name, value)`` pairs forwarded to
    :func:`~.simulator.simulate` verbatim — e.g. ``(("event_mode",
    "scalar"),)`` re-runs a cell on the scalar reference event loop, or
    ``compact_min_stale``/``compact_heap_frac`` stress heap compaction;
    scheduler-side knobs like ``placement_backend`` go through
    ``sched_kwargs`` instead.  Defaults (empty) leave the cell on the
    cohort loop the goldens pin.
    """

    key: str
    dag: tuple
    scheduler: str
    topology: tuple = ("tx2", {})
    seed: int = 1
    sched_kwargs: dict = dataclasses.field(default_factory=dict)
    background: tuple = ()
    speed: Optional[tuple] = None
    preemption: Optional[tuple] = None
    faults: Optional[tuple] = None
    recovery: Optional[dict] = None
    sharding: Optional[tuple] = None
    horizon: float = 1e6
    collect: tuple = ()
    measure_wall: bool = False
    sim_kwargs: tuple = ()


def _lookup(registry: dict, spec, what: str):
    name, kwargs = spec
    try:
        builder = registry[name]
    except KeyError:
        raise KeyError(f"unknown {what} {name!r}; "
                       f"known: {', '.join(sorted(registry))}") from None
    return builder, dict(kwargs)


def _build_task_type(spec) -> TaskType:
    builder, kwargs = _lookup(TASK_TYPES, spec, "task type")
    return builder(**kwargs)


def _resolve_task_type(kwargs: dict) -> dict:
    if "task_type" in kwargs:
        kwargs["task_type"] = _build_task_type(kwargs["task_type"])
    return kwargs


def run_cell(spec: RunSpec) -> dict:
    """Execute one cell (in whatever process this is called from) and
    reduce it to a plain result dict."""
    topo_builder, topo_kwargs = _lookup(TOPOLOGIES, spec.topology, "topology")
    topo: Topology = topo_builder(**topo_kwargs)
    sched = make_scheduler(spec.scheduler, topo, seed=spec.seed,
                           **spec.sched_kwargs)
    dag_builder, dag_kwargs = _lookup(DAG_BUILDERS, spec.dag, "dag builder")
    dag = dag_builder(**_resolve_task_type(dag_kwargs))
    background = []
    for bg_spec in spec.background:
        bg_builder, bg_kwargs = _lookup(BACKGROUND_BUILDERS, bg_spec,
                                        "background app")
        built = bg_builder(**_resolve_task_type(bg_kwargs))
        if isinstance(built, BackgroundApp):
            background.append(built)
        else:                       # episode tuple (e.g. bursty)
            background.extend(built)
    speed = None
    if spec.speed is not None:
        speed_builder, speed_kwargs = _lookup(SPEED_BUILDERS, spec.speed,
                                              "speed profile")
        speed = speed_builder(topo, **speed_kwargs)
    preemption = None
    if spec.preemption is not None:
        pre_builder, pre_kwargs = _lookup(PREEMPTION_BUILDERS,
                                          spec.preemption, "preemption model")
        preemption = pre_builder(topo, **pre_kwargs)
    faults = None
    if spec.faults is not None:
        fault_builder, fault_kwargs = _lookup(FAULT_BUILDERS, spec.faults,
                                              "fault model")
        faults = fault_builder(**fault_kwargs)
    recovery = (RecoveryPolicy(**spec.recovery)
                if spec.recovery is not None else None)
    sharding = (ShardingSpec(**dict(spec.sharding))
                if spec.sharding is not None else None)

    t0 = time.perf_counter()
    m: RunMetrics = simulate(dag, sched, background=background, speed=speed,
                             preemption=preemption, faults=faults,
                             recovery=recovery, sharding=sharding,
                             horizon=spec.horizon, **dict(spec.sim_kwargs))
    wall = time.perf_counter() - t0

    out = {
        "n_tasks": m.n_tasks,
        "makespan_s": m.makespan,
        "throughput_tps": m.throughput,
    }
    if spec.measure_wall:
        out["wall_s"] = round(wall, 4)
        out["sim_tasks_per_s"] = round(m.n_tasks / wall, 1) if wall > 0 else 0.0
    for name in spec.collect:
        try:
            collector = COLLECTORS[name]
        except KeyError:
            raise KeyError(f"unknown collector {name!r}; "
                           f"known: {', '.join(sorted(COLLECTORS))}") from None
        out[name] = collector(m)
    return out


def default_workers() -> int:
    """Worker count used when the caller passes ``workers=None``."""
    return os.cpu_count() or 1


# -- cached spawn pool -------------------------------------------------------
# Spawning a pool costs ~0.65 s per worker (fresh interpreter + imports), a
# fixed overhead every ``run_cells`` call used to pay.  The pool is cached
# across calls (suites reuse it); ``shutdown_pool`` releases it explicitly
# and runs at interpreter exit.  Cells are rebuilt from their specs inside
# whichever worker runs them, so reuse cannot change any result.
_pool = None
_pool_workers = 0


def _get_pool(workers: int):
    global _pool, _pool_workers
    if _pool is not None and _pool_workers != workers:
        shutdown_pool()
    if _pool is None:
        # spawn, never fork: workers import a fresh interpreter so cell
        # results cannot depend on inherited parent state (and the same
        # start method runs everywhere).
        _pool = get_context("spawn").Pool(processes=workers)
        _pool_workers = workers
    return _pool


def shutdown_pool() -> None:
    """Release the cached worker pool (idempotent).  Registered atexit, so
    callers only need it to free workers early (e.g. before a fork-hostile
    section or between test suites)."""
    global _pool, _pool_workers
    if _pool is not None:
        _pool.terminate()       # what Pool.__exit__ does; workers are idle
        _pool.join()
        _pool = None
        _pool_workers = 0


atexit.register(shutdown_pool)


def run_cells(specs: Iterable[RunSpec], *, workers: Optional[int] = None,
              chunksize: Optional[int] = None) -> dict:
    """Run a grid of cells, fanned across ``workers`` processes.

    Returns ``{spec.key: result_dict}`` in the order the specs were
    given.  ``workers=None`` uses every host core; ``workers<=1`` (or a
    single-cell grid) runs in-process through the exact same
    :func:`run_cell` path, so results are bit-identical for every worker
    count and chunk layout (each cell is rebuilt from its spec with its
    own seed wherever it runs).  The worker pool is cached across calls
    (see :func:`shutdown_pool`).
    """
    specs = list(specs)
    keys = [s.key for s in specs]
    if len(set(keys)) != len(keys):
        dupes = sorted({k for k in keys if keys.count(k) > 1})
        raise ValueError(f"duplicate RunSpec keys: {', '.join(dupes)}")
    if not specs:
        return {}
    if workers is None:
        workers = default_workers()
    workers = max(1, min(int(workers), len(specs)))
    if workers == 1:
        results = [run_cell(s) for s in specs]
    else:
        if chunksize is None:
            chunksize = max(1, len(specs) // (workers * 4))
        pool = _get_pool(workers)
        try:
            results = pool.map(run_cell, specs, chunksize=chunksize)
        except BaseException:   # incl. KeyboardInterrupt: workers may still
            shutdown_pool()     # be chewing abandoned chunks — don't reuse
            raise
    return dict(zip(keys, results))
