"""Engine-agnostic scheduling queues (paper Fig. 3, steps 1-3).

One reusable structure for *both* execution engines — the discrete-event
simulator and the real threaded runtime — so queue semantics exist once:

* per-core split **Work Stealing Queue**: HIGH tasks in FIFO order (the
  oldest HIGH gates the DAG and is served first), LOW tasks as a LIFO
  deque for owner locality whose FIFO end feeds thieves.  Schedulers
  without priority dequeue that steal HIGH tasks (the RWS family) route
  everything through ``low``, i.e. one plain mixed-LIFO deque, which
  preserves their priority-oblivious ordering;
* per-core FIFO **Assembly Queue** holding placed work (engine-specific
  records — the DES enqueues rate-integration records, the threaded
  runtime barrier records; a molded task's record is inserted into *all*
  member AQs and starts when every member reaches it);
* **steal policy**: the victim with the most stealable tasks wins, maxima
  tie-break uniformly at random from the caller's (seeded) RNG stream,
  and the steal pops the oldest stealable task (LOW FIFO end first).

Every method is O(1) or O(cores) and draws randomness only through the
RNG handed in by the caller, so the DES's bit-exact golden schedules and
the threaded runtime's seeded steal stream both ride on the same code.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional

import numpy as np

from .task import Priority, Task


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    """Continuous-batching knobs, shared by the serving engine's
    :class:`~repro.serve.batching.DecodeBatcher` and the engines'
    queue-level coalescing dequeue.

    ``max_batch`` — most members per dispatch (1 disables batching: the
    degeneracy pin — every path must be bit-identical to no batching).
    ``delay_s`` — how long a partial batch may wait for more members
    before it flushes anyway (the batch-delay window).
    ``flush_slack_s`` — a member whose deadline slack falls to this
    flushes the pending batch immediately.
    ``member_cost`` — marginal cost of each member past the first as a
    fraction of the base step time (batched decode is memory-bound; see
    :meth:`~repro.core.task.TaskType.batched`).

    Frozen + plain fields so it pickles across ``multirun`` workers and
    can ride ``RunSpec.sim_kwargs`` verbatim."""

    max_batch: int = 8
    delay_s: float = 2e-3
    flush_slack_s: float = 0.0
    member_cost: float = 0.05

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.delay_s < 0.0 or self.flush_slack_s < 0.0:
            raise ValueError("delay_s / flush_slack_s must be >= 0")
        if not 0.0 <= self.member_cost <= 1.0:
            raise ValueError(
                f"member_cost must be in [0, 1], got {self.member_cost}")

    @property
    def enabled(self) -> bool:
        return self.max_batch > 1


class SplitWSQ:
    """Split work-stealing queue: a HIGH FIFO deque + a LOW LIFO deque
    (whose FIFO end feeds thieves)."""

    __slots__ = ("high", "low")

    def __init__(self):
        self.high: deque[Task] = deque()
        self.low: deque[Task] = deque()

    def __len__(self) -> int:
        return len(self.high) + len(self.low)


class WorkQueues:
    """Per-core split WSQs + assembly queues under one scheduling policy.

    ``priority_dequeue`` — serve the oldest HIGH before any LOW from the
    owner's queue; ``steal_high`` — HIGH tasks are stealable (RWS family).
    HIGH tasks are routed to the split HIGH deque unless the scheduler is
    fully priority-oblivious (no priority dequeue AND HIGH stealable),
    which keeps stealable counts and steal pops consistent with
    ``Scheduler.may_steal`` for *any* flag combination.
    """

    def __init__(self, n_cores: int, *, priority_dequeue: bool,
                 steal_high: bool, track_load: bool = False,
                 groups: Optional[list[int]] = None):
        self.n_cores = n_cores
        self.priority_dequeue = priority_dequeue
        self.steal_high = steal_high
        self.route_high = priority_dequeue or not steal_high
        self.wsq: list[SplitWSQ] = [SplitWSQ() for _ in range(n_cores)]
        self.aq: list[deque] = [deque() for _ in range(n_cores)]
        # Queued-work accounting for queue-aware placement: per-core
        # estimated seconds of ready work sitting in the WSQs, maintained
        # at push/pop/steal/drain from the estimate the kernel stamped on
        # the task (``task.load_est``).  Off by default — zero cost.
        self.track_load = track_load
        self.queued_s = np.zeros(n_cores) if track_load else None
        # HIGH-only backlog (criticality currency): per-core estimated
        # seconds of *HIGH* ready work.  A shard drowning in HIGH backlog
        # delays the critical path even when its total load looks
        # balanced, so the global rebalancer's criticality-pressure
        # trigger reads this vector.  Maintained alongside ``queued_s``
        # (same push/pop/steal/drain sites); None when load tracking is
        # off — zero cost on the default paths.
        self.queued_high_s = np.zeros(n_cores) if track_load else None
        # Steal groups (sharded control plane): ``groups[core]`` is the
        # core's shard id; thieves only victimize their own group, so work
        # crosses shards exclusively through the global rebalancer.  None
        # = one flat group (the victim scan is untouched).
        self.groups = list(groups) if groups is not None else None

    # -- ready-task (WSQ) operations ----------------------------------------
    def push(self, task: Task, core: int) -> None:
        q = self.wsq[core]
        if self.route_high and task.priority == Priority.HIGH:
            q.high.append(task)
        else:
            q.low.append(task)
        if self.track_load:
            self.queued_s[core] += task.load_est
            if task.priority == Priority.HIGH:
                self.queued_high_s[core] += task.load_est

    def pop_local(self, core: int) -> Optional[Task]:
        """Owner pop: oldest HIGH first under priority dequeue; LOW pops
        LIFO for locality; leftover HIGHs (non-priority dequeue) FIFO."""
        q = self.wsq[core]
        if self.priority_dequeue and q.high:
            task = q.high.popleft()
        elif q.low:
            task = q.low.pop()
        elif q.high:
            task = q.high.popleft()
        else:
            return None
        if self.track_load:
            self.queued_s[core] -= task.load_est
            if task.priority == Priority.HIGH:
                self.queued_high_s[core] -= task.load_est
        return task

    def wsq_len(self, core: int) -> int:
        return len(self.wsq[core])

    def stealable(self, task: Task) -> bool:
        return self.steal_high or task.priority != Priority.HIGH

    def stealable_count(self, core: int) -> int:
        q = self.wsq[core]
        return len(q.low) + len(q.high) if self.steal_high else len(q.low)

    def pick_victim(self, thief: int, rng) -> int:
        """The WSQ with the most stealable tasks (paper step 3); maxima
        tie-break uniformly at random from ``rng``.  Returns -1 when no
        core has stealable work.  O(cores) length reads."""
        best_n = 0
        best: list[int] = []
        groups = self.groups
        group = groups[thief] if groups is not None else None
        wsq = self.wsq
        steal_high = self.steal_high
        for v in range(self.n_cores):
            if v == thief:
                continue
            if group is not None and groups[v] != group:
                continue
            q = wsq[v]
            n = len(q.low) + len(q.high) if steal_high else len(q.low)
            if n > best_n:
                best_n = n
                best = [v]
            elif n and n == best_n:
                best.append(v)
        if not best:
            return -1
        return best[0] if len(best) == 1 else best[rng.randrange(len(best))]

    def steal_pop(self, victim: int) -> Task:
        """Pop the oldest stealable task (LOW FIFO end first; HIGHs only
        ever surface here when ``steal_high`` routed them to ``low`` or
        priority dequeue left them exposed)."""
        q = self.wsq[victim]
        task = q.low.popleft() if q.low else q.high.popleft()
        if self.track_load:
            self.queued_s[victim] -= task.load_est
            if task.priority == Priority.HIGH:
                self.queued_high_s[victim] -= task.load_est
        return task

    def coalesce_batch(self, core: int, key: str, limit: int) -> list[Task]:
        """Coalescing LOW dequeue (continuous batching): remove up to
        ``limit`` queued LOW tasks whose ``batch_key`` equals ``key`` from
        ``core``'s queue, oldest first, and return them as batch members.
        Called right after an engine pops a dispatch leader with a batch
        key; the members skip their own place/dequeue rounds and ride the
        leader.  Only the LOW deque is scanned — batchable work is LOW by
        construction (HIGH prefills must never wait on batch fill)."""
        if limit <= 0:
            return []
        q = self.wsq[core].low
        if not q:
            return []
        taken: list[Task] = []
        kept: list[Task] = []
        for t in q:
            if len(taken) < limit and t.batch_key == key:
                taken.append(t)
            else:
                kept.append(t)
        if taken:
            q.clear()
            q.extend(kept)
            if self.track_load:
                self.queued_s[core] -= sum(t.load_est for t in taken)
        return taken

    def migrate_pop(self, core: int) -> Optional[Task]:
        """Pop one task for cross-shard migration, HIGH-first (a parked
        critical task hurts most): oldest HIGH, else the oldest LOW (the
        thief end — the owner's LIFO locality tail is left alone)."""
        q = self.wsq[core]
        if q.high:
            task = q.high.popleft()
        elif q.low:
            task = q.low.popleft()
        else:
            return None
        if self.track_load:
            self.queued_s[core] -= task.load_est
            if task.priority == Priority.HIGH:
                self.queued_high_s[core] -= task.load_est
        return task

    def drain_wsq(self, cores: Iterable[int]) -> list[Task]:
        """Empty the WSQs of ``cores`` (a revoked partition), returning
        tasks in steal order per core: oldest HIGH first, then the LOW
        deque oldest-first."""
        out: list[Task] = []
        for c in cores:
            q = self.wsq[c]
            out.extend(q.high)
            out.extend(q.low)
            q.high.clear()
            q.low.clear()
            if self.track_load:
                self.queued_s[c] = 0.0
                self.queued_high_s[c] = 0.0
        return out
