"""Run metrics: throughput, per-core work time, placement distributions —
the quantities behind the paper's Figures 4-10."""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict


@dataclasses.dataclass(frozen=True)
class TaskRecord:
    type_name: str
    priority: int
    leader: int
    width: int
    t_ready: float
    t_start: float
    t_end: float

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def place(self) -> str:
        return f"(C{self.leader},{self.width})"


@dataclasses.dataclass
class RunMetrics:
    n_cores: int
    records: list[TaskRecord] = dataclasses.field(default_factory=list)
    makespan: float = 0.0
    # preemption accounting (zero when no PreemptionModel is attached):
    # revoke episodes applied, task executions preempted, and work-seconds
    # of discarded progress (restart kills; checkpointed progress is kept)
    preempt_events: int = 0
    tasks_preempted: int = 0
    work_lost_s: float = 0.0

    def record(self, rec: TaskRecord) -> None:
        self.records.append(rec)

    def finish(self, t_end: float) -> None:
        self.makespan = t_end

    # -- aggregates -----------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return len(self.records)

    @property
    def throughput(self) -> float:
        """tasks / second (paper §5.1: total tasks / total execution time)."""
        return self.n_tasks / self.makespan if self.makespan > 0 else 0.0

    def per_core_worktime(self) -> list[float]:
        """Cumulative kernel work time per core (paper Fig. 6)."""
        out = [0.0] * self.n_cores
        for r in self.records:
            for c in range(r.leader, r.leader + r.width):
                out[c] += r.duration
        return out

    def priority_placement(self) -> dict[str, float]:
        """Fraction of HIGH tasks per execution place (paper Fig. 5)."""
        high = [r for r in self.records if r.priority == 1]
        if not high:
            return {}
        counts = Counter(r.place for r in high)
        return {p: c / len(high) for p, c in sorted(counts.items())}

    def placement_counts(self, priority: int | None = None) -> dict[str, int]:
        recs = self.records if priority is None else [
            r for r in self.records if r.priority == priority]
        return dict(Counter(r.place for r in recs))

    def per_type_mean_duration(self) -> dict[str, float]:
        sums: dict[str, list[float]] = defaultdict(list)
        for r in self.records:
            sums[r.type_name].append(r.duration)
        return {k: sum(v) / len(v) for k, v in sums.items()}

    def windowed_throughput(self, window: float) -> list[tuple[float, float]]:
        """(t, tasks/s) series — used for the DVFS / iteration-time plots."""
        if not self.records:
            return []
        buckets: dict[int, int] = defaultdict(int)
        for r in self.records:
            buckets[int(r.t_end / window)] += 1
        return [(i * window, n / window) for i, n in sorted(buckets.items())]

    def iteration_times(self, marker_type: str) -> list[float]:
        """Completion-time deltas of a per-iteration marker task type
        (e.g. the K-means reduce) — paper Fig. 9(a)."""
        ends = sorted(r.t_end for r in self.records if r.type_name == marker_type)
        return [b - a for a, b in zip(ends, ends[1:])]

    def summary(self) -> dict[str, float]:
        return {
            "tasks": self.n_tasks,
            "makespan_s": round(self.makespan, 6),
            "throughput_tps": round(self.throughput, 2),
        }
