"""Run metrics: throughput, per-core work time, placement distributions —
the quantities behind the paper's Figures 4-10."""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict


def percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolation percentile over pre-sorted values (stdlib-only;
    matches numpy's default 'linear' method)."""
    if not sorted_vals:
        raise ValueError("percentile of empty list")
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = (len(sorted_vals) - 1) * q / 100.0
    lo = int(pos)
    frac = pos - lo
    if frac == 0.0:
        return sorted_vals[lo]
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[lo + 1] * frac


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One serving request's lifecycle timestamps (all on the engine's
    clock): submission, first token out (prefill commit), last token out.
    Graceful-degradation flags: ``rejected`` = refused at admission with
    ``reject_cause`` attributing it — ``"deadline"`` (the deadline could
    not be met even best-case) or ``"backpressure"`` (bounded pending
    queue full, or the brownout ladder's reject rung); ``shed`` = admitted
    but its queued LOW decode work was dropped — ``shed_cause`` is
    ``"deadline"`` (deadline passed mid-chain) or ``"brownout"`` (the
    ladder's shed rung) — truncated output, request still finalized."""
    rid: int
    t_submit: float
    t_first_token: float
    t_done: float
    deadline_s: float = 0.0         # 0 = no deadline
    rejected: bool = False
    shed: bool = False
    reject_cause: str = ""
    shed_cause: str = ""

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_submit

    @property
    def deadline_miss(self) -> bool:
        """A deadlined request that was rejected, shed, or finished late."""
        return self.deadline_s > 0.0 and (
            self.rejected or self.shed or self.e2e > self.deadline_s)


# not frozen: a frozen dataclass __init__ pays object.__setattr__ per
# field, and one TaskRecord is built per committed task on the DES hot
# path; slots keep it compact and equality-by-value is unchanged
@dataclasses.dataclass(slots=True)
class TaskRecord:
    type_name: str
    priority: int
    leader: int
    width: int
    t_ready: float
    t_start: float
    t_end: float

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def place(self) -> str:
        return f"(C{self.leader},{self.width})"


@dataclasses.dataclass
class RunMetrics:
    n_cores: int
    records: list[TaskRecord] = dataclasses.field(default_factory=list)
    makespan: float = 0.0
    # preemption accounting (zero when no PreemptionModel is attached):
    # revoke episodes applied, task executions preempted, and work-seconds
    # of discarded progress (restart kills; checkpointed progress is kept)
    preempt_events: int = 0
    tasks_preempted: int = 0
    work_lost_s: float = 0.0
    # sharded-control-plane accounting (all zero on the flat kernel):
    # rebalancer migrations landed, wake-time overflow redirects,
    # rebalance rounds run, and the estimated seconds of work migrated
    migrations: int = 0
    overflow_migrations: int = 0
    rebalance_rounds: int = 0
    migrated_load_s: float = 0.0
    # online re-sharding rounds applied mid-run (zero without reshard
    # events; see ShardedControlPlane.reshard)
    reshard_rounds: int = 0
    # continuous-batching accounting (empty without a BatchingConfig):
    # one entry per multi-member dispatch — (dispatch type name, sorted
    # tuple of member base-type names, leader included).  The cross-engine
    # parity tests compare these as multisets.
    batches: list[tuple] = dataclasses.field(default_factory=list)
    # fault-injection / recovery accounting (all zero without a FaultModel
    # attached — see ``repro.core.faults``): injected fault counts, retry /
    # permanent-failure counts, straggler flags and speculative duplicates,
    # and the work-seconds burned by failures and by losing hedge copies
    faults_failstop: int = 0
    faults_failslow: int = 0
    retries: int = 0
    failed_tasks: int = 0           # retry budget exhausted (permanent)
    stragglers: int = 0
    hedges_launched: int = 0
    hedge_wins: int = 0             # duplicate committed before the original
    work_lost_faults_s: float = 0.0
    work_hedged_s: float = 0.0      # losing-copy work (the hedge premium)
    # error surface: worker-thread death, permanently failed tasks, drain
    # timeouts.  An empty list is the "run is trustworthy" signal — the
    # threaded engine used to silently return partial data on any of these
    errors: list[str] = dataclasses.field(default_factory=list)
    # supervisor/heartbeat recovery events ("failure@step: workers [..]")
    recovery_events: list[str] = dataclasses.field(default_factory=list)
    # serving-path accounting: one record per completed request (open-loop
    # or batch), feeding the TTFT / end-to-end latency percentiles
    request_records: list[RequestRecord] = dataclasses.field(
        default_factory=list)
    # brownout-ladder transitions (t, from_rung, to_rung) copied from the
    # serving engine's OverloadController at finalize; empty without one
    brownout_transitions: list[tuple] = dataclasses.field(
        default_factory=list)

    def record(self, rec: TaskRecord) -> None:
        self.records.append(rec)

    def record_request(self, rec: RequestRecord) -> None:
        self.request_records.append(rec)

    def finish(self, t_end: float) -> None:
        self.makespan = t_end

    # -- aggregates -----------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return len(self.records)

    @property
    def throughput(self) -> float:
        """tasks / second (paper §5.1: total tasks / total execution time)."""
        return self.n_tasks / self.makespan if self.makespan > 0 else 0.0

    def per_core_worktime(self) -> list[float]:
        """Cumulative kernel work time per core (paper Fig. 6)."""
        out = [0.0] * self.n_cores
        for r in self.records:
            for c in range(r.leader, r.leader + r.width):
                out[c] += r.duration
        return out

    def priority_placement(self) -> dict[str, float]:
        """Fraction of HIGH tasks per execution place (paper Fig. 5)."""
        high = [r for r in self.records if r.priority == 1]
        if not high:
            return {}
        counts = Counter(r.place for r in high)
        return {p: c / len(high) for p, c in sorted(counts.items())}

    def placement_counts(self, priority: int | None = None) -> dict[str, int]:
        recs = self.records if priority is None else [
            r for r in self.records if r.priority == priority]
        return dict(Counter(r.place for r in recs))

    def per_type_mean_duration(self) -> dict[str, float]:
        sums: dict[str, list[float]] = defaultdict(list)
        for r in self.records:
            sums[r.type_name].append(r.duration)
        return {k: sum(v) / len(v) for k, v in sums.items()}

    def windowed_throughput(self, window: float) -> list[tuple[float, float]]:
        """(t, tasks/s) series — used for the DVFS / iteration-time plots."""
        if not self.records:
            return []
        buckets: dict[int, int] = defaultdict(int)
        for r in self.records:
            buckets[int(r.t_end / window)] += 1
        return [(i * window, n / window) for i, n in sorted(buckets.items())]

    def iteration_times(self, marker_type: str) -> list[float]:
        """Completion-time deltas of a per-iteration marker task type
        (e.g. the K-means reduce) — paper Fig. 9(a)."""
        ends = sorted(r.t_end for r in self.records if r.type_name == marker_type)
        return [b - a for a, b in zip(ends, ends[1:])]

    def request_latency_stats(self) -> dict:
        """Per-request latency percentiles (milliseconds): time-to-first-
        token and end-to-end, p50/p95/p99 + mean, over completed (i.e.
        non-rejected) requests, plus graceful-degradation counters."""
        recs = self.request_records
        if not recs:
            return {}
        done = [r for r in recs if not r.rejected]
        out: dict = {
            "completed": len(done),
            "rejected": sum(1 for r in recs if r.rejected),
            "rejected_deadline": sum(1 for r in recs if r.rejected
                                     and r.reject_cause == "deadline"),
            "rejected_backpressure": sum(1 for r in recs if r.rejected
                                         and r.reject_cause == "backpressure"),
            "shed": sum(1 for r in recs if r.shed),
            "shed_deadline": sum(1 for r in recs if r.shed
                                 and r.shed_cause == "deadline"),
            "shed_brownout": sum(1 for r in recs if r.shed
                                 and r.shed_cause == "brownout"),
            "deadline_miss": sum(1 for r in recs if r.deadline_miss),
        }
        if self.brownout_transitions:
            trans = self.brownout_transitions
            out["brownout"] = {
                "transitions": len(trans),
                "max_rung": max(to for _, _, to in trans),
                "rung_enters": {
                    str(r): sum(1 for _, frm, to in trans
                                if frm < r <= to)
                    for r in (1, 2, 3)},
            }
        if not done:
            return out
        for key, vals in (("ttft_ms", sorted(r.ttft for r in done)),
                          ("e2e_ms", sorted(r.e2e for r in done))):
            out[key] = {
                "mean": sum(vals) / len(vals) * 1e3,
                "p50": percentile(vals, 50) * 1e3,
                "p95": percentile(vals, 95) * 1e3,
                "p99": percentile(vals, 99) * 1e3,
            }
        return out

    def fault_summary(self) -> dict:
        """Compact fault/recovery accounting (the ``faults`` collector)."""
        return {
            "failstop": self.faults_failstop,
            "failslow": self.faults_failslow,
            "retries": self.retries,
            "failed_tasks": self.failed_tasks,
            "stragglers": self.stragglers,
            "hedges": self.hedges_launched,
            "hedge_wins": self.hedge_wins,
            "work_lost_faults_s": round(self.work_lost_faults_s, 9),
            "work_hedged_s": round(self.work_hedged_s, 9),
        }

    def task_sojourn_stats(self) -> dict:
        """Ready-to-commit sojourn percentiles (seconds) over committed
        tasks — the per-task tail the straggler-hedging benchmark reads."""
        if not self.records:
            return {}
        vals = sorted(r.t_end - r.t_ready for r in self.records)
        return {
            "mean_s": sum(vals) / len(vals),
            "p50_s": percentile(vals, 50),
            "p99_s": percentile(vals, 99),
        }

    def summary(self) -> dict[str, float]:
        return {
            "tasks": self.n_tasks,
            "makespan_s": round(self.makespan, 6),
            "throughput_tps": round(self.throughput, 2),
        }
