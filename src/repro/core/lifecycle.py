"""Engine-agnostic task-lifecycle kernel (paper Fig. 3 / Algorithm 1).

The XiTAO task lifetime — **wake** (predecessor commits, binding placement
of HIGH tasks) → **place** → **dequeue or steal-with-re-search** →
**commit** (leader measures, PTT feedback, dependents wake) — used to be
implemented twice: once inside the discrete-event simulator and once
inside the threaded runtime, and the two copies drifted (the threaded
engine lost priority dequeue, seeded steal tie-breaks and revocation
entirely).  This module is the single implementation both engines drive:

* :class:`SchedulingKernel` owns the scheduler, the shared
  :class:`~.queues.WorkQueues`, and a *time source* (simulated clock for
  the DES, ``perf_counter`` deltas for the threaded runtime); every
  decision point of the lifecycle is a method here;
* what remains in each engine is only its execution substrate: event-heap
  rate integration in the simulator, worker threads + barriers in the
  threaded runtime.

All randomness flows through the scheduler's seeded streams, so the DES
stays bit-reproducible and the threaded engine's *decisions* (victim
tie-breaks, placement tie-breaks) come from the same deterministic
streams even though its measurements are wall-clock.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from .places import ExecutionPlace
from .queues import BatchingConfig, WorkQueues
from .schedulers import Scheduler
from .task import Priority, Task, TaskType


def ptt_observe(bank, type_name: str, place: ExecutionPlace,
                observed: float) -> float:
    """The one PTT-feedback path (paper step 8): the leader folds an
    observed execution time into the type's trace table.  Shared by the
    DES commit, the threaded commit, and the fleet-level PodMonitor so
    the 1:4 hysteresis semantics exist exactly once."""
    return bank.for_type(type_name).update(place, observed)


class SchedulingKernel:
    """Scheduler + queues + time source = every lifecycle decision.

    ``now`` is the engine's time source (seconds since run start).  The
    kernel resets per-run scheduler state on construction
    (:meth:`Scheduler.begin_run`) so back-to-back runs on one scheduler
    object are reproducible, and clears any revoked-capacity view at
    :meth:`end_run` so it never leaks into a later run.
    """

    def __init__(self, scheduler: Scheduler, *, now: Callable[[], float],
                 queues: Optional[WorkQueues] = None):
        self.sched = scheduler
        self.now = now
        # Outstanding-work accounting for queue-aware placement: on when
        # the scheduler either penalizes load or asks for observability.
        # Off (the default) every tracking branch below is dead code, so
        # load-oblivious runs stay bit-identical.
        self.track_load = scheduler.queue_penalty > 0.0 or scheduler.track_load
        # ``queues`` lets a sharded control plane hand several kernels one
        # shared WorkQueues (per-core structures are naturally disjoint;
        # steal groups fence the victim scans).
        if queues is not None:
            self.track_load = self.track_load or queues.track_load
            self.queues = queues
        else:
            self.queues = WorkQueues(
                scheduler.topology.n_cores,
                priority_dequeue=scheduler.priority_dequeue,
                steal_high=scheduler.steal_high,
                track_load=self.track_load)
        self._all_cores = tuple(range(scheduler.topology.n_cores))
        if self.track_load:
            # per-core estimated seconds of placed/running work, charged at
            # choose_place and discharged at commit/fail/requeue; keyed by
            # tid so a discharge cancels exactly what was charged even if
            # the PTT moved in between.  The kernel-local lock exists for
            # the threaded engine, whose commit path runs outside the
            # runtime lock; the DES is single-threaded and uncontended.
            self._running_s = np.zeros(scheduler.topology.n_cores)
            self._run_charges: dict[int, tuple[tuple[int, ...], float]] = {}
            self._load_lock = threading.Lock()
            self._place_lw = [(p.leader, p.width)
                              for p in scheduler.topology.places()]
            # (n_places, max_width) member-core gather matrix for the
            # vectorized place_load: row i holds place i's member cores,
            # padded with the leader (already a member, so the padded max
            # is exactly the max over the true members)
            max_w = max(w for _, w in self._place_lw)
            gather = np.empty((len(self._place_lw), max_w), dtype=np.int64)
            for i, (leader, width) in enumerate(self._place_lw):
                gather[i, :width] = np.arange(leader, leader + width)
                gather[i, width:] = leader
            self._place_gather = gather
            scheduler.load_view = self.place_load
        # Continuous batching (see ``form_dispatch``): engines set this to
        # a BatchingConfig with max_batch > 1 to turn the coalescing
        # dequeue on.  None (the default) keeps every dequeue untouched —
        # the max_batch=1 degeneracy pin.
        self.batching: Optional[BatchingConfig] = None
        scheduler.begin_run()

    # -- wake (steps 1-2): binding placement of HIGH tasks -------------------
    def wake(self, task: Task, waker_core: int) -> int:
        """Stamp readiness, run the wake-time placement, and return the
        core whose WSQ receives the task."""
        task.t_ready = self.now()
        target = self.sched.place_on_wake(task, waker_core)
        core = waker_core if target is None else target
        if self.track_load:
            self._stamp_load_est(task, core)
        return core

    def live_cores(self) -> tuple[int, ...]:
        view = self.sched.live
        return self._all_cores if view is None else view.cores

    def requeue_displaced(self, task: Task,
                          waker: Optional[int] = None) -> int:
        """Re-place a task displaced by a revocation: the old binding is
        void (its partition may be down), the wake-time decision is redone
        over the surviving places, and priority-oblivious paths get a
        uniformly random live waker core (one seeded draw per task, so
        the sequence is scheduler-independent).  A sharded control plane
        passes ``waker`` explicitly — it draws the core from the *global*
        live set before routing to the owning shard."""
        task.t_ready = self.now()
        task.bound_place = None
        if waker is None:
            live = self.live_cores()
            rng = self.sched.rng
            waker = (live[rng.randrange(len(live))] if len(live) > 1
                     else live[0])
        target = self.sched.place_on_wake(task, waker)
        core = waker if target is None else target
        if self.track_load:
            # any in-flight charge from the displaced assignment is void
            self.discharge(task)
            self._stamp_load_est(task, core)
        return core

    # -- outstanding-work accounting (queue-aware placement) ------------------
    def estimate_seconds(self, task_type: TaskType, place: ExecutionPlace) \
            -> float:
        """Expected execution seconds of (type, place): the PTT entry, or
        the type's cost-model prior while the entry is unexplored (a cold
        table must still produce a usable backlog signal)."""
        est = self.sched.ptt.for_type(task_type.name).get(place)
        if est > 0.0:
            return est
        st = task_type.serial_time
        if not st:
            return 0.0
        kind = self.sched.topology.partition_of(place.leader).kind
        if kind in st:
            try:
                return task_type.duration(kind, place.width)
            except Exception:
                return st[kind] / place.width
        return min(st.values())

    def _stamp_load_est(self, task: Task, core: int) -> None:
        """Stamp the estimate the WSQ accounting will carry while the task
        sits queued: the bound place's expectation for HIGH tasks, the
        width-1 expectation at the receiving core otherwise."""
        place = task.bound_place
        if place is None:
            try:
                place = self.sched.topology.place_at(core, 1)
            except Exception:
                task.load_est = 0.0
                return
        task.load_est = self.estimate_seconds(task.type, place)

    def discharge(self, task: Task) -> None:
        """Drop the running-work charge of ``task`` if one is held — called
        at commit/fail feedback and by engine paths that abandon a placed
        task without either (hedge losers, suppressed commits, cancelled
        copies).  Idempotent."""
        if not self.track_load:
            return
        with self._load_lock:
            ch = self._run_charges.pop(task.tid, None)
            if ch is not None:
                cores, est = ch
                for c in cores:
                    self._running_s[c] -= est

    def place_load(self) -> np.ndarray:
        """Per-place outstanding estimated seconds (queued + running),
        aligned with ``topology.places()``.  A molded place starts when its
        most-backlogged member drains, so wide places take the max over
        member cores — one gather + row-max over the per-core vector
        (max is exact, so this matches the old per-place loop bit-for-bit)."""
        load = self.queues.queued_s + self._running_s
        out = load[self._place_gather].max(axis=1)
        return np.maximum(out, 0.0)

    def load_per_core(self) -> np.ndarray:
        """Per-core outstanding estimated seconds (queued + running)."""
        return np.maximum(self.queues.queued_s + self._running_s, 0.0)

    def backlog_signal(self) -> float:
        """Mean outstanding estimated seconds per *live* core — the load
        signal the serving brownout ladder thresholds on."""
        live = self.live_cores()
        load = self.queues.queued_s + self._running_s
        return max(float(load[list(live)].sum()), 0.0) / max(len(live), 1)

    def prime_ptt(self, task_type: TaskType, estimate: float = None) -> int:
        """Explicit PTT warmup: seed every unexplored place of ``task_type``
        with a prior (the type's cost model per place, or ``estimate``), so
        a cold table does not herd early arrivals onto one unexplored place
        at a time.  Primed entries are weak priors — the first real
        observation overwrites them directly.  Returns the number of
        entries primed."""
        tbl = self.sched.ptt.for_type(task_type.name)
        n = 0
        for place in self.sched.topology.places():
            val = (self.estimate_seconds(task_type, place)
                   if estimate is None else float(estimate))
            if val > 0.0 and tbl.prime(place, val):
                n += 1
        return n

    # -- dequeue / steal (steps 3-5) -----------------------------------------
    def on_steal(self, task: Task) -> None:
        """A stolen task's binding decision is redone at the thief."""
        task.bound_place = None

    def form_dispatch(self, task: Task, core: int) -> Task:
        """Continuous batching at the dequeue boundary: after an engine
        pops ``task`` from ``core``'s WSQ, coalesce queued tasks sharing
        its ``batch_key`` into it (oldest first, up to ``max_batch``
        total) and re-type the dispatch via :meth:`TaskType.batched`.

        The re-typed dispatch then flows through the *unchanged* single-
        task machinery — one :meth:`choose_place` search, one run charge,
        one DES duration lookup, and one PTT observation, all against the
        batched type — which is exactly the amortization continuous
        batching buys.  Members' own lifecycle resumes at the dispatch's
        commit (:meth:`batch_feedback` + per-member successor walks).
        No-op unless ``self.batching`` is set and the task carries a
        batch key; re-forming a dispatch that already holds members (a
        preempted or retried batch popped again) only tops it up to
        ``max_batch``."""
        cfg = self.batching
        if cfg is None or task.batch_key is None:
            return task
        existing = task.batch_members or []
        room = cfg.max_batch - 1 - len(existing)
        if room <= 0:
            return task
        members = self.queues.coalesce_batch(core, task.batch_key, room)
        if members:
            task.batch_members = existing + members
            base = task.type
            if base.batch_base is not None:
                # already re-typed on a previous pop; rescale from a
                # member's base type so the bucket tracks the new size
                base = members[0].type
            task.type = base.batched(1 + len(task.batch_members),
                                     cfg.member_cost)
        return task

    def batch_feedback(self, task: Task, place: ExecutionPlace,
                       observed: float) -> None:
        """Commit feedback for a batched dispatch: one PTT observation on
        the dispatch's batch-bucketed type (the learner sees batched
        throughput per size class), plus a discharge per member — members
        hold no run charges of their own (their queued charges were
        dropped at coalesce time), but a displaced-then-coalesced member
        may, and discharge is idempotent either way."""
        self.ptt_feedback(task, place, observed)
        if task.batch_members:
            for m in task.batch_members:
                self.discharge(m)

    def choose_place(self, task: Task, worker_core: int) -> ExecutionPlace:
        """Final execution place chosen by the worker that will run it
        (re-runs the local width search after a steal, steps 4-5)."""
        place = self.sched.place_on_dequeue(task, worker_core)
        if self.track_load:
            # the task left the WSQ (pop already dropped its queued charge);
            # charge its expected duration to every member core until the
            # commit/fail/requeue discharge
            self.discharge(task)
            est = self.estimate_seconds(task.type, place)
            cores = tuple(place.cores)
            with self._load_lock:
                self._run_charges[task.tid] = (cores, est)
                for c in cores:
                    self._running_s[c] += est
        return place

    # -- commit (step 8): measurement + PTT feedback + dependents ------------
    def observe_simulated(self, task_type: TaskType, duration: float) -> float:
        """The DES's measurement model: multiplicative noise (clamped to
        [0.5, 2]) plus heavy-tailed OS-jitter spikes on short tasks.  The
        threaded engine has no business here — it measures real wall
        time."""
        rng = self.sched.rng
        noise = rng.gauss(1.0, task_type.noise) if task_type.noise else 1.0
        observed = duration * min(max(noise, 0.5), 2.0)
        if task_type.spike_prob and rng.random() < task_type.spike_prob:
            observed *= task_type.spike_mag
        return observed

    def ptt_feedback(self, task: Task, place: ExecutionPlace,
                     observed: float) -> None:
        self.discharge(task)
        ptt_observe(self.sched.ptt, task.type.name, place, observed)

    # -- fault recovery (see ``repro.core.faults``) ---------------------------
    def expected_duration(self, task: Task, place: ExecutionPlace) -> float:
        """PTT-expected execution time for (type, place); 0.0 means the
        place is unexplored (straggler detection stays silent until the
        table has an expectation to compare against)."""
        return self.sched.ptt.for_type(task.type.name).get(place)

    def fault_feedback(self, task: Task, place: ExecutionPlace,
                       elapsed: float, penalty: float) -> None:
        """Penalize a failing place in the PTT so the retry's re-placement
        avoids it: fold in ``penalty`` x the worse of (time lost on the
        failure, current expectation) — a failure is evidence the place is
        unhealthy, not just slow."""
        self.discharge(task)
        tbl = self.sched.ptt.for_type(task.type.name)
        obs = max(elapsed, tbl.get(place)) * penalty
        if obs > 0.0:
            ptt_observe(self.sched.ptt, task.type.name, place, obs)

    def hedge_place(self, task: Task, exclude_cores, rng) -> \
            Optional[ExecutionPlace]:
        """PTT-best live place for a speculative duplicate that shares no
        core with the straggling original (``exclude_cores``), or None if
        no such place survives.  Tie-breaks draw from the dedicated fault
        ``rng``, never the scheduler's streams."""
        view = self.sched.live
        live = set(self._all_cores if view is None else view.cores)
        tbl = self.sched.ptt.for_type(task.type.name)
        cand = [p for p in self.sched.topology.places()
                if live.issuperset(p.cores)
                and not exclude_cores.intersection(p.cores)]
        if not cand:
            return None
        return tbl.best(cand, cost=False, rng=rng)

    def commit_successors(self, task: Task, lock=None) -> Iterator[Task]:
        """Yield the tasks a commit makes ready, in wake order: dependents
        whose last input this was (in child order), then dynamically
        inserted zero-dep tasks from ``on_commit``.  ``lock`` (threaded
        engine) guards each dependency decrement — parents committing
        concurrently may share a child."""
        for child in task.children:
            if lock is None:
                child.n_deps -= 1
                ready = child.n_deps == 0
            else:
                with lock:
                    child.n_deps -= 1
                    ready = child.n_deps == 0
            if ready:
                yield child
        if task.on_commit is not None:
            for new_task in task.on_commit(task):
                if new_task.n_deps == 0:
                    yield new_task

    def set_availability(self, down_cores: frozenset) -> None:
        """Refresh the scheduler's live view for a revoked core set (the
        engines call this at revoke/restore edges; views are interned on
        the topology).  An empty set clears the mask entirely."""
        self.sched.live = (None if not down_cores else
                           self.sched.topology.live_view_cores(down_cores))

    def end_run(self) -> None:
        """A run that finishes mid-outage must not leak its availability
        mask into later runs reusing the scheduler (PTT state is meant to
        carry across runs; a revoked-capacity view is not)."""
        self.sched.live = None


def split_by_priority(tasks: Iterable[Task]) -> tuple[list[Task], list[Task]]:
    """Partition displaced work for HIGH-first re-placement: the critical
    path re-binds before the bulk work lands on the survivors."""
    high: list[Task] = []
    low: list[Task] = []
    for t in tasks:
        (high if t.priority == Priority.HIGH else low).append(t)
    return high, low
