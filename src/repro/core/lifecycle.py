"""Engine-agnostic task-lifecycle kernel (paper Fig. 3 / Algorithm 1).

The XiTAO task lifetime — **wake** (predecessor commits, binding placement
of HIGH tasks) → **place** → **dequeue or steal-with-re-search** →
**commit** (leader measures, PTT feedback, dependents wake) — used to be
implemented twice: once inside the discrete-event simulator and once
inside the threaded runtime, and the two copies drifted (the threaded
engine lost priority dequeue, seeded steal tie-breaks and revocation
entirely).  This module is the single implementation both engines drive:

* :class:`SchedulingKernel` owns the scheduler, the shared
  :class:`~.queues.WorkQueues`, and a *time source* (simulated clock for
  the DES, ``perf_counter`` deltas for the threaded runtime); every
  decision point of the lifecycle is a method here;
* what remains in each engine is only its execution substrate: event-heap
  rate integration in the simulator, worker threads + barriers in the
  threaded runtime.

All randomness flows through the scheduler's seeded streams, so the DES
stays bit-reproducible and the threaded engine's *decisions* (victim
tie-breaks, placement tie-breaks) come from the same deterministic
streams even though its measurements are wall-clock.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator, Optional

from .places import ExecutionPlace
from .queues import WorkQueues
from .schedulers import Scheduler
from .task import Priority, Task, TaskType


def ptt_observe(bank, type_name: str, place: ExecutionPlace,
                observed: float) -> float:
    """The one PTT-feedback path (paper step 8): the leader folds an
    observed execution time into the type's trace table.  Shared by the
    DES commit, the threaded commit, and the fleet-level PodMonitor so
    the 1:4 hysteresis semantics exist exactly once."""
    return bank.for_type(type_name).update(place, observed)


class SchedulingKernel:
    """Scheduler + queues + time source = every lifecycle decision.

    ``now`` is the engine's time source (seconds since run start).  The
    kernel resets per-run scheduler state on construction
    (:meth:`Scheduler.begin_run`) so back-to-back runs on one scheduler
    object are reproducible, and clears any revoked-capacity view at
    :meth:`end_run` so it never leaks into a later run.
    """

    def __init__(self, scheduler: Scheduler, *, now: Callable[[], float]):
        self.sched = scheduler
        self.now = now
        self.queues = WorkQueues(
            scheduler.topology.n_cores,
            priority_dequeue=scheduler.priority_dequeue,
            steal_high=scheduler.steal_high)
        self._all_cores = tuple(range(scheduler.topology.n_cores))
        scheduler.begin_run()

    # -- wake (steps 1-2): binding placement of HIGH tasks -------------------
    def wake(self, task: Task, waker_core: int) -> int:
        """Stamp readiness, run the wake-time placement, and return the
        core whose WSQ receives the task."""
        task.t_ready = self.now()
        target = self.sched.place_on_wake(task, waker_core)
        return waker_core if target is None else target

    def live_cores(self) -> tuple[int, ...]:
        view = self.sched.live
        return self._all_cores if view is None else view.cores

    def requeue_displaced(self, task: Task) -> int:
        """Re-place a task displaced by a revocation: the old binding is
        void (its partition may be down), the wake-time decision is redone
        over the surviving places, and priority-oblivious paths get a
        uniformly random live waker core (one seeded draw per task, so
        the sequence is scheduler-independent)."""
        task.t_ready = self.now()
        task.bound_place = None
        live = self.live_cores()
        rng = self.sched.rng
        waker = live[rng.randrange(len(live))] if len(live) > 1 else live[0]
        target = self.sched.place_on_wake(task, waker)
        return waker if target is None else target

    # -- dequeue / steal (steps 3-5) -----------------------------------------
    def on_steal(self, task: Task) -> None:
        """A stolen task's binding decision is redone at the thief."""
        task.bound_place = None

    def choose_place(self, task: Task, worker_core: int) -> ExecutionPlace:
        """Final execution place chosen by the worker that will run it
        (re-runs the local width search after a steal, steps 4-5)."""
        return self.sched.place_on_dequeue(task, worker_core)

    # -- commit (step 8): measurement + PTT feedback + dependents ------------
    def observe_simulated(self, task_type: TaskType, duration: float) -> float:
        """The DES's measurement model: multiplicative noise (clamped to
        [0.5, 2]) plus heavy-tailed OS-jitter spikes on short tasks.  The
        threaded engine has no business here — it measures real wall
        time."""
        rng = self.sched.rng
        noise = rng.gauss(1.0, task_type.noise) if task_type.noise else 1.0
        observed = duration * min(max(noise, 0.5), 2.0)
        if task_type.spike_prob and rng.random() < task_type.spike_prob:
            observed *= task_type.spike_mag
        return observed

    def ptt_feedback(self, task: Task, place: ExecutionPlace,
                     observed: float) -> None:
        ptt_observe(self.sched.ptt, task.type.name, place, observed)

    # -- fault recovery (see ``repro.core.faults``) ---------------------------
    def expected_duration(self, task: Task, place: ExecutionPlace) -> float:
        """PTT-expected execution time for (type, place); 0.0 means the
        place is unexplored (straggler detection stays silent until the
        table has an expectation to compare against)."""
        return self.sched.ptt.for_type(task.type.name).get(place)

    def fault_feedback(self, task: Task, place: ExecutionPlace,
                       elapsed: float, penalty: float) -> None:
        """Penalize a failing place in the PTT so the retry's re-placement
        avoids it: fold in ``penalty`` x the worse of (time lost on the
        failure, current expectation) — a failure is evidence the place is
        unhealthy, not just slow."""
        tbl = self.sched.ptt.for_type(task.type.name)
        obs = max(elapsed, tbl.get(place)) * penalty
        if obs > 0.0:
            ptt_observe(self.sched.ptt, task.type.name, place, obs)

    def hedge_place(self, task: Task, exclude_cores, rng) -> \
            Optional[ExecutionPlace]:
        """PTT-best live place for a speculative duplicate that shares no
        core with the straggling original (``exclude_cores``), or None if
        no such place survives.  Tie-breaks draw from the dedicated fault
        ``rng``, never the scheduler's streams."""
        view = self.sched.live
        live = set(self._all_cores if view is None else view.cores)
        tbl = self.sched.ptt.for_type(task.type.name)
        cand = [p for p in self.sched.topology.places()
                if p.leader in live and not exclude_cores.intersection(p.cores)]
        if not cand:
            return None
        return tbl.best(cand, cost=False, rng=rng)

    def commit_successors(self, task: Task, lock=None) -> Iterator[Task]:
        """Yield the tasks a commit makes ready, in wake order: dependents
        whose last input this was (in child order), then dynamically
        inserted zero-dep tasks from ``on_commit``.  ``lock`` (threaded
        engine) guards each dependency decrement — parents committing
        concurrently may share a child."""
        for child in task.children:
            if lock is None:
                child.n_deps -= 1
                ready = child.n_deps == 0
            else:
                with lock:
                    child.n_deps -= 1
                    ready = child.n_deps == 0
            if ready:
                yield child
        if task.on_commit is not None:
            for new_task in task.on_commit(task):
                if new_task.n_deps == 0:
                    yield new_task

    def end_run(self) -> None:
        """A run that finishes mid-outage must not leak its availability
        mask into later runs reusing the scheduler (PTT state is meant to
        carry across runs; a revoked-capacity view is not)."""
        self.sched.live = None


def split_by_priority(tasks: Iterable[Task]) -> tuple[list[Task], list[Task]]:
    """Partition displaced work for HIGH-first re-placement: the critical
    path re-binds before the bulk work lands on the survivors."""
    high: list[Task] = []
    low: list[Task] = []
    for t in tasks:
        (high if t.priority == Priority.HIGH else low).append(t)
    return high, low
