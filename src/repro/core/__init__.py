"""Core: the paper's contribution — dynamic-asymmetry-aware DAG scheduling.

Public surface:
  places      — ExecutionPlace / ResourcePartition / Topology (+ presets)
  ptt         — Performance Trace Table (online EMA model, 1:4 weighting)
  task        — Task / TaskType + the paper's kernel cost models
  dag         — synthetic / kmeans / heat DAG builders
  schedulers  — RWS, RWSM-C, FA, FAM-C, DA, DAM-C, DAM-P (Algorithm 1)
  queues      — split HIGH-FIFO/LOW-LIFO WSQs + AQs (shared by both engines)
  lifecycle   — engine-agnostic scheduling kernel (wake/place/steal/commit)
  interference— co-running apps + DVFS speed profiles
  preemption  — seeded pod-slice revoke/restore episode models
  faults      — seeded task-level fault injection + recovery policy
  shards      — sharded control plane (per-pod kernels + global rebalancer)
  simulator   — discrete-event engine (paper-scale evaluation)
  multirun    — batched multi-run engine (sweeps fanned across host cores)
  runtime     — threaded executor running real payloads (JAX kernels)
  metrics     — throughput / placement / worktime aggregation
"""
from .dag import (DAG, chain_dag, decode_pool_dag, heat_dag, kmeans_dag,
                  mixed_dag, synthetic_dag)
from .faults import (Fault, FaultModel, RecoveryPolicy, mmpp_faults,
                     task_faults)
from .lifecycle import SchedulingKernel, ptt_observe, split_by_priority
from .interference import (BackgroundApp, LoadCoupledGovernor,
                           PeriodicProfile, SpeedProfile, SpeedProfileBase,
                           TraceProfile, burst_episodes, corun_chain,
                           corun_socket, dvfs_denver, governor_profile,
                           mmpp_burst_episodes, mmpp_on_off,
                           mmpp_state_timeline, random_walk_trace,
                           renewal_on_off)
from .metrics import RequestRecord, RunMetrics, TaskRecord
from .multirun import (RunSpec, default_workers, run_cell, run_cells,
                       shutdown_pool)
from .places import ExecutionPlace, LiveView, ResourcePartition, Topology, \
    haswell, haswell_cluster, tpu_pod_slices, tx2, tx2_xl
from .preemption import (PreemptionModel, mmpp_preemption,
                         pod_slice_preemption, prune_full_outages,
                         sub_slice_preemption)
from .ptt import PTT, PTTBank
from .queues import BatchingConfig, SplitWSQ, WorkQueues
from .runtime import ThreadedRuntime, run_threaded
from .schedulers import ALL_SCHEDULERS, Scheduler, make_scheduler
from .shards import (GlobalRebalancer, ShardedControlPlane, ShardingSpec,
                     make_control_plane)
from .simulator import Simulator, simulate
from .task import (Priority, Task, TaskType, batch_bucket, copy_type,
                   kmeans_map_type, kmeans_reduce_type, matmul_type,
                   mpi_exchange_type, stencil_type)

__all__ = [
    "DAG", "chain_dag", "decode_pool_dag", "heat_dag", "kmeans_dag",
    "mixed_dag", "synthetic_dag",
    "BackgroundApp", "PeriodicProfile", "SpeedProfile", "SpeedProfileBase",
    "TraceProfile", "burst_episodes", "corun_chain", "corun_socket",
    "dvfs_denver", "governor_profile", "LoadCoupledGovernor",
    "mmpp_burst_episodes", "mmpp_on_off", "mmpp_state_timeline",
    "random_walk_trace", "renewal_on_off",
    "RequestRecord", "RunMetrics", "TaskRecord", "ExecutionPlace", "LiveView",
    "ResourcePartition", "Topology", "haswell", "haswell_cluster",
    "tpu_pod_slices", "tx2", "tx2_xl",
    "PreemptionModel", "mmpp_preemption", "pod_slice_preemption",
    "prune_full_outages", "sub_slice_preemption",
    "GlobalRebalancer", "ShardedControlPlane", "ShardingSpec",
    "make_control_plane",
    "Fault", "FaultModel", "RecoveryPolicy", "mmpp_faults", "task_faults",
    "SchedulingKernel", "ptt_observe", "split_by_priority",
    "BatchingConfig", "SplitWSQ", "WorkQueues", "batch_bucket",
    "PTT", "PTTBank", "ThreadedRuntime",
    "run_threaded", "ALL_SCHEDULERS", "Scheduler", "make_scheduler",
    "RunSpec", "default_workers", "run_cell", "run_cells", "shutdown_pool",
    "Simulator", "simulate", "Priority", "Task", "TaskType", "copy_type",
    "kmeans_map_type", "kmeans_reduce_type", "matmul_type",
    "mpi_exchange_type", "stencil_type",
]
