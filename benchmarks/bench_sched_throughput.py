"""Scheduler-engine throughput: simulated-tasks-per-wall-second.

This measures the *simulator itself* (the cost of the scheduling machinery),
not the simulated application: how many DAG tasks the discrete-event engine
retires per second of wall time.  It is the perf-trajectory guardrail for
the incremental-dispatch architecture (see ``repro/core/simulator.py``) —
the headline cell is the Fig. 4 acceptance workload (matmul / P4 / DAM-C /
2,000 tasks on the TX2 with a core-0 co-runner), and the ``tx2_xl`` /
``haswell`` sweeps demonstrate the headroom on larger topologies where the
old all-cores fixpoint scaled worst.

The sweep cells run through the multi-run engine (each worker times its
own ``simulate`` call; with ``workers>1`` those wall numbers include host
contention, which is fine for breadth cells).  The headline is always
measured serially in-process — one untimed warmup + best-of-5 — so the
trajectory number is never polluted by sibling workers.

Emits ``name,value,derived`` CSV rows and a ``BENCH_sched.json`` artifact,
which is also mirrored to the repo root for the perf-trajectory tracker.
"""
from __future__ import annotations

from repro.core import ALL_SCHEDULERS, RunSpec, run_cell, run_cells

from .common import emit, write_artifact

# (workload name, topology spec, parallelism, total tasks, bg cores);
# the emitted key carries the *actual* task count so --fast (halved) runs
# never alias full-size trajectory cells
WORKLOADS = (
    ("tx2/P4", ("tx2", {}), 4, 2000, (0,)),
    ("tx2_xl4/P16", ("tx2_xl", {"clusters": 4}), 16, 8000, (0, 6)),
    ("haswell/P10", ("haswell", {}), 10, 6000, (0,)),
)

_TT = ("matmul", {"tile": 64})


def _spec(key, topo_spec, parallelism, total, bg_cores, sched_name, *,
          seed: int = 1) -> RunSpec:
    return RunSpec(
        key=key,
        dag=("synthetic", {"task_type": _TT, "parallelism": parallelism,
                           "total_tasks": total}),
        scheduler=sched_name,
        topology=topo_spec,
        seed=seed,
        background=tuple(("chain", {"task_type": _TT, "core": c})
                         for c in bg_cores),
        measure_wall=True,
    )


def run(fast: bool = False, workers: int | None = None) -> dict:
    out: dict = {}
    workloads = WORKLOADS if not fast else WORKLOADS[:1]
    scheds = ALL_SCHEDULERS if not fast else ("RWS", "FA", "DAM-C")
    specs, expected = [], {}
    for wname, topo_spec, p, total, bg in workloads:
        n = total if not fast else total // 2
        for sched_name in scheds:
            key = f"sched_throughput/{wname}/{n // 1000}k/{sched_name}"
            specs.append(_spec(key, topo_spec, p, n, bg, sched_name))
            expected[key] = n
    for key, res in run_cells(specs, workers=workers).items():
        assert res["n_tasks"] == expected[key], key
        out[key] = {k: res[k] for k in
                    ("wall_s", "sim_tasks_per_s", "throughput_tps")}
        out[key]["makespan_s"] = round(res["makespan_s"], 6)
        emit(key, res["sim_tasks_per_s"], "sim_tasks_per_wall_s")
    # headline: the acceptance-criterion cell (full size even under --fast).
    # One untimed warmup + best-of-5, serial and in-process, so
    # interpreter/numpy cold-start, machine jitter, and sibling sweep
    # workers don't pollute the trajectory number.
    tx2_spec = ("tx2", {})
    run_cell(_spec("warmup", tx2_spec, 4, 500, (0,), "DAM-C"))
    headline = max((run_cell(_spec("headline", tx2_spec, 4, 2000, (0,),
                                   "DAM-C")) for _ in range(5)),
                   key=lambda r: r["sim_tasks_per_s"])
    headline = {k: headline[k] for k in
                ("wall_s", "sim_tasks_per_s", "throughput_tps")} | {
                    "makespan_s": round(headline["makespan_s"], 6)}
    out["headline/fig4_matmul_P4_DAM-C_2k"] = headline
    emit("sched_throughput/headline/DAM-C", headline["sim_tasks_per_s"],
         "acceptance: >=5x seed (seed engine: ~2.9k)")
    write_artifact("BENCH_sched", out, root_copy=True)
    return out


if __name__ == "__main__":
    run()
