"""Scheduler-engine throughput: simulated-tasks-per-wall-second.

This measures the *simulator itself* (the cost of the scheduling machinery),
not the simulated application: how many DAG tasks the discrete-event engine
retires per second of wall time.  It is the perf-trajectory guardrail for
the incremental-dispatch architecture (see ``repro/core/simulator.py``) —
the headline cell is the Fig. 4 acceptance workload (matmul / P4 / DAM-C /
2,000 tasks on the TX2 with a core-0 co-runner), and the ``tx2_xl`` /
``haswell`` sweeps demonstrate the headroom on larger topologies where the
old all-cores fixpoint scaled worst.

The sweep cells run through the multi-run engine (each worker times its
own ``simulate`` call; with ``workers>1`` those wall numbers include host
contention, which is fine for breadth cells).  The headline is always
measured serially in-process — one untimed warmup + best-of-5 — so the
trajectory number is never polluted by sibling workers.

Emits ``name,value,derived`` CSV rows and a ``BENCH_sched.json`` artifact,
which is also mirrored to the repo root for the perf-trajectory tracker.
Each cell records ``prev_sim_tasks_per_s``/``speedup_vs_prev`` against the
previously committed root artifact (the before/after trajectory), and the
``acceptance`` block carries the throughput floors ``make check`` gates
through ``tools/check_acceptance.py``.
"""
from __future__ import annotations

import json
import os

from repro.core import ALL_SCHEDULERS, RunSpec, run_cell, run_cells

from .common import REPO_ROOT, emit, write_artifact

# The scalar-core (PR 1) headline throughput this refactor is measured
# against; the acceptance criterion is >= 3x this on the same cell.
_SCALAR_CORE_HEADLINE = 14331.2
# The scalar core's slowest tx2 cell (RWSM-C: every LOW dequeue redoes
# the local width search), tracked explicitly so the outlier's trajectory
# is visible, not just the headline's.
_SCALAR_CORE_RWSM_C = 7317.6

# (workload name, topology spec, parallelism, total tasks, bg cores);
# the emitted key carries the *actual* task count so --fast (halved) runs
# never alias full-size trajectory cells
WORKLOADS = (
    ("tx2/P4", ("tx2", {}), 4, 2000, (0,)),
    ("tx2_xl4/P16", ("tx2_xl", {"clusters": 4}), 16, 8000, (0, 6)),
    ("haswell/P10", ("haswell", {}), 10, 6000, (0,)),
)

_TT = ("matmul", {"tile": 64})


def _spec(key, topo_spec, parallelism, total, bg_cores, sched_name, *,
          seed: int = 1) -> RunSpec:
    return RunSpec(
        key=key,
        dag=("synthetic", {"task_type": _TT, "parallelism": parallelism,
                           "total_tasks": total}),
        scheduler=sched_name,
        topology=topo_spec,
        seed=seed,
        background=tuple(("chain", {"task_type": _TT, "core": c})
                         for c in bg_cores),
        measure_wall=True,
    )


def _load_prev() -> dict:
    """The previously committed root artifact — the 'before' side of every
    cell's before/after trajectory pair."""
    try:
        with open(os.path.join(REPO_ROOT, "BENCH_sched.json")) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    return prev if isinstance(prev, dict) else {}


def _with_prev(cell: dict, prev_cell) -> dict:
    p = (prev_cell or {}).get("sim_tasks_per_s")
    if isinstance(p, (int, float)) and p > 0:
        cell["prev_sim_tasks_per_s"] = p
        cell["speedup_vs_prev"] = round(cell["sim_tasks_per_s"] / p, 2)
    return cell


def _best_serial(spec_args, n_runs: int) -> dict:
    res = max((run_cell(_spec(*spec_args)) for _ in range(n_runs)),
              key=lambda r: r["sim_tasks_per_s"])
    return {k: res[k] for k in
            ("wall_s", "sim_tasks_per_s", "throughput_tps")} | {
                "makespan_s": round(res["makespan_s"], 6)}


def run(fast: bool = False, workers: int | None = None) -> dict:
    out: dict = {}
    prev = _load_prev()
    workloads = WORKLOADS if not fast else WORKLOADS[:1]
    scheds = ALL_SCHEDULERS if not fast else ("RWS", "FA", "DAM-C")
    specs, expected = [], {}
    for wname, topo_spec, p, total, bg in workloads:
        n = total if not fast else total // 2
        for sched_name in scheds:
            key = f"sched_throughput/{wname}/{n // 1000}k/{sched_name}"
            specs.append(_spec(key, topo_spec, p, n, bg, sched_name))
            expected[key] = n
    for key, res in run_cells(specs, workers=workers).items():
        assert res["n_tasks"] == expected[key], key
        cell = {k: res[k] for k in
                ("wall_s", "sim_tasks_per_s", "throughput_tps")}
        cell["makespan_s"] = round(res["makespan_s"], 6)
        out[key] = _with_prev(cell, prev.get(key))
        emit(key, res["sim_tasks_per_s"], "sim_tasks_per_wall_s")
    # headline: the acceptance-criterion cell (full size even under --fast).
    # One untimed warmup + best-of-5, serial and in-process, so
    # interpreter/numpy cold-start, machine jitter, and sibling sweep
    # workers don't pollute the trajectory number.
    tx2_spec = ("tx2", {})
    run_cell(_spec("warmup", tx2_spec, 4, 500, (0,), "DAM-C"))
    hkey = "headline/fig4_matmul_P4_DAM-C_2k"
    headline = _with_prev(
        _best_serial(("headline", tx2_spec, 4, 2000, (0,), "DAM-C"), 5),
        prev.get(hkey))
    out[hkey] = headline
    emit("sched_throughput/headline/DAM-C", headline["sim_tasks_per_s"],
         "acceptance: >=3x scalar core (14.3k)")
    # the scalar core's slowest cell, tracked full-size and serial like
    # the headline so the outlier's trajectory never hides in a --fast
    # sweep or behind sibling workers
    okey = "outlier/RWSM-C_tx2_P4_2k"
    outlier = _with_prev(
        _best_serial(("outlier", tx2_spec, 4, 2000, (0,), "RWSM-C"), 3),
        prev.get(okey))
    out[okey] = outlier
    emit("sched_throughput/outlier/RWSM-C", outlier["sim_tasks_per_s"],
         "scalar-core outlier cell (was 7.3k)")
    out["methodology"] = {
        "timing": "sim_tasks_per_s = n_tasks / wall of simulate() only "
                  "(construction excluded); sweep cells timed in their "
                  "run_cells worker, headline/outlier serial in-process "
                  "with one untimed warmup, best-of-5/best-of-3",
        "trajectory": "prev_sim_tasks_per_s / speedup_vs_prev compare "
                      "against the previously committed root artifact",
        "host": "numbers are host-specific; acceptance floors leave "
                "headroom for CI contention (see benchmarks/README.md)",
    }
    out["acceptance"] = {
        "headline_sim_tasks_per_s": headline["sim_tasks_per_s"],
        "outlier_sim_tasks_per_s": outlier["sim_tasks_per_s"],
        "headline_speedup_vs_scalar_core": round(
            headline["sim_tasks_per_s"] / _SCALAR_CORE_HEADLINE, 2),
        "headline_floor_35k":
            headline["sim_tasks_per_s"] >= 35000.0,
        "headline_ge_3x_scalar_core":
            headline["sim_tasks_per_s"] >= 3.0 * _SCALAR_CORE_HEADLINE,
        "outlier_rwsm_c_floor_20k":
            outlier["sim_tasks_per_s"] >= 20000.0,
        "outlier_rwsm_c_ge_2x_scalar_core":
            outlier["sim_tasks_per_s"] >= 2.0 * _SCALAR_CORE_RWSM_C,
    }
    write_artifact("BENCH_sched", out, root_copy=True)
    return out


if __name__ == "__main__":
    run()
