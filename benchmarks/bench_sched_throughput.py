"""Scheduler-engine throughput: simulated-tasks-per-wall-second.

This measures the *simulator itself* (the cost of the scheduling machinery),
not the simulated application: how many DAG tasks the discrete-event engine
retires per second of wall time.  It is the perf-trajectory guardrail for
the incremental-dispatch architecture (see ``repro/core/simulator.py``) —
the headline cell is the Fig. 4 acceptance workload (matmul / P4 / DAM-C /
2,000 tasks on the TX2 with a core-0 co-runner), and the ``tx2_xl`` /
``haswell`` sweeps demonstrate the headroom on larger topologies where the
old all-cores fixpoint scaled worst.

Emits ``name,value,derived`` CSV rows and a ``BENCH_sched.json`` artifact.
"""
from __future__ import annotations

import time

from repro.core import (ALL_SCHEDULERS, corun_chain, haswell, make_scheduler,
                        matmul_type, simulate, synthetic_dag, tx2, tx2_xl)

from .common import Timer, emit, write_artifact

# (workload name, topology factory, parallelism, total tasks, bg cores);
# the emitted key carries the *actual* task count so --fast (halved) runs
# never alias full-size trajectory cells
WORKLOADS = (
    ("tx2/P4", tx2, 4, 2000, (0,)),
    ("tx2_xl4/P16", lambda: tx2_xl(4), 16, 8000, (0, 6)),
    ("haswell/P10", haswell, 10, 6000, (0,)),
)


def _bench(topo_factory, parallelism, total, bg_cores, sched_name,
           *, seed: int = 1) -> dict:
    tt = matmul_type(64)
    sched = make_scheduler(sched_name, topo_factory(), seed=seed)
    dag = synthetic_dag(tt, parallelism=parallelism, total_tasks=total)
    bg = [corun_chain(tt, core=c) for c in bg_cores]
    with Timer() as t:
        m = simulate(dag, sched, background=bg)
    assert m.n_tasks == total, (sched_name, m.n_tasks)
    return {
        "wall_s": round(t.s, 4),
        "sim_tasks_per_s": round(m.n_tasks / t.s, 1),
        "throughput_tps": round(m.throughput, 1),
        "makespan_s": round(m.makespan, 6),
    }


def run(fast: bool = False) -> dict:
    out: dict = {}
    workloads = WORKLOADS if not fast else WORKLOADS[:1]
    scheds = ALL_SCHEDULERS if not fast else ("RWS", "FA", "DAM-C")
    for wname, topo_factory, p, total, bg in workloads:
        n = total if not fast else total // 2
        for sched_name in scheds:
            res = _bench(topo_factory, p, n, bg, sched_name)
            key = f"sched_throughput/{wname}/{n // 1000}k/{sched_name}"
            out[key] = res
            emit(key, res["sim_tasks_per_s"], "sim_tasks_per_wall_s")
    # headline: the acceptance-criterion cell (full size even under --fast).
    # One untimed warmup + best-of-5 so interpreter/numpy cold-start and
    # machine jitter (shared CI hosts) don't pollute the trajectory number.
    _bench(tx2, 4, 500, (0,), "DAM-C")
    headline = max((_bench(tx2, 4, 2000, (0,), "DAM-C") for _ in range(5)),
                   key=lambda r: r["sim_tasks_per_s"])
    out["headline/fig4_matmul_P4_DAM-C_2k"] = headline
    emit("sched_throughput/headline/DAM-C", headline["sim_tasks_per_s"],
         "acceptance: >=5x seed (seed engine: ~2.9k)")
    write_artifact("BENCH_sched", out)
    return out


if __name__ == "__main__":
    run()
