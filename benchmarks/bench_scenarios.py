"""Scenario sweeps beyond the paper's figures: bursty co-runners,
per-partition DVFS governors, and trace-driven asymmetry on scaled
topologies.

Three *dynamic* interference scenarios (the regimes where adaptive
schedulers differentiate — cf. Mage, arXiv:1804.06462, and the
learning-based dynamic-pinning line, arXiv:1803.00355), swept over
scaled topologies (``tx2_xl(8)`` = 48 cores, ``haswell_cluster`` = 80
cores) and DAG parallelism beyond the paper's P=6, with multi-seed
error bars per cell:

* ``bursty``   — seeded on/off co-runner episodes (exponential idle/busy
  lengths) on a few cores: interference arrives and leaves while the DAG
  runs, so static placements go stale mid-run.
* ``governor`` — every partition runs its own phase-staggered, slightly
  detuned DVFS square-wave governor (closed-form periodic profiles; no
  segment materialization at any horizon).
* ``trace``    — per-core random-walk speed traces (stand-ins for
  recorded co-tenancy traces) plus a persistent core-0 co-runner.
* ``governor_load`` — a single-cell probe (first topology, P=8): the
  governor square-waves are *coupled to partition load* via
  ``LoadCoupledGovernor`` (a partition running more tasks detunes
  harder), so placement decisions feed back into the asymmetry the
  scheduler must adapt to.
* ``mmpp_storm`` — a single-cell probe on the *sharded* control plane
  (``pods_per_shard=2`` + rebalancer + overflow routing): MMPP-correlated
  co-runner bursts (``mmpp_burst_episodes``) share one calm/storm
  timeline across one core group per cluster, so interference storms hit
  several shards at once and the rebalancer must move queued work while
  the storm lasts.

Each (scenario, topology, P, scheduler) cell runs at several seeds; the
emitted aggregates are mean ± population-std of throughput across seeds.
All cells fan out through the multi-run engine and its cached worker
pool.  ``--fast`` shrinks the grid to CI size.
"""
from __future__ import annotations

import statistics

from repro.core import RunSpec, run_cells

from .common import emit, write_artifact

_TT = ("matmul", {"tile": 64})

# interference timescales are chosen against the ~0.01-0.05 s makespans of
# these cells: several episodes / dozens of governor flips / many trace
# steps land inside every run
_T_END = 0.5

SCHEDULERS = ("RWS", "FA", "DAM-C", "DAM-P")
TOPOLOGIES = (
    ("tx2_xl8", ("tx2_xl", {"clusters": 8})),
    ("haswell_cluster", ("haswell_cluster", {})),
)
PARALLELISM = (8, 16, 24)
SEEDS = (1, 2, 3)
FULL_TASKS, CI_TASKS = 2000, 600


def _scenario_kwargs(scenario: str, seed: int) -> dict:
    """RunSpec speed/background fields for one scenario cell.  The cell
    seed also seeds the interference pattern, so seeds vary both the
    scheduler RNG and the environment."""
    if scenario == "bursty":
        return dict(background=(
            ("bursty", {"task_type": _TT, "cores": (0, 1, 2), "seed": seed,
                        "t_end": _T_END, "mean_on": 0.002,
                        "mean_off": 0.004}),))
    if scenario == "governor":
        return dict(speed=("governor", {"period": 0.004, "lo": 0.2,
                                        "t_end": _T_END,
                                        "period_spread": 0.05}))
    if scenario == "trace":
        return dict(
            background=(("chain", {"task_type": _TT, "core": 0}),),
            speed=("trace_walk", {"seed": seed, "dt": 0.002, "t_end": _T_END,
                                  "lo": 0.25, "step": 0.2}))
    if scenario == "governor_load":
        # same detuned square-wave governors, but coupled to partition
        # load (``LoadCoupledGovernor``): a partition running more tasks
        # detunes harder, so the scheduler's own placement shifts the
        # asymmetry it must adapt to
        return dict(speed=("governor_load", {"coupling": 0.3,
                                             "period": 0.004, "lo": 0.2,
                                             "t_end": _T_END,
                                             "period_spread": 0.05}))
    if scenario == "mmpp_storm":
        # correlated bursts (one MMPP calm/storm timeline, one burst
        # stream per core group) on a sharded control plane: storms land
        # on several shards together, so the global rebalancer — not just
        # local stealing — has to dig the hot shards out
        return dict(
            background=(("mmpp_bursty", {
                "task_type": _TT,
                "core_groups": ((0, 1, 2), (6, 7, 8), (12, 13, 14),
                                (18, 19, 20)),
                "seed": seed, "t_end": _T_END, "mean_on": 0.002,
                "mean_calm": 0.02, "mean_storm": 0.008,
                "mean_off_calm": 0.008, "mean_off_storm": 0.002}),),
            sharding=(("pods_per_shard", 2), ("rebalance_period_s", 0.002),
                      ("overflow_ratio", 2.0)))
    raise ValueError(f"unknown scenario {scenario!r}")


SCENARIOS = ("bursty", "governor", "trace", "governor_load", "mmpp_storm")


def grid(fast: bool = False) -> list[RunSpec]:
    topos = TOPOLOGIES if not fast else (("tx2_xl4", ("tx2_xl", {"clusters": 4})),)
    par = PARALLELISM if not fast else (8,)
    scheds = SCHEDULERS if not fast else ("RWS", "DAM-C")
    seeds = SEEDS if not fast else (1, 2)
    total = FULL_TASKS if not fast else CI_TASKS
    specs = []
    for scenario in SCENARIOS:
        # governor_load / mmpp_storm are single-cell probes (load
        # feedback, sharded-plane storms), not full sweep axes: first
        # topology, smallest P
        probe = scenario in ("governor_load", "mmpp_storm")
        sc_topos = topos[:1] if probe else topos
        sc_par = par[:1] if probe else par
        for tname, topo_spec in sc_topos:
            for p in sc_par:
                for sched_name in scheds:
                    for seed in seeds:
                        specs.append(RunSpec(
                            key=f"scenarios/{scenario}/{tname}/P{p}/"
                                f"{sched_name}/seed{seed}",
                            dag=("synthetic", {"task_type": _TT,
                                               "parallelism": p,
                                               "total_tasks": total}),
                            scheduler=sched_name,
                            topology=topo_spec,
                            seed=seed,
                            **_scenario_kwargs(scenario, seed)))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    specs = grid(fast)
    results = run_cells(specs, workers=workers)
    out: dict = {k: {"throughput_tps": r["throughput_tps"],
                     "makespan_s": r["makespan_s"]}
                 for k, r in results.items()}
    # aggregate across seeds: mean ± population std per cell
    groups: dict[str, list[float]] = {}
    for key, res in results.items():
        cell = key.rsplit("/seed", 1)[0]
        groups.setdefault(cell, []).append(res["throughput_tps"])
    for cell, tps in groups.items():
        mean = statistics.mean(tps)
        std = statistics.pstdev(tps)
        out[f"{cell}/mean"] = round(mean, 1)
        out[f"{cell}/std"] = round(std, 1)
        emit(f"{cell}/mean_tps", round(mean, 1),
             f"±{round(std, 1)} over {len(tps)} seeds")
    # headline ratios: adaptive vs random under each dynamic scenario
    adaptive = "DAM-C"
    for scenario in SCENARIOS:
        ratios = []
        for cell, tps in groups.items():
            if f"/{scenario}/" in f"/{cell}/" and cell.endswith(f"/{adaptive}"):
                base_cell = cell.rsplit("/", 1)[0] + "/RWS"
                if base_cell in groups:
                    ratios.append(statistics.mean(tps)
                                  / statistics.mean(groups[base_cell]))
        if ratios:
            emit(f"scenarios/{scenario}/DAM-C_vs_RWS_avg",
                 round(sum(ratios) / len(ratios), 2),
                 "adaptive vs random, mean over topo x P")
    write_artifact("scenarios", out)
    return out


if __name__ == "__main__":
    run()
