"""Pallas kernel microbenchmarks (interpret-mode correctness timing on CPU
+ XLA-path wall time).  On this CPU-only container the numbers measure the
XLA fallback path; the interpret pass validates the kernels' semantics at
bench shapes.  name,us_per_call,derived CSV per the harness contract."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

from .common import emit, write_artifact


def _time(fn, *args, iters=5) -> float:
    warm = fn(*args)                 # single warm-up call (compile + trace)
    if isinstance(warm, tuple):
        warm[0].block_until_ready()
    else:
        jax.block_until_ready(warm)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(fast: bool = False, workers: int | None = None) -> dict:
    out = {}                       # workers: unused (single-process suite)
    k = jax.random.PRNGKey(0)

    a = jax.random.normal(k, (512, 512))
    b = jax.random.normal(k, (512, 512))
    us = _time(jax.jit(ops.matmul), a, b)
    flops = 2 * 512 ** 3
    out["matmul_512"] = us
    emit("kernels/matmul_512_xla", round(us, 1),
         f"{flops / us / 1e3:.1f}_GFLOPs")
    got = matmul_pallas(a, b, interpret=True)
    err = float(jnp.abs(got - ref.matmul_ref(a, b)).max())
    emit("kernels/matmul_512_pallas_interp_maxerr", f"{err:.2e}", "vs_ref")

    q = jax.random.normal(k, (1, 8, 512, 64))
    kk = jax.random.normal(k, (1, 2, 512, 64))
    v = jax.random.normal(k, (1, 2, 512, 64))
    us = _time(jax.jit(lambda *x: ops.flash_attention(*x)), q, kk, v)
    out["attention_512"] = us
    emit("kernels/attention_512_xla", round(us, 1), "B1_Hq8_Hkv2_D64")
    got = flash_attention_pallas(q, kk, v, bq=128, bk=128, interpret=True)
    err = float(jnp.abs(got - ref.attention_ref(q, kk, v)).max())
    emit("kernels/attention_512_pallas_interp_maxerr", f"{err:.2e}", "vs_ref")

    x = jax.random.normal(k, (2, 512, 4, 64)) * 0.3
    aa = -jnp.abs(jax.random.normal(k, (2, 512, 4))) * 0.1
    bb = jax.random.normal(k, (2, 512, 64)) * 0.3
    cc = jax.random.normal(k, (2, 512, 64)) * 0.3
    us = _time(jax.jit(ops.ssd_scan), x, aa, bb, cc)
    out["ssd_512"] = us
    emit("kernels/ssd_512_xla", round(us, 1), "B2_S512_H4_D64_N64")
    got = ssd_scan_pallas(x, aa, bb, cc, chunk=128, interpret=True)
    err = float(jnp.abs(got - ref.ssd_ref(x, aa, bb, cc)).max())
    emit("kernels/ssd_512_pallas_interp_maxerr", f"{err:.2e}", "vs_ref")

    u = jax.random.normal(k, (1, 512, 512))
    us = _time(jax.jit(ops.stencil), u)
    out["stencil_512"] = us
    emit("kernels/stencil_512_xla", round(us, 1), "jacobi_5pt")

    xx = jax.random.normal(k, (1024, 2048))
    us = _time(jax.jit(ops.copy), xx)
    gbps = 2 * xx.size * 4 / (us * 1e-6) / 1e9
    out["copy_8MB"] = us
    emit("kernels/copy_8MB_xla", round(us, 1), f"{gbps:.1f}_GB/s")

    write_artifact("kernels_microbench", out)
    return out


if __name__ == "__main__":
    run()
