"""Benchmark orchestrator — one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--workers N] [--only fig4,fig7,...]

Prints ``name,value,derived`` CSV rows; JSON artifacts land in
benchmarks/artifacts/ (each artifact self-reports its suite's wall time
under ``_meta``).  The grid suites (fig4/fig7/fig8/sched) fan their cells
across ``--workers`` processes via the multi-run engine
(``repro.core.multirun``); the default uses every host core, ``--workers 1``
runs serially with bit-identical per-cell results.  Full paper sizes are
the default; ``--fast`` drops to CI sizes.  The roofline section reads the
dry-run artifacts (produce them with ``python -m repro.launch.dryrun --all
--mesh both``).

Running the ``sched`` suite also refreshes the repo-root ``BENCH_sched.json``
headline artifact that the perf-trajectory tracker reads.

``make check`` runs the smoke subset (fig4 + kernels, 2 workers) plus the
test suite.
"""
from __future__ import annotations

import argparse
import time

from . import (bench_dvfs, bench_faults, bench_heat, bench_interference,
               bench_kernels, bench_kmeans, bench_preemption, bench_roofline,
               bench_scale, bench_scenarios, bench_sched_throughput,
               bench_sensitivity, bench_serve, bench_task_distribution)
from . import common

SUITES = {
    "fig4": bench_interference.run,
    "fig5_6": bench_task_distribution.run,
    "fig7": bench_dvfs.run,
    "fig8": bench_sensitivity.run,
    "fig9": bench_kmeans.run,
    "fig10": bench_heat.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
    "scenarios": bench_scenarios.run,
    "preempt": bench_preemption.run,
    "faults": bench_faults.run,
    "sched": bench_sched_throughput.run,
    "serve": bench_serve.run,
    "scale": bench_scale.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced task counts (CI-speed); default is "
                         "paper-full sizes")
    ap.add_argument("--workers", type=int, default=None,
                    help="processes for the grid suites (default: all host "
                         "cores; 1 = serial in-process)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {','.join(unknown)}; "
                 f"available: {','.join(SUITES)}")
    print("name,value,derived")
    t0 = time.time()
    for name in names:
        t = time.time()
        common.begin_suite(name)
        SUITES[name](fast=args.fast, workers=args.workers)
        print(f"suite/{name}/elapsed_s,{time.time() - t:.1f},")
    print(f"suite/total_elapsed_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    main()
