"""Benchmark orchestrator — one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig4,fig7,...]

Prints ``name,value,derived`` CSV rows; JSON artifacts land in
benchmarks/artifacts/ (each artifact self-reports its suite's wall time
under ``_meta``).  The roofline section reads the dry-run artifacts
(produce them with ``python -m repro.launch.dryrun --all --mesh both``).

``make check`` runs the smoke subset (fig4 + kernels) plus the test suite.
"""
from __future__ import annotations

import argparse
import time

from . import (bench_dvfs, bench_heat, bench_interference, bench_kernels,
               bench_kmeans, bench_roofline, bench_sched_throughput,
               bench_sensitivity, bench_task_distribution)
from . import common

SUITES = {
    "fig4": bench_interference.run,
    "fig5_6": bench_task_distribution.run,
    "fig7": bench_dvfs.run,
    "fig8": bench_sensitivity.run,
    "fig9": bench_kmeans.run,
    "fig10": bench_heat.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
    "sched": bench_sched_throughput.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced task counts (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {','.join(unknown)}; "
                 f"available: {','.join(SUITES)}")
    print("name,value,derived")
    t0 = time.time()
    for name in names:
        t = time.time()
        common.begin_suite(name)
        SUITES[name](fast=args.fast)
        print(f"suite/{name}/elapsed_s,{time.time() - t:.1f},")
    print(f"suite/total_elapsed_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    main()
