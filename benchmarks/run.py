"""Benchmark orchestrator — one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig4,fig7,...]

Prints ``name,value,derived`` CSV rows; JSON artifacts land in
benchmarks/artifacts/.  The roofline section reads the dry-run artifacts
(produce them with ``python -m repro.launch.dryrun --all --mesh both``).
"""
from __future__ import annotations

import argparse
import time

from . import (bench_dvfs, bench_heat, bench_interference, bench_kernels,
               bench_kmeans, bench_roofline, bench_sensitivity,
               bench_task_distribution)

SUITES = {
    "fig4": bench_interference.run,
    "fig5_6": bench_task_distribution.run,
    "fig7": bench_dvfs.run,
    "fig8": bench_sensitivity.run,
    "fig9": bench_kmeans.run,
    "fig10": bench_heat.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced task counts (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    print("name,value,derived")
    t0 = time.time()
    for name in names:
        t = time.time()
        SUITES[name](fast=args.fast)
        print(f"suite/{name}/elapsed_s,{time.time() - t:.1f},")
    print(f"suite/total_elapsed_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    main()
