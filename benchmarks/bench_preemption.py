"""Preemptible pod-slice sweeps: revocation x schedulers x DAG mixes.

The last big scenario family from the ROADMAP: capacity that is *revoked
outright* (pod-slice preemption, maintenance events) instead of merely
slowed.  The swept machine is a mixed-generation TPU fleet
(``tpu_pod_slices`` with one current-gen pod + three v4-class pods at
roughly half its rates) — the statically asymmetric configuration where
criticality-aware schedulers have something to lose when the fast pod
disappears mid-run.

Grid: preemption setting x DAG (uniform matmul / heterogeneous
matmul+copy+stencil mix) x parallelism x scheduler x >= 3 seeds, with the
episode timescales *calibrated* against a preemption-free DAM-C baseline
makespan (M0) per (DAG, P) group, so the sweep stays meaningful if task
cost models change:

* ``off``       — no preemption (the reference cells);
* ``slow``      — independent per-pod renewal revocations
                  (mean up 0.8 M0, outage 0.2 M0), ``restart`` kills;
* ``slow_ckpt`` — same episodes as ``slow`` but ``checkpoint`` semantics
                  (progress survives, 10% resume penalty);
* ``fast``      — heavier revocation (mean up 0.35 M0, outage 0.15 M0),
                  ``restart`` kills;
* ``fast_ckpt`` — same episodes as ``fast`` but checkpointing;
* ``storm``     — MMPP-correlated revocations: a shared calm/storm chain
                  modulates every pod's revocation rate, so pods drop in
                  clusters (maintenance-wave signature).

The uniform-matmul DAG sweeps the renewal rates up through ``fast``; the
heterogeneous mix sweeps ``slow``/``slow_ckpt``/``storm`` — under
*sustained* heavy churn the mix's criticality advantage erodes (the
adaptive schedulers concentrate work on the fast pod, which is exactly
what keeps being revoked, while RWS's scattered placement barely
notices), a measured finding documented in benchmarks/README.md rather
than swept past.

Emitted aggregates are mean +/- population-std of *makespan* across seeds
per cell, plus headline ratios RWS / {DAM-C, FAM-C} per setting (> 1
means the criticality-aware scheduler wins).  The artifact lands as
``BENCH_preempt.json`` (repo root + benchmarks/artifacts) with the
calibrated episode parameters, per-cell preemption counters, and an
``acceptance`` block recording DAM-C/FAM-C vs RWS per preempted
(setting, DAG, P) group.
"""
from __future__ import annotations

import statistics

from repro.core import RunSpec, run_cells

from .common import emit, write_artifact

_MM = ("matmul", {"tile": 512})
_MIX_TYPES = (("matmul", {"tile": 512}), ("copy", {"tile": 512}),
              ("stencil", {"tile": 2048}))
# one current-gen pod + three previous-gen pods, 8 slices each (32 slices)
TOPOLOGY = ("tpu_pod_slices", {"pods": 4, "slices_per_pod": 8,
                               "kinds": ("pod", "pod_v4", "pod_v4",
                                         "pod_v4")})

SCHEDULERS = ("RWS", "RWSM-C", "FAM-C", "DAM-C")
# per-DAG preemption settings (see module docstring: sustained heavy churn
# erodes the mix's criticality margin, so the mix sweeps slow/storm rates)
SETTINGS = {
    "matmul": ("off", "slow", "fast", "fast_ckpt", "storm"),
    "mix": ("off", "slow", "slow_ckpt", "storm"),
}
DAGS = ("matmul", "mix")
PARALLELISM = (8, 16)
SEEDS = (1, 2, 3)            # >= 3 seeds in fast mode too (error bars)
FULL_TASKS, CI_TASKS = 4000, 800
BASELINE_SCHED = "DAM-C"     # calibration reference (preemption-free)


def _dag_spec(dag: str, parallelism: int, total: int) -> tuple:
    if dag == "matmul":
        return ("synthetic", {"task_type": _MM, "parallelism": parallelism,
                              "total_tasks": total})
    if dag == "mix":
        return ("mixed", {"task_types": _MIX_TYPES,
                          "parallelism": parallelism, "total_tasks": total})
    raise ValueError(f"unknown dag {dag!r}")


def _preemption_spec(setting: str, seed: int, m0: float) -> tuple | None:
    """RunSpec.preemption for one cell: episode timescales are fractions
    of the group's calibrated baseline makespan ``m0``."""
    t_end = 10.0 * m0            # preempted runs finish well inside this
    if setting == "off":
        return None
    if setting == "slow":
        return ("pod_slices", {"seed": seed, "t_end": t_end,
                               "mean_up": 0.8 * m0, "mean_down": 0.2 * m0})
    if setting == "slow_ckpt":
        return ("pod_slices", {"seed": seed, "t_end": t_end,
                               "mean_up": 0.8 * m0, "mean_down": 0.2 * m0,
                               "preempt": "checkpoint",
                               "resume_penalty": 0.1})
    if setting == "fast":
        return ("pod_slices", {"seed": seed, "t_end": t_end,
                               "mean_up": 0.35 * m0, "mean_down": 0.15 * m0})
    if setting == "fast_ckpt":
        return ("pod_slices", {"seed": seed, "t_end": t_end,
                               "mean_up": 0.35 * m0, "mean_down": 0.15 * m0,
                               "preempt": "checkpoint",
                               "resume_penalty": 0.1})
    if setting == "storm":
        return ("mmpp", {"seed": seed, "t_end": t_end,
                         "mean_calm": 1.5 * m0, "mean_storm": 0.4 * m0,
                         "mean_up_calm": 3.0 * m0,
                         "mean_up_storm": 0.12 * m0,
                         "mean_down": 0.12 * m0})
    raise ValueError(f"unknown setting {setting!r}")


def _calibrate(dags, par, total, workers) -> dict[tuple, float]:
    """Preemption-free DAM-C makespan per (dag, P) group: the timescale
    every preemption setting in that group is expressed against."""
    specs = [RunSpec(key=f"cal/{dag}/P{p}",
                     dag=_dag_spec(dag, p, total),
                     scheduler=BASELINE_SCHED, topology=TOPOLOGY, seed=1)
             for dag in dags for p in par]
    results = run_cells(specs, workers=workers)
    return {(dag, p): results[f"cal/{dag}/P{p}"]["makespan_s"]
            for dag in dags for p in par}


def grid(fast: bool = False, *, m0: dict[tuple, float]) -> list[RunSpec]:
    dags = DAGS if not fast else ("mix",)
    par = PARALLELISM if not fast else (8,)
    scheds = SCHEDULERS if not fast else ("RWS", "FAM-C", "DAM-C")
    total = FULL_TASKS if not fast else CI_TASKS
    specs = []
    for dag in dags:
        for setting in SETTINGS[dag]:
            for p in par:
                for sched_name in scheds:
                    for seed in SEEDS:
                        pre = _preemption_spec(setting, seed, m0[(dag, p)])
                        specs.append(RunSpec(
                            key=f"preempt/{setting}/{dag}/P{p}/"
                                f"{sched_name}/seed{seed}",
                            dag=_dag_spec(dag, p, total),
                            scheduler=sched_name,
                            topology=TOPOLOGY,
                            seed=seed,
                            preemption=pre,
                            collect=() if pre is None else ("preemption",)))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    dags = DAGS if not fast else ("mix",)
    par = PARALLELISM if not fast else (8,)
    total = FULL_TASKS if not fast else CI_TASKS
    m0 = _calibrate(dags, par, total, workers)
    out: dict = {f"calibration/{dag}/P{p}/makespan_s": m
                 for (dag, p), m in m0.items()}

    specs = grid(fast, m0=m0)
    results = run_cells(specs, workers=workers)
    groups: dict[str, list[float]] = {}
    for key, res in results.items():
        cell = key.rsplit("/seed", 1)[0]
        groups.setdefault(cell, []).append(res["makespan_s"])
        out[key] = {k: v for k, v in res.items() if not k.startswith("_")}
    for cell, spans in groups.items():
        mean = statistics.mean(spans)
        std = statistics.pstdev(spans)
        out[f"{cell}/mean_makespan_s"] = mean
        out[f"{cell}/std_makespan_s"] = std
        emit(f"{cell}/mean_makespan_s", f"{mean:.6g}",
             f"±{std:.2g} over {len(spans)} seeds")

    # headline + acceptance: criticality-aware vs RWS under revocation
    settings = sorted({c.split("/")[1] for c in groups})
    acceptance: dict[str, bool] = {}
    for setting in settings:
        for adaptive in ("DAM-C", "FAM-C"):
            ratios = []
            wins = []
            for cell, spans in groups.items():
                parts = cell.split("/")
                if parts[1] != setting or parts[-1] != adaptive:
                    continue
                base_cell = "/".join(parts[:-1]) + "/RWS"
                if base_cell not in groups:
                    continue
                rws = statistics.mean(groups[base_cell])
                own = statistics.mean(spans)
                ratios.append(rws / own)
                wins.append(own < rws)
            if not ratios:
                continue
            avg = sum(ratios) / len(ratios)
            emit(f"preempt/{setting}/RWS_vs_{adaptive}_makespan",
                 round(avg, 3), "x slower (>1: criticality-aware wins)")
            if setting != "off":
                acceptance[f"{setting}/{adaptive}_beats_RWS"] = all(wins)
    out["acceptance"] = acceptance
    # the repo-root mirror is the headline artifact (full sizes only, so a
    # bench-smoke run can't overwrite it with CI-size numbers)
    write_artifact("BENCH_preempt", out, root_copy=not fast)
    return out


if __name__ == "__main__":
    run()
