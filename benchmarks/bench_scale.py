"""Control-plane scaling: flat kernel vs sharded plane under modeled
scheduler overhead.

The DES charges ``ShardingSpec.decision_s`` per wake through a per-shard
single-server decision queue (``repro/core/simulator.py``).  The flat
kernel is then *one* saturating server — its simulated throughput is
capped at ``1/decision_s`` tasks/s no matter how many cores the fleet
has — while the sharded plane (``repro/core/shards.py``) runs one server
per shard plus the global rebalancer and wake-time overflow routing.
This harness sweeps pods x decision latency and shows the crossover: at
zero latency flat wins slightly (sharding fences work stealing), and as
latency grows the flat kernel saturates while the sharded plane keeps
scaling.

The fleet is mixed-generation (alternating ``pod`` / ``pod_v4``) with
chain co-runners parked on a few fast slices, so placement quality still
matters at scale: the acceptance block requires the sharded plane to
sustain >=2x the flat throughput at the largest pods x latency cell
*and* DAM-C to still beat RWS there — scaling the control plane must not
cost the paper's asymmetry-awareness win.

Emits ``name,value,derived`` CSV rows and a ``BENCH_scale.json``
artifact mirrored to the repo root; ``tools/check_acceptance.py`` gates
its acceptance block in ``make check``.
"""
from __future__ import annotations

import dataclasses

from repro.core import RunSpec, run_cell, run_cells

from .common import emit, write_artifact

_TT = ("matmul", {"tile": 4096})
SLICES_PER_POD = 4
PODS = (4, 8)
DECISIONS = (0.0, 2e-4, 1e-3)
SCHEDS = ("DAM-C", "RWS")


def _kinds(pods: int) -> tuple[str, ...]:
    return tuple("pod" if p % 2 == 0 else "pod_v4" for p in range(pods))


def _bg_cores(pods: int) -> tuple[int, ...]:
    # a chain co-runner on the first slice of two pods per 4-pod group:
    # enough dynamic asymmetry that blind placement (RWS) pays for it
    return tuple(SLICES_PER_POD * p for p in range(pods) if p % 4 in (0, 1))


def _sharding(pods: int, decision_s: float, *, sharded: bool):
    if sharded:
        return (("pods_per_shard", 2), ("decision_s", decision_s),
                ("rebalance_period_s", 2e-3),
                ("rebalance_decision_s", decision_s),
                ("migration_s", 2e-4), ("overflow_ratio", 2.0))
    if decision_s == 0.0:
        return None                 # the true flat kernel, no event layer
    # degenerate one-shard grouping: the flat kernel behind one modeled
    # decision server — what "the old control plane at this latency" costs
    return (("pods_per_shard", pods), ("decision_s", decision_s))


def _spec(key: str, pods: int, decision_s: float, sched: str, *,
          sharded: bool, total: int, seed: int = 5) -> RunSpec:
    return RunSpec(
        key=key,
        dag=("synthetic", {"task_type": _TT, "parallelism": 48,
                           "total_tasks": total}),
        scheduler=sched,
        topology=("tpu_pod_slices", {"pods": pods,
                                     "slices_per_pod": SLICES_PER_POD,
                                     "kinds": _kinds(pods)}),
        seed=seed,
        background=tuple(("chain", {"task_type": _TT, "core": c})
                         for c in _bg_cores(pods)),
        sharding=_sharding(pods, decision_s, sharded=sharded),
        collect=("migration",) if sharded else (),
    )


def run(fast: bool = False, workers: int | None = None) -> dict:
    out: dict = {}
    total = 6000 if not fast else 3000
    pods_sweep = PODS if not fast else PODS[-1:]    # acceptance cell stays
    decisions = DECISIONS if not fast else (0.0, DECISIONS[-1])
    specs = []
    for pods in pods_sweep:
        for d in decisions:
            for sched in SCHEDS:
                for mode in ("flat", "sharded"):
                    key = f"scale/p{pods}/d{d:g}/{mode}/{sched}"
                    specs.append(_spec(key, pods, d, sched,
                                       sharded=(mode == "sharded"),
                                       total=total))
    results = run_cells(specs, workers=workers)
    for key, res in results.items():
        out[key] = {"throughput_tps": round(res["throughput_tps"], 1),
                    "makespan_s": round(res["makespan_s"], 6)}
        if "migration" in res:
            out[key]["migration"] = res["migration"]
        emit(key, round(res["throughput_tps"], 1), "sim_tasks_per_sim_s")

    # equivalence pin: a one-shard zero-overhead sharded spec IS the flat
    # code path (make_control_plane degenerates) — bit-identical makespan
    p0 = pods_sweep[0]
    base = run_cell(_spec("eq/flat", p0, 0.0, "DAM-C", sharded=False,
                          total=total))
    one = dataclasses.replace(
        _spec("eq/one_shard", p0, 0.0, "DAM-C", sharded=False, total=total),
        sharding=(("pods_per_shard", p0),))
    oner = run_cell(one)
    eq = (base["makespan_s"] == oner["makespan_s"]
          and base["n_tasks"] == oner["n_tasks"])
    out["equivalence"] = {"flat_makespan_s": base["makespan_s"],
                          "one_shard_makespan_s": oner["makespan_s"]}

    # acceptance: at the largest pods x decision-latency cell the sharded
    # plane must sustain >=2x flat, DAM-C must still beat RWS there, and
    # the flat kernel must actually be saturating (else the sweep proves
    # nothing about control-plane scaling)
    pl, dl = pods_sweep[-1], decisions[-1]
    cell = lambda mode, sched: results[f"scale/p{pl}/d{dl:g}/{mode}/{sched}"]
    flat_dam = cell("flat", "DAM-C")["throughput_tps"]
    shard_dam = cell("sharded", "DAM-C")["throughput_tps"]
    shard_rws = cell("sharded", "RWS")["throughput_tps"]
    mig = cell("sharded", "DAM-C")["migration"]
    out["acceptance"] = {
        "equivalence/one_shard_spec_is_flat_bit_identical": eq,
        f"p{pl}/d{dl:g}/sharded_ge_2x_flat_DAM-C":
            shard_dam >= 2.0 * flat_dam,
        f"p{pl}/d{dl:g}/DAM-C_beats_RWS_sharded": shard_dam > shard_rws,
        f"p{pl}/d{dl:g}/flat_saturates_at_1_over_d":
            flat_dam <= 1.05 / dl,
        f"p{pl}/d{dl:g}/migration_active":
            mig["migrations"] + mig["overflow_migrations"] > 0,
    }
    for k, v in out["acceptance"].items():
        emit(f"scale/acceptance/{k}", v, "")
    write_artifact("BENCH_scale", out, root_copy=True)
    return out


if __name__ == "__main__":
    run()
