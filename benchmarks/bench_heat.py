"""Paper Fig. 10: distributed 2D Heat on a 4-node Haswell cluster (80
cores) with an interfering matmul kernel on 5 cores of node 0's socket 0.
Boundary-exchange (MPI) tasks are HIGH priority."""
from __future__ import annotations

from repro.core import (corun_socket, haswell_cluster, heat_dag,
                        make_scheduler, matmul_type, simulate)

from .common import emit, write_artifact

SCHEDULERS = ("RWS", "RWSM-C", "DA", "DAM-C", "DAM-P")


def run(fast: bool = False, workers: int | None = None) -> dict:
    out: dict = {}                 # workers: unused (5 serial runs)
    iters = 20 if fast else 60
    topo = haswell_cluster(4, 2, 10)
    for name in SCHEDULERS:
        sched = make_scheduler(name, topo, seed=1)
        dag = heat_dag(nodes=4, tiles_per_node=16, iterations=iters)
        m = simulate(dag, sched,
                     background=[corun_socket(matmul_type(96), range(0, 5))])
        out[name] = {"throughput_tps": m.throughput,
                     "makespan_s": m.makespan}
        emit(f"fig10/{name}/throughput", round(m.throughput, 1), "tasks_per_s")
    for a, b, paper in (("DAM-C", "RWS", "paper: +76%"),
                        ("DAM-C", "RWSM-C", "paper: +17%"),
                        ("DAM-C", "DA", "paper: moldability helps MPI")):
        r = out[a]["throughput_tps"] / out[b]["throughput_tps"]
        emit(f"fig10/{a}_vs_{b}", round(r, 2), paper)
    write_artifact("fig10_heat", out)
    return out


if __name__ == "__main__":
    run()
