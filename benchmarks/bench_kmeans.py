"""Paper Fig. 9: K-means (dynamic DAG) on the symmetric Haswell platform
with an interference window on socket 0 — per-iteration times + the
high-priority placement trace."""
from __future__ import annotations

import numpy as np

from repro.core import (corun_socket, haswell, kmeans_dag, make_scheduler,
                        matmul_type, simulate)

from .common import emit, write_artifact

SCHEDULERS = ("RWS", "RWSM-C", "DA", "DAM-C", "DAM-P")   # FA dropped: no
#                                      static asymmetry on Haswell (paper)


def run(fast: bool = False, workers: int | None = None) -> dict:
    out: dict = {}                 # workers: unused (5 serial runs)
    iters = 30 if fast else 70
    topo = haswell(2, 8)
    for name in SCHEDULERS:
        sched = make_scheduler(name, topo, seed=1)
        dag = kmeans_dag(n_points=2_000_000, dims=32, k=16, n_chunks=24,
                         iterations=iters)
        # co-runner starts after a training window (paper: "a few
        # iterations after the start") on 5 cores of socket 0
        m = simulate(dag, sched,
                     background=[corun_socket(matmul_type(96), range(0, 5),
                                              t_start=0.15, t_end=0.60)])
        red = [k for k in m.per_type_mean_duration()
               if k.startswith("kmeans_reduce")][0]
        its = m.iteration_times(red)
        out[name] = {
            "iteration_times_s": its,
            "makespan_s": m.makespan,
            "priority_placement": m.priority_placement(),
        }
        emit(f"fig9/{name}/iter_ms_mean", round(float(np.mean(its)) * 1e3, 2),
             f"p95={np.percentile(its, 95) * 1e3:.2f}ms")
        emit(f"fig9/{name}/makespan_s", round(m.makespan, 3), "")
    write_artifact("fig9_kmeans", out)
    return out


if __name__ == "__main__":
    run()
