"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import os
import time

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# set by ``begin_suite`` (the orchestrator) so artifacts can self-report how
# much wall time their suite burned — perf regressions of the harness itself
# then show up in the artifact trajectory, not just in stdout
_suite_name: str | None = None
_suite_t0: float = 0.0


def begin_suite(name: str) -> None:
    global _suite_name, _suite_t0
    _suite_name = name
    _suite_t0 = time.perf_counter()


def write_artifact(name: str, payload) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    if isinstance(payload, dict) and _suite_name is not None:
        payload = dict(payload)
        payload["_meta"] = {
            "suite": _suite_name,
            "suite_wall_s": round(time.perf_counter() - _suite_t0, 2),
        }
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
