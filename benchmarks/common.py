"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import os
import time

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def write_artifact(name: str, payload) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
