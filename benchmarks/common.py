"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import os
import tempfile
import time

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# set by ``begin_suite`` (the orchestrator) so artifacts can self-report how
# much wall time their suite burned — perf regressions of the harness itself
# then show up in the artifact trajectory, not just in stdout
_suite_name: str | None = None
_suite_t0: float = 0.0


def begin_suite(name: str) -> None:
    global _suite_name, _suite_t0
    _suite_name = name
    _suite_t0 = time.perf_counter()


def _atomic_write_json(path: str, payload) -> None:
    """Write-temp-then-rename so concurrent writers (parallel suite
    workers, a reader mid-``make bench``) never observe partial JSON."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=os.path.basename(path) + ".tmp.")
    try:
        os.fchmod(fd, 0o644)                   # mkstemp defaults to 0600
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        os.replace(tmp, path)                  # atomic on POSIX
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_artifact(name: str, payload, *, root_copy: bool = False) -> str:
    """Write ``artifacts/<name>.json`` atomically.  ``root_copy=True`` also
    mirrors it to ``<repo root>/<name>.json`` (the perf-trajectory tracker
    reads headline artifacts from the repo root, e.g. BENCH_sched.json)."""
    os.makedirs(ART_DIR, exist_ok=True)
    if isinstance(payload, dict) and _suite_name is not None:
        payload = dict(payload)
        payload["_meta"] = {
            "suite": _suite_name,
            "suite_wall_s": round(time.perf_counter() - _suite_t0, 2),
        }
    path = os.path.join(ART_DIR, name + ".json")
    _atomic_write_json(path, payload)
    if root_copy:
        _atomic_write_json(os.path.join(REPO_ROOT, name + ".json"), payload)
    return path


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")
