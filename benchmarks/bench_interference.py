"""Paper Fig. 4: throughput of all 7 schedulers under a co-running
application, DAG parallelism 2..6, for the matmul/copy/stencil DAGs.

Paper-faithful sizes: matmul 32000 tasks (tile 64), copy 10000 (tile 1024),
stencil 20000 (tile 1024); co-runner = single chain of the same kernel
pinned to core 0 (CPU interference for matmul/stencil, memory interference
for copy), persisting for the whole run.
"""
from __future__ import annotations

from repro.core import (ALL_SCHEDULERS, copy_type, corun_chain,
                        make_scheduler, matmul_type, simulate, stencil_type,
                        synthetic_dag, tx2)

from .common import emit, write_artifact

KERNELS = {
    "matmul": (matmul_type(64), 16000),   # paper: 32000 (halved: same dynamics, 2x faster CI)
    "copy": (copy_type(1024), 6000),      # paper: 10000
    "stencil": (stencil_type(1024), 10000),  # paper: 20000
}
PARALLELISM = (2, 3, 4, 5, 6)


def run(fast: bool = False) -> dict:
    out: dict = {}
    kernels = KERNELS if not fast else {
        k: (t, n // 8) for k, (t, n) in KERNELS.items()}
    par = PARALLELISM if not fast else (2, 4, 6)
    for kname, (tt, total) in kernels.items():
        for p in par:
            for sched_name in ALL_SCHEDULERS:
                sched = make_scheduler(sched_name, tx2(), seed=1)
                dag = synthetic_dag(tt, parallelism=p, total_tasks=total)
                m = simulate(dag, sched,
                             background=[corun_chain(tt, core=0)])
                key = f"fig4/{kname}/P{p}/{sched_name}"
                out[key] = {"throughput_tps": m.throughput,
                            "makespan_s": m.makespan}
                emit(key, round(m.throughput, 1), "tasks_per_s")
    # paper headline ratios at the most contended point
    for kname in kernels:
        base = out[f"fig4/{kname}/P2/RWS"]["throughput_tps"]
        fa = out[f"fig4/{kname}/P2/FA"]["throughput_tps"]
        dam = out[f"fig4/{kname}/P2/DAM-C"]["throughput_tps"]
        emit(f"fig4/{kname}/P2/DAM-C_vs_RWS", round(dam / base, 2),
             "paper: up to 3.5x (matmul)")
        emit(f"fig4/{kname}/P2/DAM-C_vs_FA", round(dam / fa, 2),
             "paper: up to 1.9x (matmul)")
    write_artifact("fig4_interference", out)
    return out


if __name__ == "__main__":
    run()
