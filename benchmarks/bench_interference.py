"""Paper Fig. 4: throughput of all 7 schedulers under a co-running
application, DAG parallelism 2..6, for the matmul/copy/stencil DAGs.

Paper-faithful sizes (the default): matmul 32000 tasks (tile 64), copy
10000 (tile 1024), stencil 20000 (tile 1024); co-runner = single chain of
the same kernel pinned to core 0 (CPU interference for matmul/stencil,
memory interference for copy), persisting for the whole run.  ``--fast``
keeps the old CI sizes (2000/750/1250).

The (kernel x parallelism x scheduler) grid — 105 independent seeded runs
at full size — is fanned across host cores by the multi-run engine;
per-cell results are bit-identical for any worker count.
"""
from __future__ import annotations

from repro.core import ALL_SCHEDULERS, RunSpec, run_cells

from .common import emit, write_artifact

# kernel -> (task-type spec, paper-full tasks, CI-fast tasks)
KERNELS = {
    "matmul": (("matmul", {"tile": 64}), 32000, 2000),
    "copy": (("copy", {"tile": 1024}), 10000, 750),
    "stencil": (("stencil", {"tile": 1024}), 20000, 1250),
}
PARALLELISM = (2, 3, 4, 5, 6)


def grid(fast: bool = False) -> list[RunSpec]:
    par = PARALLELISM if not fast else (2, 4, 6)
    specs = []
    for kname, (tt, full, ci) in KERNELS.items():
        total = ci if fast else full
        for p in par:
            for sched_name in ALL_SCHEDULERS:
                specs.append(RunSpec(
                    key=f"fig4/{kname}/P{p}/{sched_name}",
                    dag=("synthetic", {"task_type": tt, "parallelism": p,
                                       "total_tasks": total}),
                    scheduler=sched_name,
                    topology=("tx2", {}),
                    seed=1,
                    background=(("chain", {"task_type": tt, "core": 0}),),
                ))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    specs = grid(fast)
    results = run_cells(specs, workers=workers)
    out: dict = {}
    for key, res in results.items():
        out[key] = {"throughput_tps": res["throughput_tps"],
                    "makespan_s": res["makespan_s"]}
        emit(key, round(res["throughput_tps"], 1), "tasks_per_s")
    # paper headline ratios at the most contended point
    for kname in KERNELS:
        base = out[f"fig4/{kname}/P2/RWS"]["throughput_tps"]
        fa = out[f"fig4/{kname}/P2/FA"]["throughput_tps"]
        dam = out[f"fig4/{kname}/P2/DAM-C"]["throughput_tps"]
        emit(f"fig4/{kname}/P2/DAM-C_vs_RWS", round(dam / base, 2),
             "paper: up to 3.5x (matmul)")
        emit(f"fig4/{kname}/P2/DAM-C_vs_FA", round(dam / fa, 2),
             "paper: up to 1.9x (matmul)")
    write_artifact("fig4_interference", out)
    return out


if __name__ == "__main__":
    run()
