"""Fault-injection sweeps: fault regime x recovery policy x schedulers.

The robustness scenario family: tasks themselves fail (fail-stop: the
attempt dies partway through; fail-slow: the place silently degrades
mid-execution) instead of capacity being revoked or merely interfered
with.  The swept machine is the same mixed-generation TPU fleet as the
preemption suite (``tpu_pod_slices``, one current-gen pod + three
v4-class pods) — heterogeneity is what gives PTT-based straggler hedging
an alternative place worth duplicating onto.

Grid: fault setting x recovery mode x scheduler x >= 3 seeds over the
heterogeneous matmul+copy+stencil mix, with backoff timescales
*calibrated* against a fault-free DAM-C baseline makespan (M0) per
parallelism group (DES makespans are tiny virtual seconds; absolute
backoff constants would dwarf or vanish against them):

* ``clean``    — no faults (reference cells, and the zero-overhead check:
                 hedging enabled on a clean run must cost nothing);
* ``failstop`` — independent per-attempt fail-stop (p=0.15, budget 2
                 failures/task), retries with exponential backoff;
* ``failslow`` — independent fail-slow (p=0.25, 6x degradation): the
                 attempt *survives* but crawls, the regime straggler
                 hedging exists for;
* ``storm``    — MMPP-correlated bursts of both fault kinds (a shared
                 calm/storm chain multiplies the rates 8x during storms).

Recovery modes: ``retry`` (attempt budgets + seeded exponential backoff +
PTT penalty on the failing place) and ``retry_hedge`` (same, plus
criticality-aware speculative duplicates for flagged HIGH stragglers).

Emitted aggregates are mean +/- population-std makespan across seeds per
cell, p99 task sojourn, and fault/recovery counters.  Headline +
acceptance ratios under ``failslow``: hedged DAM-C vs retry-only DAM-C
(hedging must pay for itself where it targets) and vs retry-only RWS
(>= 1.2x, the criticality + hedging combined margin).  The artifact
lands as ``BENCH_faults.json`` (repo root mirror on full runs only).
"""
from __future__ import annotations

import statistics

from repro.core import RunSpec, run_cells

from .common import emit, write_artifact

_MIX_TYPES = (("matmul", {"tile": 512}), ("copy", {"tile": 512}),
              ("stencil", {"tile": 2048}))
# one current-gen pod + three previous-gen pods, 8 slices each (32 slices)
TOPOLOGY = ("tpu_pod_slices", {"pods": 4, "slices_per_pod": 8,
                               "kinds": ("pod", "pod_v4", "pod_v4",
                                         "pod_v4")})

SCHEDULERS = ("RWS", "FAM-C", "DAM-C")
SETTINGS = ("clean", "failstop", "failslow", "storm")
RECOVERY_MODES = ("retry", "retry_hedge")
PARALLELISM = (8, 16)
SEEDS = (1, 2, 3)            # >= 3 seeds in fast mode too (error bars)
FULL_TASKS, CI_TASKS = 4000, 800
BASELINE_SCHED = "DAM-C"     # calibration reference (fault-free)


def _dag_spec(parallelism: int, total: int) -> tuple:
    return ("mixed", {"task_types": _MIX_TYPES, "parallelism": parallelism,
                      "total_tasks": total})


def _fault_spec(setting: str, seed: int, m0: float) -> tuple | None:
    """RunSpec.faults for one cell; MMPP timescales are fractions of the
    group's calibrated fault-free makespan ``m0``."""
    if setting == "clean":
        return None
    if setting == "failstop":
        return ("independent", {"seed": seed, "p_fail": 0.15})
    if setting == "failslow":
        return ("independent", {"seed": seed, "p_slow": 0.25,
                                "slow_factor": 6.0})
    if setting == "storm":
        return ("mmpp", {"seed": seed, "t_end": 10.0 * m0,
                         "mean_calm": 1.5 * m0, "mean_storm": 0.4 * m0,
                         "storm_mult": 8.0, "p_fail": 0.04, "p_slow": 0.06,
                         "slow_factor": 6.0})
    raise ValueError(f"unknown setting {setting!r}")


def _recovery_spec(mode: str, m0: float) -> dict:
    """RunSpec.recovery kwargs: backoffs as fractions of ``m0`` so the
    retry penalty is commensurate with the run it interrupts."""
    return {"backoff_base": 0.01 * m0, "backoff_cap": 0.1 * m0,
            "hedge": mode == "retry_hedge"}


def _calibrate(par, total, workers) -> dict[int, float]:
    """Fault-free DAM-C makespan per parallelism group: the timescale the
    fault/backoff parameters of that group are expressed against."""
    specs = [RunSpec(key=f"cal/P{p}", dag=_dag_spec(p, total),
                     scheduler=BASELINE_SCHED, topology=TOPOLOGY, seed=1)
             for p in par]
    results = run_cells(specs, workers=workers)
    return {p: results[f"cal/P{p}"]["makespan_s"] for p in par}


def grid(fast: bool = False, *, m0: dict[int, float]) -> list[RunSpec]:
    par = PARALLELISM if not fast else (8,)
    scheds = SCHEDULERS if not fast else ("RWS", "DAM-C")
    settings = SETTINGS if not fast else ("clean", "failslow", "storm")
    total = FULL_TASKS if not fast else CI_TASKS
    specs = []
    for setting in settings:
        for mode in RECOVERY_MODES:
            for p in par:
                for sched_name in scheds:
                    for seed in SEEDS:
                        faults = _fault_spec(setting, seed, m0[p])
                        specs.append(RunSpec(
                            key=f"faults/{setting}/{mode}/P{p}/"
                                f"{sched_name}/seed{seed}",
                            dag=_dag_spec(p, total),
                            scheduler=sched_name,
                            topology=TOPOLOGY,
                            seed=seed,
                            faults=faults,
                            recovery=_recovery_spec(mode, m0[p]),
                            collect=("faults", "task_sojourn")))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    par = PARALLELISM if not fast else (8,)
    total = FULL_TASKS if not fast else CI_TASKS
    m0 = _calibrate(par, total, workers)
    out: dict = {f"calibration/P{p}/makespan_s": m for p, m in m0.items()}

    specs = grid(fast, m0=m0)
    results = run_cells(specs, workers=workers)
    groups: dict[str, list[float]] = {}
    p99s: dict[str, list[float]] = {}
    for key, res in results.items():
        cell = key.rsplit("/seed", 1)[0]
        groups.setdefault(cell, []).append(res["makespan_s"])
        soj = res.get("task_sojourn") or {}
        if "p99_s" in soj:
            p99s.setdefault(cell, []).append(soj["p99_s"])
        out[key] = {k: v for k, v in res.items() if not k.startswith("_")}
    for cell, spans in groups.items():
        mean = statistics.mean(spans)
        std = statistics.pstdev(spans)
        out[f"{cell}/mean_makespan_s"] = mean
        out[f"{cell}/std_makespan_s"] = std
        if cell in p99s:
            out[f"{cell}/mean_p99_sojourn_s"] = statistics.mean(p99s[cell])
        emit(f"{cell}/mean_makespan_s", f"{mean:.6g}",
             f"±{std:.2g} over {len(spans)} seeds")

    def _mean(cell: str) -> float | None:
        return statistics.mean(groups[cell]) if cell in groups else None

    # headline + acceptance ratios, per parallelism group
    settings = sorted({c.split("/")[1] for c in groups})
    acceptance: dict[str, bool] = {}
    for setting in settings:
        if setting == "clean":
            # zero-overhead check: hedging armed on a clean run must not
            # change the makespan (no straggler ever flags, so no
            # duplicate is ever launched)
            for p in par:
                a = _mean(f"faults/clean/retry/P{p}/DAM-C")
                b = _mean(f"faults/clean/retry_hedge/P{p}/DAM-C")
                if a is not None and b is not None:
                    acceptance[f"clean/P{p}/hedge_is_free"] = (
                        abs(a - b) <= 1e-12 * max(a, b, 1.0))
            continue
        for p in par:
            hedged = _mean(f"faults/{setting}/retry_hedge/P{p}/DAM-C")
            retry = _mean(f"faults/{setting}/retry/P{p}/DAM-C")
            rws = _mean(f"faults/{setting}/retry/P{p}/RWS")
            if hedged is None or rws is None:
                continue
            r_rws = rws / hedged
            emit(f"faults/{setting}/P{p}/RWS_retry_vs_DAM-C_hedge",
                 round(r_rws, 3), "x slower (>1: hedged DAM-C wins)")
            if retry is not None:
                emit(f"faults/{setting}/P{p}/DAM-C_retry_vs_hedge",
                     round(retry / hedged, 3), "x slower (>1: hedging pays)")
            if setting == "failslow":
                acceptance[f"failslow/P{p}/hedged_DAM-C_1.2x_RWS"] = (
                    r_rws >= 1.2)
                if retry is not None:
                    acceptance[f"failslow/P{p}/hedge_beats_retry_only"] = (
                        hedged < retry)
    out["acceptance"] = acceptance
    # the repo-root mirror is the headline artifact (full sizes only, so a
    # bench-smoke run can't overwrite it with CI-size numbers)
    write_artifact("BENCH_faults", out, root_copy=not fast)
    return out


if __name__ == "__main__":
    run()
