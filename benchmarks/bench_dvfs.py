"""Paper Fig. 7: DVFS interference — the Denver cluster alternates between
2035 MHz and 345 MHz with a 10 s period (5 s + 5 s).

Paper-faithful sizes by default (matmul 32000 / copy 10000 / stencil
20000); ``--fast`` keeps the old CI sizes.  The grid runs through the
multi-run engine (see bench_interference.py).  ``dvfs_denver`` is a
closed-form ``PeriodicProfile``: per-cell construction no longer
materializes ~200k square-wave segments (which used to cost ~0.2 s per
cell), and results are bit-identical to the materialized form.
"""
from __future__ import annotations

from repro.core import ALL_SCHEDULERS, RunSpec, run_cells

from .common import emit, write_artifact

KERNELS = {
    "matmul": (("matmul", {"tile": 64}), 32000, 2000),
    "copy": (("copy", {"tile": 1024}), 10000, 750),
    "stencil": (("stencil", {"tile": 1024}), 20000, 1250),
}


def _parallelism(fast: bool) -> tuple[int, ...]:
    return (2, 3, 4, 5, 6) if not fast else (2, 6)


def grid(fast: bool = False) -> list[RunSpec]:
    par = _parallelism(fast)
    specs = []
    for kname, (tt, full, ci) in KERNELS.items():
        total = ci if fast else full
        for p in par:
            for name in ALL_SCHEDULERS:
                specs.append(RunSpec(
                    key=f"fig7/{kname}/P{p}/{name}",
                    dag=("synthetic", {"task_type": tt, "parallelism": p,
                                       "total_tasks": total}),
                    scheduler=name,
                    topology=("tx2", {}),
                    seed=1,
                    speed=("dvfs_denver", {}),
                ))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    par = _parallelism(fast)
    results = run_cells(grid(fast), workers=workers)
    out = {key: res["throughput_tps"] for key, res in results.items()}
    for key, v in out.items():
        emit(key, round(v, 1), "tasks_per_s")
    # paper: for copy, DAM-C ~2.2x RWS / 1.9x RWSM-C average across P
    for kname in KERNELS:
        ratios = [out[f"fig7/{kname}/P{p}/DAM-P"] /
                  out[f"fig7/{kname}/P{p}/RWS"] for p in par]
        emit(f"fig7/{kname}/DAM-P_vs_RWS_avg",
             round(sum(ratios) / len(ratios), 2),
             "paper(copy): ~2.2x")
    write_artifact("fig7_dvfs", out)
    return out


if __name__ == "__main__":
    run()
