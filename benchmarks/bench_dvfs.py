"""Paper Fig. 7: DVFS interference — the Denver cluster alternates between
2035 MHz and 345 MHz with a 10 s period (5 s + 5 s)."""
from __future__ import annotations

from repro.core import (ALL_SCHEDULERS, copy_type, dvfs_denver,
                        make_scheduler, matmul_type, simulate, stencil_type,
                        synthetic_dag, tx2)

from .common import emit, write_artifact

KERNELS = {
    "matmul": (matmul_type(64), 16000),   # paper: 32000 (halved: same dynamics, 2x faster CI)
    "copy": (copy_type(1024), 6000),      # paper: 10000
    "stencil": (stencil_type(1024), 10000),  # paper: 20000
}


def run(fast: bool = False) -> dict:
    out: dict = {}
    kernels = KERNELS if not fast else {
        k: (t, n // 8) for k, (t, n) in KERNELS.items()}
    par = (2, 3, 4, 5, 6) if not fast else (2, 6)
    for kname, (tt, total) in kernels.items():
        for p in par:
            for name in ALL_SCHEDULERS:
                sched = make_scheduler(name, tx2(), seed=1)
                dag = synthetic_dag(tt, parallelism=p, total_tasks=total)
                m = simulate(dag, sched, speed=dvfs_denver())
                out[f"fig7/{kname}/P{p}/{name}"] = m.throughput
                emit(f"fig7/{kname}/P{p}/{name}", round(m.throughput, 1),
                     "tasks_per_s")
    # paper: for copy, DAM-C ~2.2x RWS / 1.9x RWSM-C average across P
    for kname in kernels:
        ratios = []
        for p in par:
            ratios.append(out[f"fig7/{kname}/P{p}/DAM-P"] /
                          out[f"fig7/{kname}/P{p}/RWS"])
        emit(f"fig7/{kname}/DAM-P_vs_RWS_avg",
             round(sum(ratios) / len(ratios), 2),
             "paper(copy): ~2.2x")
    write_artifact("fig7_dvfs", out)
    return out


if __name__ == "__main__":
    run()
