"""Serving-path benchmark on the *threaded* engine: schedulers x injected
interference, open-loop arrival, p50/p99 TTFT.

Everything else in ``make bench`` measures the discrete-event simulator;
this suite exercises the unified scheduling kernel on the **real threaded
runtime** (DESIGN.md §3) under the serving workload shape (DESIGN.md §2):
each request is a HIGH-priority prefill task releasing a LOW-priority
decode chain, submitted *open loop* (seeded Poisson inter-arrival via
``ThreadedRuntime.start()``/``drain()``), so queueing delay lands in the
TTFT tail instead of being hidden by batch submission.  Payloads are
calibrated sleeps standing in for the jitted model dispatches the
``repro.serve`` engine issues — interference is injected through the
runtime's ``slowdown`` map and wall-clock pod revocation
(``PreemptionModel`` episodes fired by the runtime's timer thread), which
exercise the identical scheduler-visible code paths.

Fleet: 2 pods x 4 slices, mixed generation — pod0 is current-gen (the
statically fastest, what FA/FAM-C bind to) and pod1 is v4-class, modeled
as a 2x baseline slowdown on its slices in *every* scenario (the threaded
runtime has no cost models, so static asymmetry must be expressed in
execution).  Scenarios add dynamic interference on top:

* ``clean``        — static asymmetry only (sanity reference);
* ``slow_fast_pod``— the statically fast pod0 slowed 8x (co-tenant burst):
                     static binding is now wrong, the PTT must override it;
* ``slow_spread``  — slowdown across both pods (8x/8x on two pod0 slices,
                     6x on two pod1 slices): only a PTT-guided scheduler
                     still finds the quiet slices;
* ``revoke_fast``  — pod0 revoked twice mid-run (wall-clock pod-slice
                     preemption): prefills must re-place on the survivor.

All PTT-guided cells run load-aware (``queue_penalty=1.0``) with a
warm-started table (``SchedulingKernel.prime_ptt``), so simultaneous
HIGH prefills spread across quiet places instead of herding onto the
single momentarily-best one.  A second section sweeps the arrival rate
*past fleet saturation* on the synthetic-payload ``ServingEngine``
(brownout ladder + bounded admission): goodput, p99 TTFT and the
shed/reject breakdown per rate — see ``benchmarks/README.md``.

Emits per-cell p50/p99 TTFT + makespan and an ``acceptance`` block
recording, per interference scenario, whether a criticality-aware
scheduler (DAM-C / FAM-C) beats RWS on p99 TTFT, plus the overload
criteria (goodput plateaus; ladder rungs monotone in offered rate).
Artifact: ``BENCH_serve.json`` (repo root + benchmarks/artifacts).
"""
from __future__ import annotations

import random
import time

import numpy as np

from repro.core import (BatchingConfig, PreemptionModel, Priority,
                        RequestRecord, ResourcePartition, Task, TaskType,
                        ThreadedRuntime, Topology, make_scheduler)
from repro.core.dag import DAG
from repro.core.metrics import percentile
from repro.serve import BrownoutConfig, ServingEngine

from .common import emit, write_artifact

SCHEDULERS = ("RWS", "FAM-C", "DAM-C", "DAM-P")
FAST_SCHEDULERS = ("RWS", "FAM-C", "DAM-C")
PREFILL_S = 8e-3           # sleep standing in for the prefill dispatch
DECODE_S = 2e-3            # per decode step
DECODE_STEPS = 4
RATE_RPS = 30.0            # open-loop arrival rate (util low enough that
                           # steady-state prefills don't queue behind
                           # each other — see DESIGN.md §2)
N_REQ, N_REQ_FAST = 84, 36
# the PTT-guided cells prime the table before taking traffic
# (``SchedulingKernel.prime_ptt`` — the engine's warm start), so the old
# 28-request cold-table exclusion window is gone.  What remains excluded
# is the *interference-learning* transient: a primed prior says nothing
# about a scenario's 8x co-tenant slowdown, so the first exploration
# round (8 slices) still sends ~one prefill to each slow slice, plus one
# overlap round where a slow slice's prior survives because its first
# observation has not committed yet.  ~2 rounds = 16 requests, down from
# the cold-table 28.
N_WARMUP, N_WARMUP_FAST = 16, 16
QUEUE_PENALTY = 1.0        # load-aware placement: score = ptt + 1.0*backlog
                           # (seconds against seconds), so simultaneous
                           # HIGH prefills spread instead of herding onto
                           # the single momentarily-best place
POD0 = (0, 1, 2, 3)        # slices of the statically fast pod
V4_FACTOR = 2.0            # pod1 baseline: previous-gen slices run 2x slower

SCENARIOS: dict[str, dict] = {
    "clean": {},
    "slow_fast_pod": {"slowdown": {c: 8.0 for c in POD0}},
    "slow_spread": {"slowdown": {0: 8.0, 1: 8.0, 4: 6.0, 5: 6.0}},
    # pod0 loses its slices twice while requests are in flight; episode
    # times are fractions of the ~N_REQ/RATE_RPS arrival window
    "revoke_fast": {"revoke": ((0, 0.15, 0.35), (0, 0.55, 0.75))},
}
INTERFERENCE = ("slow_fast_pod", "slow_spread", "revoke_fast")

# -- overload sweep: arrival-rate ramp past fleet saturation ------------------
# Decode-heavy synthetic requests (prefill + 15 decode steps, 4 ms each
# ~= 64 ms of fleet work per request) put *unbatched* nominal capacity at
# ~94 rps: 4 full-speed slices + 4 half-speed v4 slices deliver 6
# core-seconds of work per wall second / 0.064 s per request — the
# realistic serving regime where decode dominates and one dispatch per
# token is the bottleneck.  With continuous batching (max_batch=16,
# member_cost=0.02 — batched decode is memory-bound) the decode chain
# costs ~4.9 ms at full fill and capacity rises to ~670 rps.  Each axis's
# rate grid brackets its own knee: unbatched 30/60 under, 120/480 past;
# batched 30..480 under, 960 past.  The acceptance block gates the
# batched knee at >= 5x the unbatched knee with p99 TTFT unchanged.
OVER_PREFILL_S = 4e-3
OVER_DECODE_S = 4e-3
OVER_STEPS = 15                     # request = prefill + 15 decode steps
OVER_BATCHING = BatchingConfig(max_batch=16, delay_s=2e-3, member_cost=0.02)
OVER_RATES = (30.0, 60.0, 120.0, 480.0)
OVER_RATES_BATCHED = (30.0, 60.0, 120.0, 480.0, 960.0)
OVER_RATES_FAST = (60.0, 120.0)
OVER_RATES_BATCHED_FAST = (60.0, 480.0)
# per-cell request count scales with the rate so every cell offers the
# same arrival window — a fixed count would let the drain tail dominate
# the makespan at high rates and depress goodput for bookkeeping reasons
OVER_WINDOW_S, OVER_WINDOW_S_FAST = 4.0, 1.5
OVER_MAX_PENDING = 96               # backpressure bound on in-flight requests
# ladder thresholds in backlog-seconds-per-live-core, sized to this sweep:
# just past saturation should shrink LOW output length (rung 1-2); far
# past, with the pending queue full (~96 x ~64 ms over 8 slices), the
# signal reaches ~0.7 and climbs to admission rejection (rung 3)
OVER_BROWNOUT = BrownoutConfig(enter=(0.06, 0.15, 0.22),
                               exit=(0.03, 0.08, 0.12), min_tokens=1)
# a rate is *sustainable* when the cell ran degradation-free (ladder at
# rung 0, nothing shed or refused) and goodput kept up with the offer;
# the knee is the highest sustainable rate in the axis's grid.  The
# goodput bar is a sanity floor, not the discriminator — the rung /
# shed / reject conditions catch unsustainable cells, while goodput as
# measured over the *makespan* (arrival window + drain tail) sits ~18%
# under the offered rate at high rates for bookkeeping reasons alone
KNEE_GOODPUT_FRAC = 0.75


def _run_overload(rate_rps: float, n_req: int, *,
                  batching: BatchingConfig | None = None,
                  seed: int = 0) -> dict:
    """One overload-sweep cell: the synthetic-payload ServingEngine (same
    request DAG shape, brownout ladder + backpressure attached) driven
    open-loop at ``rate_rps`` on the 2-pod fleet, with or without
    continuous batching on the decode path."""
    topo = _fleet()
    slowdown = {c: V4_FACTOR for c in range(4, 8)}
    eng = ServingEngine(None, topo, scheduler="DAM-C", seed=seed,
                        slowdown=slowdown, queue_penalty=QUEUE_PENALTY,
                        max_pending=OVER_MAX_PENDING, brownout=OVER_BROWNOUT,
                        batching=batching,
                        prefill_s=OVER_PREFILL_S, decode_s=OVER_DECODE_S)
    prompts = [np.zeros(16, np.int32)] * n_req
    m = eng.run_open_loop(prompts, rate_rps=rate_rps,
                          max_new_tokens=1 + OVER_STEPS,
                          arrival_seed=seed, timeout=120.0)
    s = eng.latency_stats()
    good = s["completed"] - s["shed"]   # finished full-length (possibly
                                        # token-clamped), not truncated
    cell = {
        "rate_rps": rate_rps,
        "n_req": n_req,
        "batched": batching is not None,
        "goodput_rps": round(good / m.makespan, 3) if m.makespan else None,
        "completed": s["completed"],
        "rejected_backpressure": s["rejected_backpressure"],
        "rejected_deadline": s["rejected_deadline"],
        "shed_brownout": s["shed_brownout"],
        "shed_deadline": s["shed_deadline"],
        "tokens_clamped": s["tokens_clamped"],
        "brownout_max_rung": s.get("brownout_max_rung", 0),
        "brownout_transitions": s.get("brownout_transitions", 0),
        "ttft_ms_p99": s.get("ttft_ms_p99"),
        "makespan_s": round(m.makespan, 4),
    }
    if eng.batcher is not None:
        cell["batches_formed"] = eng.batcher.batches_formed
        cell["members_dispatched"] = eng.batcher.members_dispatched
        cell["mean_batch_fill"] = round(
            eng.batcher.members_dispatched
            / max(eng.batcher.batches_formed, 1), 3)
    return cell


def _sustainable(cell: dict) -> bool:
    return (cell["brownout_max_rung"] == 0
            and cell["rejected_backpressure"] == 0
            and cell["shed_brownout"] == 0 and cell["shed_deadline"] == 0
            and cell["goodput_rps"] is not None
            and cell["goodput_rps"] >= KNEE_GOODPUT_FRAC * cell["rate_rps"])


def _knee(cells: list[dict]) -> float | None:
    """Highest sustainable rate in the sweep (None if nothing held)."""
    ok = [c["rate_rps"] for c in cells if _sustainable(c)]
    return max(ok) if ok else None


def _fleet():
    """2 pods x 4 slices, width-1 places only: each dispatch occupies one
    slice.  Molded (multi-slice) assemblies are deliberately not exposed
    here — a wide place spanning an interfered slice stalls its clean
    members in the assembly barrier, and this suite measures placement
    under interference, not molding (the fig4/fig7 DES sweeps and the
    real-model serve engine keep the full width sets)."""
    return Topology([
        ResourcePartition("pod0", "pod", 0, 4, (1,), static_rank=0),
        ResourcePartition("pod1", "pod_v4", 4, 4, (1,), static_rank=1),
    ])


def _cell_config(scenario: str, window_s: float):
    """(slowdown map, preemption model) for one cell: the v4 pod's 2x
    baseline everywhere, scenario slowdowns on top, revocation episodes
    scaled to the arrival window."""
    cfg = SCENARIOS[scenario]
    slowdown = {c: V4_FACTOR for c in range(4, 8)}
    slowdown.update(cfg.get("slowdown", ()))
    pre = None
    if "revoke" in cfg:
        pre = PreemptionModel(tuple(
            (pidx, t0 * window_s, t1 * window_s)
            for pidx, t0, t1 in cfg["revoke"]))
    return slowdown, pre


class _Request:
    __slots__ = ("rid", "t_submit", "t_first", "t_done")

    def __init__(self, rid):
        self.rid = rid
        self.t_submit = time.perf_counter()
        self.t_first = 0.0
        self.t_done = 0.0


def _request_dag(req: _Request, pre_type: TaskType,
                 dec_type: TaskType) -> DAG:
    """The serve engine's request shape (DESIGN.md §2): one HIGH prefill
    releasing a chain of LOW decode steps, with sleep payloads."""

    def prefill_payload(width, _req=req):
        time.sleep(PREFILL_S)

    def make_decode(step: int) -> Task:
        t = Task(dec_type, priority=Priority.LOW,
                 payload=lambda width: time.sleep(DECODE_S))

        def on_commit(_task, _step=step, _req=req):
            if _step + 1 < DECODE_STEPS:
                return [make_decode(_step + 1)]
            _req.t_done = time.perf_counter()
            return []

        t.on_commit = on_commit
        return t

    pre = Task(pre_type, priority=Priority.HIGH, payload=prefill_payload)

    def pre_commit(_task, _req=req):
        # first token is out when the prefill *commits* — after any
        # injected slowdown, exactly when a real client would see it
        _req.t_first = time.perf_counter()
        return [make_decode(0)]

    pre.on_commit = pre_commit
    return DAG([pre], 1 + DECODE_STEPS)


def _run_seed(sched_name: str, scenario: str, *, n_req: int, n_warmup: int,
              seed: int) -> tuple[dict, list[RequestRecord]]:
    topo = _fleet()
    slowdown, pre = _cell_config(scenario,
                                 window_s=(n_req + n_warmup) / RATE_RPS)
    sched = make_scheduler(sched_name, topo, seed=seed,
                           queue_penalty=QUEUE_PENALTY, track_load=True)
    rt = ThreadedRuntime(sched, slowdown=slowdown, preemption=pre)
    kinds = {p.kind for p in topo.partitions}
    pre_type = TaskType("serve_prefill", {k: PREFILL_S for k in kinds})
    dec_type = TaskType("serve_decode", {k: DECODE_S for k in kinds})
    # warm start: seed every (type, place) PTT entry with its cost-model
    # prior so no cell pays the unexplored-entry herding transient
    rt.kernel.prime_ptt(pre_type)
    rt.kernel.prime_ptt(dec_type)
    arrivals = random.Random(f"serve-arrival:{seed}")
    requests = [_Request(i) for i in range(n_warmup + n_req)]
    rt.start()
    for i, req in enumerate(requests):
        if i:
            time.sleep(arrivals.expovariate(RATE_RPS))
        req.t_submit = time.perf_counter()
        rt.submit(_request_dag(req, pre_type, dec_type))
    m = rt.drain(timeout=60.0)
    measured = [RequestRecord(rid=req.rid, t_submit=req.t_submit,
                              t_first_token=req.t_first, t_done=req.t_done)
                for req in requests[n_warmup:] if req.t_done > 0]
    info = {
        "completed": sum(1 for req in requests if req.t_done > 0),
        "expected": n_warmup + n_req,
        "makespan_s": round(m.makespan, 4),
        "preempt_events": m.preempt_events,
    }
    return info, measured


def _run_cell(sched_name: str, scenario: str, *, n_req: int, n_warmup: int,
              seeds: tuple[int, ...]) -> dict:
    """One (scheduler, scenario) cell: requests pooled across seeds so the
    p99 is not a single-sample statistic."""
    pooled: list[RequestRecord] = []
    infos = []
    for seed in seeds:
        info, measured = _run_seed(sched_name, scenario, n_req=n_req,
                                   n_warmup=n_warmup, seed=seed)
        infos.append(info)
        pooled.extend(measured)
    ttft = sorted(r.ttft for r in pooled)
    e2e = sorted(r.e2e for r in pooled)
    return {
        "completed": sum(i["completed"] for i in infos),
        "expected": sum(i["expected"] for i in infos),
        "measured": len(pooled),
        "ttft_ms_p50": round(percentile(ttft, 50) * 1e3, 3) if ttft else None,
        "ttft_ms_p99": round(percentile(ttft, 99) * 1e3, 3) if ttft else None,
        "e2e_ms_p99": round(percentile(e2e, 99) * 1e3, 3) if e2e else None,
        "makespan_s": [i["makespan_s"] for i in infos],
        "preempt_events": sum(i["preempt_events"] for i in infos),
    }


def run(fast: bool = False, workers: int | None = None) -> dict:
    del workers                    # threaded cells are in-process serial
    n_req = N_REQ_FAST if fast else N_REQ
    n_warmup = N_WARMUP_FAST if fast else N_WARMUP
    seeds = (0, 1) if fast else (0, 1, 2)
    scheds = FAST_SCHEDULERS if fast else SCHEDULERS
    out: dict = {"n_requests": n_req, "n_warmup": n_warmup,
                 "rate_rps": RATE_RPS, "seeds": list(seeds)}
    p99: dict[tuple[str, str], float] = {}
    for scenario in SCENARIOS:
        for name in scheds:
            res = _run_cell(name, scenario, n_req=n_req, n_warmup=n_warmup,
                            seeds=seeds)
            out[f"serve/{scenario}/{name}"] = res
            if (res["completed"] == res["expected"]
                    and res["ttft_ms_p99"] is not None):
                p99[(scenario, name)] = res["ttft_ms_p99"]
            emit(f"serve/{scenario}/{name}/ttft_ms_p99",
                 res["ttft_ms_p99"], f"p50={res['ttft_ms_p50']} "
                 f"completed={res['completed']}/{res['expected']}")

    # overload sweep: the same fleet pushed past saturation, once with the
    # one-dispatch-per-token decode path and once with continuous
    # batching; goodput must plateau (brownout ladder + backpressure), and
    # the batched knee must sit >= 5x the unbatched one
    window = OVER_WINDOW_S_FAST if fast else OVER_WINDOW_S
    axes = (("unbatched", OVER_RATES_FAST if fast else OVER_RATES, None),
            ("batched",
             OVER_RATES_BATCHED_FAST if fast else OVER_RATES_BATCHED,
             OVER_BATCHING))
    over_cells: dict[str, list[dict]] = {}
    for axis, rates, batching in axes:
        cells = over_cells[axis] = []
        for rate in rates:
            n = max(40, int(rate * window))
            cell = _run_overload(rate, n, batching=batching)
            cells.append(cell)
            out[f"overload/{axis}/rate_{int(rate)}"] = cell
            emit(f"overload/{axis}/rate_{int(rate)}/goodput_rps",
                 cell["goodput_rps"],
                 f"p99_ttft={cell['ttft_ms_p99']} "
                 f"rung={cell['brownout_max_rung']} "
                 f"rej_bp={cell['rejected_backpressure']} "
                 f"shed={cell['shed_brownout']}"
                 + (f" fill={cell['mean_batch_fill']}"
                    if "mean_batch_fill" in cell else ""))

    # acceptance: a criticality-aware scheduler beats RWS on p99 TTFT
    # under the injected-interference scenarios (threaded path)
    acceptance: dict = {}
    scenario_wins = 0
    for scenario in INTERFERENCE:
        rws = p99.get((scenario, "RWS"))
        if rws is None:
            continue
        for adaptive in ("DAM-C", "FAM-C"):
            own = p99.get((scenario, adaptive))
            if own is None:
                continue
            acceptance[f"{scenario}/{adaptive}_beats_RWS_p99_ttft"] = own < rws
            emit(f"serve/{scenario}/RWS_vs_{adaptive}_p99_ttft",
                 round(rws / own, 3), "x slower (>1: criticality-aware wins)")
    for scenario in INTERFERENCE:
        if any(acceptance.get(f"{scenario}/{a}_beats_RWS_p99_ttft")
               for a in ("DAM-C", "FAM-C")):
            scenario_wins += 1
    acceptance["interference_scenarios_won"] = scenario_wins
    acceptance["criticality_beats_RWS_p99_ttft_ge_2_scenarios"] = \
        scenario_wins >= 2
    # overload acceptance: past saturation the ladder trades output length
    # and LOW admissions for stability — goodput at the top rate must hold
    # >= 70% of the sweep's peak (plateau, not collapse), and the ladder
    # must climb monotonically with the offered rate, on both axes
    rungs_ok = True
    for axis, cells in over_cells.items():
        goodputs = [c["goodput_rps"] for c in cells
                    if c["goodput_rps"] is not None]
        if goodputs:
            acceptance[f"overload/{axis}/goodput_plateaus"] = \
                goodputs[-1] >= 0.7 * max(goodputs)
        rungs = [c["brownout_max_rung"] for c in cells]
        rungs_ok &= all(a <= b for a, b in zip(rungs, rungs[1:]))
    acceptance["overload/rungs_monotone_with_rate"] = rungs_ok
    # the tentpole gate: continuous batching must move the sustainable-
    # throughput knee by >= 5x without degrading first-token latency at
    # the knee (<= 1.5x relative or +5 ms absolute — wall-clock threaded
    # cells carry some sleep/dispatch jitter)
    knee_u = _knee(over_cells["unbatched"])
    knee_b = _knee(over_cells["batched"])
    out["overload/knee_unbatched_rps"] = knee_u
    out["overload/knee_batched_rps"] = knee_b
    acceptance["overload/knee_5x_vs_unbatched"] = (
        knee_u is not None and knee_b is not None and knee_b >= 5.0 * knee_u)
    p99_u = p99_b = None
    if knee_u is not None:
        p99_u = next(c["ttft_ms_p99"] for c in over_cells["unbatched"]
                     if c["rate_rps"] == knee_u)
    if knee_b is not None:
        p99_b = next(c["ttft_ms_p99"] for c in over_cells["batched"]
                     if c["rate_rps"] == knee_b)
    out["overload/p99_ttft_at_knee_unbatched_ms"] = p99_u
    out["overload/p99_ttft_at_knee_batched_ms"] = p99_b
    acceptance["overload/p99_ttft_unchanged_at_knee"] = (
        p99_u is not None and p99_b is not None
        and (p99_b <= 1.5 * p99_u or p99_b <= p99_u + 5.0))
    emit("overload/knee_batched_vs_unbatched",
         round(knee_b / knee_u, 2) if knee_u and knee_b else None,
         f"x (knee {knee_u} -> {knee_b} rps, p99 ttft "
         f"{p99_u} -> {p99_b} ms)")
    out["acceptance"] = acceptance
    # the repo-root mirror is the headline artifact (full sizes only)
    write_artifact("BENCH_serve", out, root_copy=not fast)
    return out


if __name__ == "__main__":
    run()
