"""Paper Fig. 8: sensitivity to the PTT update weight ratio (1/5..4/5) and
to the matmul tile size (32/64/80/96).  The paper finds the ratio matters
only for tile 32 (noisy ~10 us tasks), with 1/5 best, and selects 1:4."""
from __future__ import annotations

from repro.core import (corun_chain, make_scheduler, matmul_type, simulate,
                        synthetic_dag, tx2)

from .common import emit, write_artifact

TILES = (32, 64, 80, 96)
WEIGHTS = ((1, 4), (2, 3), (3, 2), (4, 1))      # new:old


def run(fast: bool = False) -> dict:
    out: dict = {}
    total = 4000 if fast else 12000
    for tile in TILES:
        tt = matmul_type(tile)
        for new_w, old_w in WEIGHTS:
            sched = make_scheduler("DAM-C", tx2(), seed=1,
                                   ptt_new_weight=new_w, ptt_old_weight=old_w)
            dag = synthetic_dag(tt, parallelism=2, total_tasks=total)
            m = simulate(dag, sched, background=[corun_chain(tt, core=0)])
            key = f"fig8/tile{tile}/w{new_w}_{new_w + old_w}"
            out[key] = m.throughput
            emit(key, round(m.throughput, 1), "tasks_per_s")
    for tile in TILES:
        vals = [out[f"fig8/tile{tile}/w{n}_{n + o}"] for n, o in WEIGHTS]
        spread = (max(vals) - min(vals)) / max(vals)
        emit(f"fig8/tile{tile}/weight_sensitivity_pct",
             round(spread * 100, 1),
             "paper: ~36% at tile 32, ~0 for larger tiles")
    write_artifact("fig8_sensitivity", out)
    return out


if __name__ == "__main__":
    run()
