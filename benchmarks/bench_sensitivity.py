"""Paper Fig. 8: sensitivity to the PTT update weight ratio (1/5..4/5) and
to the matmul tile size (32/64/80/96).  The paper finds the ratio matters
only for tile 32 (noisy ~10 us tasks), with 1/5 best, and selects 1:4.

The 16-cell (tile x weight) grid runs through the multi-run engine.
"""
from __future__ import annotations

from repro.core import RunSpec, run_cells

from .common import emit, write_artifact

TILES = (32, 64, 80, 96)
WEIGHTS = ((1, 4), (2, 3), (3, 2), (4, 1))      # new:old


def grid(fast: bool = False) -> list[RunSpec]:
    total = 4000 if fast else 12000
    specs = []
    for tile in TILES:
        tt = ("matmul", {"tile": tile})
        for new_w, old_w in WEIGHTS:
            specs.append(RunSpec(
                key=f"fig8/tile{tile}/w{new_w}_{new_w + old_w}",
                dag=("synthetic", {"task_type": tt, "parallelism": 2,
                                   "total_tasks": total}),
                scheduler="DAM-C",
                topology=("tx2", {}),
                seed=1,
                sched_kwargs={"ptt_new_weight": new_w,
                              "ptt_old_weight": old_w},
                background=(("chain", {"task_type": tt, "core": 0}),),
            ))
    return specs


def run(fast: bool = False, workers: int | None = None) -> dict:
    results = run_cells(grid(fast), workers=workers)
    out = {key: res["throughput_tps"] for key, res in results.items()}
    for key, v in out.items():
        emit(key, round(v, 1), "tasks_per_s")
    for tile in TILES:
        vals = [out[f"fig8/tile{tile}/w{n}_{n + o}"] for n, o in WEIGHTS]
        spread = (max(vals) - min(vals)) / max(vals)
        emit(f"fig8/tile{tile}/weight_sensitivity_pct",
             round(spread * 100, 1),
             "paper: ~36% at tile 32, ~0 for larger tiles")
    write_artifact("fig8_sensitivity", out)
    return out


if __name__ == "__main__":
    run()
