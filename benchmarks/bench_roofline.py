"""Roofline report: reads the dry-run artifacts and prints the full
(arch x shape x mesh) table with the three terms, the dominant bottleneck,
and MODEL_FLOPS/HLO_FLOPs — EXPERIMENTS.md §Roofline is generated from
this."""
from __future__ import annotations

import glob
import json
import os

from .common import emit, write_artifact

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(fast: bool = False, workers: int | None = None) -> dict:
    cells = load_cells()           # workers: unused (artifact reader)
    if not cells:
        emit("roofline/NO_ARTIFACTS", 0,
             "run python -m repro.launch.dryrun --all --mesh both first")
        return {}
    table = []
    for c in cells:
        if c["status"] != "OK":
            table.append({"cell": f"{c['arch']}/{c['shape']}/{c['mesh']}",
                          "status": c["status"]})
            continue
        r = c["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound > 0 else 0.0
        row = {
            "cell": f"{c['arch']}/{c['shape']}/{c['mesh']}",
            "status": "OK",
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "roofline_fraction": frac,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "fits_hbm": c["fits_hbm"],
            "per_device_MiB": c["per_device_bytes"] // 2 ** 20,
        }
        table.append(row)
        emit(f"roofline/{row['cell']}",
             round(frac, 3),
             f"dom={r['dominant']},c={r['compute_s']*1e3:.1f}ms,"
             f"m={r['memory_s']*1e3:.1f}ms,coll={r['collective_s']*1e3:.1f}ms")
    ok = [r for r in table if r.get("status") == "OK"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        emit("roofline/worst_fraction_cell", worst["cell"],
             f"{worst['roofline_fraction']:.3f}")
        collbound = [r for r in ok if r["dominant"] == "collective"]
        emit("roofline/collective_bound_cells", len(collbound),
             f"of {len(ok)}")
    write_artifact("roofline_table", table)
    return {"table": table}


if __name__ == "__main__":
    run()
