"""Paper Fig. 5 + Fig. 6: distribution of priority tasks over execution
places and cumulative per-core work time, matmul DAG parallelism 2 with a
co-runner on Denver core 0 (50% of tasks are critical).

Runs through the multi-run engine using the priority-placement and
per-core-worktime collectors (7 cells, one per scheduler).
"""
from __future__ import annotations

from repro.core import ALL_SCHEDULERS, RunSpec, run_cells

from .common import emit, write_artifact

_TT = ("matmul", {"tile": 64})


def run(fast: bool = False, workers: int | None = None) -> dict:
    total = 4000 if fast else 16000   # paper: 32000
    specs = [RunSpec(
        key=name,
        dag=("synthetic", {"task_type": _TT, "parallelism": 2,
                           "total_tasks": total}),
        scheduler=name,
        topology=("tx2", {}),
        seed=1,
        background=(("chain", {"task_type": _TT, "core": 0}),),
        collect=("priority_placement", "per_core_worktime_s"),
    ) for name in ALL_SCHEDULERS]
    out: dict = {}
    for name, res in run_cells(specs, workers=workers).items():
        pp = res["priority_placement"]
        wt = res["per_core_worktime_s"]
        out[name] = {"priority_placement": pp, "per_core_worktime_s": wt}
        on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
        top = max(pp.items(), key=lambda kv: kv[1]) if pp else ("-", 0)
        emit(f"fig5/{name}/prio_on_interfered_core_pct", round(on_c0 * 100, 1),
             f"top_place={top[0]}:{top[1]*100:.0f}%")
        emit(f"fig6/{name}/worktime_core0_s", round(wt[0], 2),
             f"max_core={wt.index(max(wt))}")
    write_artifact("fig5_6_distribution", out)
    return out


if __name__ == "__main__":
    run()
