"""Paper Fig. 5 + Fig. 6: distribution of priority tasks over execution
places and cumulative per-core work time, matmul DAG parallelism 2 with a
co-runner on Denver core 0 (50% of tasks are critical)."""
from __future__ import annotations

from repro.core import (ALL_SCHEDULERS, corun_chain, make_scheduler,
                        matmul_type, simulate, synthetic_dag, tx2)

from .common import emit, write_artifact


def run(fast: bool = False) -> dict:
    total = 4000 if fast else 16000   # paper: 32000
    out: dict = {}
    for name in ALL_SCHEDULERS:
        sched = make_scheduler(name, tx2(), seed=1)
        dag = synthetic_dag(matmul_type(64), parallelism=2, total_tasks=total)
        m = simulate(dag, sched, background=[corun_chain(matmul_type(64), 0)])
        pp = m.priority_placement()
        wt = m.per_core_worktime()
        out[name] = {"priority_placement": pp, "per_core_worktime_s": wt}
        on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
        top = max(pp.items(), key=lambda kv: kv[1]) if pp else ("-", 0)
        emit(f"fig5/{name}/prio_on_interfered_core_pct", round(on_c0 * 100, 1),
             f"top_place={top[0]}:{top[1]*100:.0f}%")
        emit(f"fig6/{name}/worktime_core0_s", round(wt[0], 2),
             f"max_core={wt.index(max(wt))}")
    write_artifact("fig5_6_distribution", out)
    return out


if __name__ == "__main__":
    run()
