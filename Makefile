# Single-entry smoke check: unit/regression tests + the fig4 and kernel
# benchmark suites at CI sizes.  The benchmark CSV includes per-suite wall
# times (also embedded in each JSON artifact under _meta.suite_wall_s) so
# perf regressions are visible in the trajectory.
PY := PYTHONPATH=src python

.PHONY: check test bench-smoke bench

check: test bench-smoke

test:
	$(PY) -m pytest -q

# --workers 2 keeps the multiprocessing fan-out path exercised in CI (the
# worker pool is cached across suites); scenarios covers the bursty/
# governor/trace profiles and the lazy-breakpoint pull path; preempt
# covers pod-slice revocation + the mixed-generation fleet
bench-smoke:
	$(PY) -m benchmarks.run --fast --workers 2 --only fig4,scenarios,preempt,kernels

# full paper-figure sweep (paper-full task counts: matmul 32k / copy 10k /
# stencil 20k) + scheduler-engine throughput, fanned across all host cores
bench:
	$(PY) -m benchmarks.run
