# Single-entry smoke check: unit/regression tests + the fig4 and kernel
# benchmark suites at CI sizes.  The benchmark CSV includes per-suite wall
# times (also embedded in each JSON artifact under _meta.suite_wall_s) so
# perf regressions are visible in the trajectory.
PY := PYTHONPATH=src python

.PHONY: check test bench-smoke bench

check: test bench-smoke

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) -m benchmarks.run --fast --only fig4,kernels

# full paper-figure sweep + scheduler-engine throughput
bench:
	$(PY) -m benchmarks.run
