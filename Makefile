# Single-entry smoke check: lint + unit/regression tests + the fig4, serve
# and kernel benchmark suites at CI sizes.  The benchmark CSV includes
# per-suite wall times (also embedded in each JSON artifact under
# _meta.suite_wall_s) so perf regressions are visible in the trajectory.
PY := PYTHONPATH=src python

.PHONY: check test lint bench-smoke bench acceptance

check: lint test bench-smoke acceptance

# acceptance blocks gate: every `false` entry in the root
# BENCH_serve.json / BENCH_scale.json artifacts must be in
# tools/check_acceptance.py's documented-negatives allowlists
# (see DESIGN.md §2 and §"Control plane")
acceptance:
	python tools/check_acceptance.py

test:
	$(PY) -m pytest -q

# prefer a real linter when one is installed; the stdlib AST checker
# (tools/lint.py — syntax errors + dead/duplicate imports) is the
# no-dependency fallback this container runs
lint:
	@if command -v ruff >/dev/null 2>&1; then ruff check src benchmarks tests tools examples; \
	elif python -c 'import pyflakes' >/dev/null 2>&1; then python -m pyflakes src benchmarks tests tools examples; \
	else python tools/lint.py; fi

# --workers 2 keeps the multiprocessing fan-out path exercised in CI (the
# worker pool is cached across suites); scenarios covers the bursty/
# governor/trace profiles and the lazy-breakpoint pull path; preempt
# covers pod-slice revocation + the mixed-generation fleet; serve covers
# the threaded open-loop serving path (p50/p99 TTFT under interference);
# scale covers the sharded control plane's flat-vs-sharded crossover
bench-smoke:
	$(PY) -m benchmarks.run --fast --workers 2 --only fig4,scenarios,preempt,faults,serve,kernels,scale

# full paper-figure sweep (paper-full task counts: matmul 32k / copy 10k /
# stencil 20k) + scheduler-engine throughput + the serving sweep, fanned
# across all host cores
bench:
	$(PY) -m benchmarks.run
