"""Gate on the serving benchmark's acceptance block.

``make check`` runs this after the bench smoke: the root
``BENCH_serve.json`` artifact must exist, its ``acceptance`` block must
parse, and every boolean entry that is ``false`` must appear in the
DOCUMENTED_NEGATIVES allowlist below with a written reason.  A new
``false`` that nobody wrote down is a regression (e.g. the load-aware
placement win in ``slow_fast_pod`` silently coming undone); a ``false``
in the allowlist is an honest negative the docs explain (DESIGN.md §2).

Usage: python tools/check_acceptance.py [path/to/BENCH_serve.json]
"""
from __future__ import annotations

import json
import pathlib
import sys

# Known-and-documented losses.  Key: acceptance-block entry; value: the
# one-line reason (the long form lives in DESIGN.md §2 and
# benchmarks/README.md).
DOCUMENTED_NEGATIVES: dict[str, str] = {
    "slow_fast_pod/FAM-C_beats_RWS_p99_ttft":
        "FAM-C binds prefill to the statically-ranked fast pod and cannot "
        "adapt when interference lands there; only the measurement-driven "
        "configs recover (DESIGN.md §2).",
    "slow_spread/FAM-C_beats_RWS_p99_ttft":
        "same static-binding failure mode with interference spread across "
        "both pods (DESIGN.md §2).",
    "revoke_fast/FAM-C_beats_RWS_p99_ttft":
        "phase-sensitive: FAM-C statically binds prefill to the pod the "
        "scenario revokes, so its p99 TTFT swings with revocation timing "
        "vs arrivals across runs; only the measurement-driven DAM-C win "
        "is stable enough to gate on.",
}


def check(path: pathlib.Path) -> int:
    try:
        artifact = json.loads(path.read_text())
    except FileNotFoundError:
        print(f"check_acceptance: {path} missing — run the serve benchmark "
              f"(make bench-smoke) first", file=sys.stderr)
        return 1
    except json.JSONDecodeError as e:
        print(f"check_acceptance: {path} is not valid JSON: {e}",
              file=sys.stderr)
        return 1

    acceptance = artifact.get("acceptance")
    if not isinstance(acceptance, dict) or not acceptance:
        print(f"check_acceptance: {path} has no acceptance block",
              file=sys.stderr)
        return 1

    failures = []
    for key, value in sorted(acceptance.items()):
        if value is not False:        # only boolean falses gate; ints and
            continue                  # trues are informational
        if key in DOCUMENTED_NEGATIVES:
            print(f"  allowed  {key}: {DOCUMENTED_NEGATIVES[key]}")
        else:
            failures.append(key)

    stale = [k for k in DOCUMENTED_NEGATIVES
             if acceptance.get(k) is True]
    for key in stale:
        print(f"  note     {key} is now true — consider dropping it from "
              f"the allowlist")

    if failures:
        for key in failures:
            print(f"check_acceptance: UNDOCUMENTED negative {key!r} — fix "
                  f"the regression or add it to DOCUMENTED_NEGATIVES with "
                  f"a reason", file=sys.stderr)
        return 1
    n_bool = sum(1 for v in acceptance.values() if isinstance(v, bool))
    print(f"check_acceptance: OK ({n_bool} boolean acceptance entries, "
          f"{sum(1 for v in acceptance.values() if v is False)} documented "
          f"negatives)")
    return 0


if __name__ == "__main__":
    target = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    sys.exit(check(target))
