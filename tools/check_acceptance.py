"""Gate on the benchmark artifacts' acceptance blocks.

``make check`` runs this after the bench smoke: each root artifact listed
in ARTIFACTS must exist, its ``acceptance`` block must parse, every key
in that artifact's REQUIRED_KEYS entry must be present (a headline gate
silently vanishing from the block — e.g. a benchmark edit dropping the
continuous-batching knee check — must fail loudly, not pass by absence),
and every boolean entry that is ``false`` must appear in that artifact's
documented-negatives allowlist below with a written reason.  A new
``false`` that nobody wrote down is a regression (e.g. the load-aware
placement win in ``slow_fast_pod`` silently coming undone, or the
sharded control plane losing its scaling crossover); a ``false`` in the
allowlist is an honest negative the docs explain (DESIGN.md §2,
§"Control plane").

Usage: python tools/check_acceptance.py [path/to/artifact.json ...]
(no arguments = every artifact in ARTIFACTS).
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Known-and-documented losses, per artifact.  Key: acceptance-block
# entry; value: the one-line reason (the long form lives in DESIGN.md
# and benchmarks/README.md).
DOCUMENTED_NEGATIVES: dict[str, dict[str, str]] = {
    "BENCH_serve.json": {
        "slow_fast_pod/FAM-C_beats_RWS_p99_ttft":
            "FAM-C binds prefill to the statically-ranked fast pod and "
            "cannot adapt when interference lands there; only the "
            "measurement-driven configs recover (DESIGN.md §2).",
        "slow_spread/FAM-C_beats_RWS_p99_ttft":
            "same static-binding failure mode with interference spread "
            "across both pods (DESIGN.md §2).",
        "revoke_fast/FAM-C_beats_RWS_p99_ttft":
            "phase-sensitive: FAM-C statically binds prefill to the pod "
            "the scenario revokes, so its p99 TTFT swings with revocation "
            "timing vs arrivals across runs; only the measurement-driven "
            "DAM-C win is stable enough to gate on.",
    },
    "BENCH_scale.json": {},
    # Scheduler-engine throughput trajectory (the array-native DES core):
    # the floors gate the committed root artifact's headline (DAM-C
    # fig4-class cell) and the RWSM-C outlier cell against the scalar-core
    # baselines (14.3k / 7.3k sim-tasks/s).  Regenerating on a heavily
    # loaded host can undershoot the >=3x entry — rerun `python -m
    # benchmarks.run --only sched` on a quiet machine rather than
    # allowlisting it.
    "BENCH_sched.json": {},
}

ARTIFACTS = tuple(DOCUMENTED_NEGATIVES)

# Acceptance keys that must be PRESENT (any boolean value — falses still
# go through the allowlist above).  Guards the headline gates against
# being dropped by a benchmark refactor.
REQUIRED_KEYS: dict[str, tuple[str, ...]] = {
    "BENCH_serve.json": (
        "overload/knee_5x_vs_unbatched",
        "overload/p99_ttft_unchanged_at_knee",
        "overload/rungs_monotone_with_rate",
    ),
}


def check(path: pathlib.Path) -> int:
    allowed = DOCUMENTED_NEGATIVES.get(path.name, {})
    try:
        artifact = json.loads(path.read_text())
    except FileNotFoundError:
        print(f"check_acceptance: {path} missing — run the benchmarks "
              f"(make bench-smoke) first", file=sys.stderr)
        return 1
    except json.JSONDecodeError as e:
        print(f"check_acceptance: {path} is not valid JSON: {e}",
              file=sys.stderr)
        return 1

    acceptance = artifact.get("acceptance")
    if not isinstance(acceptance, dict) or not acceptance:
        print(f"check_acceptance: {path} has no acceptance block",
              file=sys.stderr)
        return 1

    missing = [k for k in REQUIRED_KEYS.get(path.name, ())
               if k not in acceptance]
    if missing:
        for key in missing:
            print(f"check_acceptance: REQUIRED key {key!r} absent from "
                  f"{path.name} acceptance block — the gate was dropped, "
                  f"not passed", file=sys.stderr)
        return 1

    failures = []
    for key, value in sorted(acceptance.items()):
        if value is not False:        # only boolean falses gate; ints and
            continue                  # trues are informational
        if key in allowed:
            print(f"  allowed  {key}: {allowed[key]}")
        else:
            failures.append(key)

    stale = [k for k in allowed if acceptance.get(k) is True]
    for key in stale:
        print(f"  note     {key} is now true — consider dropping it from "
              f"the allowlist")

    if failures:
        for key in failures:
            print(f"check_acceptance: UNDOCUMENTED negative {key!r} in "
                  f"{path.name} — fix the regression or add it to "
                  f"DOCUMENTED_NEGATIVES with a reason", file=sys.stderr)
        return 1
    n_bool = sum(1 for v in acceptance.values() if isinstance(v, bool))
    print(f"check_acceptance: {path.name} OK ({n_bool} boolean acceptance "
          f"entries, {sum(1 for v in acceptance.values() if v is False)} "
          f"documented negatives)")
    return 0


def main(argv: list[str]) -> int:
    targets = ([pathlib.Path(a) for a in argv] if argv
               else [REPO_ROOT / name for name in ARTIFACTS])
    return max(check(t) for t in targets)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
