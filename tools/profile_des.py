"""DES phase timer: per-event-kind and per-phase wall buckets.

``cProfile`` inflates the DES hot path 2-3x and smears cost across
inlined helpers; this tool instead wraps the simulator's event handlers
and hot phases with ``perf_counter`` pairs on one instance, so a
regression localizes to a bucket ("finish handling got slower", "the
dispatch fixpoint is doing more rounds") without distorting the
relative numbers.  Buckets overlap by construction — an event-kind
bucket (e.g. ``finish``) contains the phase work its handler triggers
(``refresh``, ``dispatch``) — so they are read as a breakdown per axis,
not a partition of wall time.

Output is JSON: per-event-kind wall buckets under ``_meta.kinds_s``,
phase buckets under ``_meta.phases_s``, plus the workload descriptor
and the same ``sim_tasks_per_s`` currency as ``BENCH_sched.json``
(timed *without* instrumentation first, so the headline number is
comparable).

Usage:
    PYTHONPATH=src python tools/profile_des.py
    PYTHONPATH=src python tools/profile_des.py --sched RWSM-C \\
        --tasks 4000 -o artifacts/profile_des.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core import (corun_chain, haswell, make_scheduler, matmul_type,  # noqa: E402
                        synthetic_dag, tx2, tx2_xl)
from repro.core.simulator import Simulator  # noqa: E402

TOPOS = {
    "tx2": lambda: tx2(),
    "tx2_xl": lambda: tx2_xl(clusters=4),
    "haswell": lambda: haswell(),
}

# handler -> event kind it serves (the DES heap's ``kind`` strings)
KIND_HANDLERS = {
    "_commit": "finish",
    "_on_fault_trigger": "finish(fault)",
    "_on_straggler": "straggle",
    "_requeue": "retry",
    "_notice_expire": "notice",
    "_recompute_speed": "speed",
    "_recompute_bg": "bg",
    "_revoke": "revoke",
    "_restore": "restore",
    "_decide": "decide",
    "_migrate_land": "migrate",
    "_rebalance": "rebalance",
}

# hot phases shared by every event's live tail
PHASE_HANDLERS = {
    "_advance": "advance",
    "_dispatch": "dispatch",
    "_refresh_rates": "refresh",
    "_place_into_aqs": "place",
    "_try_steal": "steal",
    "_maybe_compact": "compact",
}


def _build(args):
    topo = TOPOS[args.topo]()
    sched = make_scheduler(args.sched, topo, seed=args.seed)
    tt = matmul_type(64)
    dag = synthetic_dag(tt, parallelism=args.parallelism,
                        total_tasks=args.tasks)
    sim = Simulator(sched, background=[corun_chain(tt, core=0)])
    sim.submit(dag)
    return sim


def _instrument(sim, table: dict) -> dict:
    """Wrap handlers on *this instance* with perf_counter pairs; the
    class (and every other simulator) is untouched.  Wrapping happens
    before run(), and the event loops call every handler through
    ``self.``, so instance attributes shadow the methods."""
    buckets: dict[str, dict] = {}
    pc = time.perf_counter
    for attr, bucket in table.items():
        fn = getattr(sim, attr, None)
        if fn is None:
            continue
        cell = buckets[bucket] = {"wall_s": 0.0, "calls": 0}

        def timed(*a, _fn=fn, _c=cell, **k):
            t0 = pc()
            try:
                return _fn(*a, **k)
            finally:
                _c["wall_s"] += pc() - t0
                _c["calls"] += 1

        setattr(sim, attr, timed)
    return buckets


def profile(args) -> dict:
    # headline pass: untouched instance, so the throughput number is the
    # real one (instrumentation costs ~2 perf_counter calls per handler
    # call and would understate it)
    sim = _build(args)
    t0 = time.perf_counter()
    metrics = sim.run()
    wall = time.perf_counter() - t0

    sim2 = _build(args)
    kinds = _instrument(sim2, KIND_HANDLERS)
    phases = _instrument(sim2, PHASE_HANDLERS)
    t0 = time.perf_counter()
    sim2.run()
    wall_instr = time.perf_counter() - t0

    rnd = lambda d: {k: {"wall_s": round(v["wall_s"], 6),
                         "calls": v["calls"]}
                     for k, v in sorted(d.items()) if v["calls"]}
    return {
        "_meta": {
            "workload": {
                "sched": args.sched, "topo": args.topo,
                "parallelism": args.parallelism, "tasks": args.tasks,
                "seed": args.seed,
            },
            "wall_s": round(wall, 4),
            "sim_tasks_per_s": round(metrics.n_tasks / wall, 1),
            "makespan_s": round(metrics.makespan, 6),
            "instrumented_wall_s": round(wall_instr, 4),
            "kinds_s": rnd(kinds),
            "phases_s": rnd(phases),
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sched", default="DAM-C")
    ap.add_argument("--topo", default="tx2", choices=sorted(TOPOS))
    ap.add_argument("--parallelism", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("-o", "--out", default=None,
                    help="also write the JSON here")
    args = ap.parse_args(argv)
    payload = profile(args)
    text = json.dumps(payload, indent=1)
    print(text)
    if args.out:
        pathlib.Path(args.out).write_text(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
