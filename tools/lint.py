"""Minimal stdlib linter: syntax errors, unused imports, duplicate imports.

`make lint` prefers ruff or pyflakes when one is installed; neither ships
in this container (and the build bakes its dependencies), so this AST
checker covers the failure mode refactors actually leave behind — dead
imports — plus outright syntax errors, with no third-party dependency.

Rules:
  * every file must parse;
  * an imported name must be referenced somewhere in the module — as a
    load, an attribute root, a decorator, an annotation, or a string
    entry of ``__all__``;
  * the same name must not be imported twice *at module level*
    (function-scoped lazy imports are their own scope and exempt).

``__init__.py`` files without ``__all__`` are exempt from the unused
check (bare re-export surface); ``from __future__ import ...`` is always
exempt; lines carrying a ``noqa`` comment are skipped, as the usual
linters would.

Usage: python tools/lint.py [paths...]   (default: src benchmarks tests
tools examples, relative to the repo root)
"""
from __future__ import annotations

import ast
import os
import sys

DEFAULT_PATHS = ("src", "benchmarks", "tests", "tools", "examples")


def _iter_py(paths):
    for path in paths:
        if os.path.isfile(path) and path.endswith(".py"):
            yield path
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git", "artifacts")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


class _Imports(ast.NodeVisitor):
    """Collect imported binding names and every referenced name."""

    def __init__(self):
        self.imports: list[tuple[str, int, bool]] = []  # (name, line, toplevel)
        self.used: set[str] = set()
        self.dunder_all: list[str] = []
        self._depth = 0

    def _scoped(self, node):
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_FunctionDef = visit_AsyncFunctionDef = visit_ClassDef = \
        visit_Lambda = _scoped

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            name = alias.asname or alias.name.split(".")[0]
            self.imports.append((name, node.lineno, self._depth == 0))

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "__future__":
            return
        for alias in node.names:
            if alias.name == "*":
                continue                 # star imports defeat the analysis
            self.imports.append((alias.asname or alias.name, node.lineno,
                                 self._depth == 0))

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            self.used.add(node.id)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and tgt.id == "__all__" \
                    and isinstance(node.value, (ast.List, ast.Tuple)):
                self.dunder_all.extend(
                    elt.value for elt in node.value.elts
                    if isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str))
        self.generic_visit(node)


def lint_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    v = _Imports()
    v.visit(tree)
    lines = src.splitlines()

    def noqa(lineno: int) -> bool:
        return 0 < lineno <= len(lines) and "noqa" in lines[lineno - 1]

    problems = []
    seen: dict[str, int] = {}
    for name, lineno, toplevel in v.imports:
        if not toplevel or noqa(lineno):
            continue
        if name in seen:
            problems.append(f"{path}:{lineno}: duplicate import of "
                            f"{name!r} (first at line {seen[name]})")
        else:
            seen[name] = lineno
    is_bare_init = (os.path.basename(path) == "__init__.py"
                    and not v.dunder_all)
    if not is_bare_init:
        used = v.used | set(v.dunder_all)
        for name, lineno, _toplevel in v.imports:
            if name not in used and not name.startswith("_") \
                    and not noqa(lineno):
                problems.append(f"{path}:{lineno}: unused import {name!r}")
    return problems


def main(argv: list[str]) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv or [os.path.join(root, p) for p in DEFAULT_PATHS
                     if os.path.isdir(os.path.join(root, p))]
    problems = []
    n = 0
    for path in _iter_py(paths):
        n += 1
        problems.extend(lint_file(path))
    for p in problems:
        print(p)
    print(f"lint: {n} files, {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
