"""Load-aware scheduling kernel: queue-penalty placement, PTT priming,
charge/discharge accounting, and the serving brownout ladder.

The tentpole invariant: with ``queue_penalty=0`` (the paper-faithful
default) every code path is bit-identical to the untracked kernel, and
with a penalty attached simultaneous HIGH wakes spread across places
instead of herding onto the single momentarily-best one (the cross-engine
version of that regression lives in ``test_cross_engine.py``)."""
import pytest

from repro.core import (ExecutionPlace, Priority, ResourcePartition,
                        Simulator, SpeedProfile, Task, TaskType, Topology,
                        make_scheduler, matmul_type, simulate, synthetic_dag,
                        task_faults, tx2)
from repro.core.dag import DAG
from repro.serve import BrownoutConfig, OverloadController


def _records(m):
    return [(r.type_name, r.priority, r.leader, r.width, r.t_start, r.t_end)
            for r in m.records]


# -- bit-identity at queue_penalty=0 ------------------------------------------
def test_penalty_zero_is_bit_identical():
    """Load *tracking* alone (accounting on, penalty off) must not perturb
    a single scheduling decision: same records, same timestamps."""
    speed = SpeedProfile(6).add_window([0], 0.0, float("inf"), 0.25)
    runs = []
    for kw in ({}, {"track_load": True}):
        sched = make_scheduler("DAM-C", tx2(), seed=7, **kw)
        m = simulate(synthetic_dag(matmul_type(64), parallelism=6,
                                   total_tasks=240), sched, speed=speed)
        runs.append(_records(m))
    assert runs[0] == runs[1]


def test_make_scheduler_rejects_negative_penalty():
    with pytest.raises(ValueError):
        make_scheduler("DAM-C", tx2(), queue_penalty=-0.5)


# -- charge/discharge accounting ----------------------------------------------
def test_load_drains_to_zero_after_run():
    """Every charge path (wake stamp, dequeue charge) must meet its
    discharge (commit, fault, requeue): at end of run the kernel's
    outstanding-load vector is empty (to float +=/-= residue)."""
    sched = make_scheduler("DAM-C", tx2(), seed=1, queue_penalty=1.0)
    sim = Simulator(sched)
    sim.submit(synthetic_dag(matmul_type(64), parallelism=6,
                             total_tasks=120))
    sim.run()
    assert not sim.kernel._run_charges
    assert sim.kernel.load_per_core().max() < 1e-12
    assert sim.kernel.backlog_signal() < 1e-12


def test_load_drains_to_zero_with_faults():
    """Retries re-stamp and re-charge; permanent failures and fault
    feedback must still discharge every cent."""
    sched = make_scheduler("DAM-C", tx2(), seed=2, queue_penalty=1.0)
    sim = Simulator(sched, faults=task_faults(seed=3, p_fail=0.3))
    sim.submit(synthetic_dag(matmul_type(64), parallelism=4,
                             total_tasks=80))
    m = sim.run()
    assert m.faults_failstop > 0
    assert not sim.kernel._run_charges
    assert sim.kernel.load_per_core().max() < 1e-12


def test_load_drains_to_zero_across_shard_migration():
    """A task charged on its source shard and committed on its
    destination must not strand a charge on either side: after a sharded
    run with real migrations, every per-shard kernel's charge table is
    empty and the plane-wide load vector is zero."""
    from repro.core import ShardingSpec, tpu_pod_slices
    sched = make_scheduler("DAM-C", tpu_pod_slices(pods=4, slices_per_pod=4),
                           seed=5, queue_penalty=1.0)
    sim = Simulator(sched, sharding=ShardingSpec(pods_per_shard=1,
                                                 decision_s=5e-5,
                                                 rebalance_period_s=1e-3,
                                                 overflow_ratio=2.0))
    sim.submit(synthetic_dag(matmul_type(4096), parallelism=24,
                             total_tasks=400))
    m = sim.run()
    assert m.n_tasks == 400
    assert m.migrations + m.overflow_migrations > 0
    for k in sim.kernel.kernels:
        assert not k._run_charges
    assert sim.kernel.load_per_core().max() < 1e-12
    assert sim.kernel.backlog_signal() < 1e-12


# -- PTT priming ---------------------------------------------------------------
def test_ptt_prime_seeds_unexplored_only():
    topo = tx2()
    sched = make_scheduler("DAM-C", topo, seed=0)
    tbl = sched.ptt.for_type("matmul64")
    place = ExecutionPlace(0, 1)
    assert tbl.prime(place, 5e-3)            # cold entry takes the prior
    assert tbl.get(place) == 5e-3
    assert tbl.visited(place) == 0           # a prior is not a visit
    assert not tbl.prime(place, 9e-3)        # primed entries are not re-primed
    assert tbl.get(place) == 5e-3
    # the first real observation *overwrites* the prior (first-visit
    # direct), it does not average against it
    tbl.update(place, 2e-3)
    assert tbl.get(place) == pytest.approx(2e-3)
    assert not tbl.prime(place, 5e-3)        # visited entries never primed
    with pytest.raises(ValueError):
        tbl.prime(ExecutionPlace(1, 1), 0.0)


def test_kernel_prime_ptt_covers_every_place():
    sched = make_scheduler("DAM-C", tx2(), seed=0)
    sim = Simulator(sched)
    tt = matmul_type(64)
    n = sim.kernel.prime_ptt(tt)
    places = sched.topology.places()
    assert n == len(places)
    tbl = sched.ptt.for_type(tt.name)
    for p in places:
        assert tbl.get(p) == pytest.approx(
            sim.kernel.estimate_seconds(tt, p))
    assert sim.kernel.prime_ptt(tt) == 0     # idempotent


# -- brownout ladder -----------------------------------------------------------
def test_brownout_config_validation():
    with pytest.raises(ValueError):
        BrownoutConfig(enter=(0.5, 1.5, 4.0), exit=(0.6, 0.75, 2.0))
    with pytest.raises(ValueError):          # enter not increasing
        BrownoutConfig(enter=(1.5, 0.5, 4.0), exit=(0.2, 0.3, 2.0))
    with pytest.raises(ValueError):
        BrownoutConfig(min_tokens=0)
    cfg = BrownoutConfig()
    assert cfg.enter[0] > cfg.exit[0]


def test_overload_controller_hysteresis():
    ctl = OverloadController(BrownoutConfig(enter=(1.0, 2.0, 4.0),
                                            exit=(0.5, 1.0, 2.0)))
    assert ctl.update(0.4, 0.0) == 0
    assert ctl.update(1.2, 1.0) == 1         # cross enter[0]
    assert ctl.update(0.8, 2.0) == 1         # inside the hysteresis band
    assert ctl.update(0.4, 3.0) == 0         # below exit[0]
    assert ctl.update(5.0, 4.0) == 3         # step change climbs all rungs
    assert ctl.shrink_low and ctl.shed_low and ctl.reject_low
    assert ctl.update(3.0, 5.0) == 3         # >= exit[2]: holds
    assert ctl.update(1.5, 6.0) == 2         # < exit[2] but >= exit[1]
    assert ctl.update(0.7, 7.0) == 1         # < exit[1] but >= exit[0]
    assert ctl.update(0.1, 8.0) == 0
    # one transition tuple per rung *change*, multi-rung jumps collapsed
    assert ctl.transitions == [(1.0, 0, 1), (3.0, 1, 0), (4.0, 0, 3),
                               (6.0, 3, 2), (7.0, 2, 1), (8.0, 1, 0)]


# -- DES forced overload: the serving-shaped ladder drill ----------------------
def _overload_sim():
    """A 2-core fleet hit by a burst of 40 simultaneous HIGH prefills
    (~1.3 s of work against 2 cores): the serving-shaped DES twin of the
    threaded open-loop overload test in ``test_serve.py``.  Every commit
    folds the kernel's backlog signal into the controller; each prefill's
    commit is the request's admission point (rung 3 rejects its decode
    chain outright), each decode commit is a shed point (rung >= 2 drops
    the rest of the chain).  The ladder jumps straight to rung 3 on the
    first observation, then walks down through shed and admit phases as
    the backlog drains."""
    topo = Topology([ResourcePartition("s0", "pod", 0, 2, (1,))])
    sched = make_scheduler("DAM-C", topo, seed=0, queue_penalty=1.0)
    sim = Simulator(sched)
    ctl = OverloadController(BrownoutConfig(enter=(0.05, 0.15, 0.30),
                                            exit=(0.02, 0.04, 0.15)))
    root_t = TaskType("burst_root", serial_time={"pod": 1e-4})
    pre_t = TaskType("ov_prefill", serial_time={"pod": 0.05})
    dec_t = TaskType("ov_decode", serial_time={"pod": 0.02})
    counters = {"admitted": 0, "rejected": 0, "shed": 0}
    rungs: list[int] = []
    n_requests = 40

    def tick() -> None:
        rungs.append(ctl.update(sim.kernel.backlog_signal(), sim.now))

    def make_dec(i):
        d = Task(dec_t, priority=Priority.LOW)

        def dec_commit(_t, _i=i):
            tick()
            if ctl.shed_low:
                counters["shed"] += 1
                return []
            return [make_dec(_i + 1)] if _i + 1 < 3 else []

        d.on_commit = dec_commit
        return d

    def make_request():
        pre = Task(pre_t, priority=Priority.HIGH)

        def pre_commit(_t):
            tick()
            if ctl.reject_low:
                counters["rejected"] += 1
                return []
            counters["admitted"] += 1
            return [make_dec(0)]

        pre.on_commit = pre_commit
        return pre

    root = Task(root_t, priority=Priority.LOW)
    root.on_commit = lambda _t: [make_request() for _ in range(n_requests)]
    sim.submit(DAG([root], 1 + n_requests))
    sim.run()
    return ctl, counters, rungs


def test_des_forced_overload_climbs_and_recovers():
    ctl, counters, rungs = _overload_sim()
    # the burst's backlog sends the very first observation to rung 3
    # (admission rejection); both interventions fire on the way down
    assert rungs[0] == 3
    assert counters["rejected"] > 0
    assert counters["shed"] > 0
    assert counters["admitted"] > 0
    assert counters["rejected"] + counters["admitted"] == 40
    # the DES is deterministic, so the counters pin exactly
    assert counters == {"admitted": 7, "rejected": 33, "shed": 2}
    # the backlog only drains after the burst, so the rung walk is
    # monotone non-increasing and ends fully recovered, one rung at a
    # time: 3 -> 2 -> 1 -> 0
    assert all(a >= b for a, b in zip(rungs, rungs[1:]))
    assert rungs[-1] == 0
    assert [(frm, to) for _, frm, to in ctl.transitions] == \
        [(0, 3), (3, 2), (2, 1), (1, 0)]


def test_des_forced_overload_is_deterministic():
    a = _overload_sim()
    b = _overload_sim()
    assert a[1] == b[1]
    assert a[2] == b[2]
    assert a[0].transitions == b[0].transitions
