"""Golden-schedule regression tests for the discrete-event engine.

Small seeded runs of all 7 schedulers under combined interference (core-0
co-runner + a Denver DVFS square wave) with makespan/throughput and the
full task-placement histogram pinned.  Purpose: any change to the
simulator/scheduler hot path that alters scheduler-visible behavior —
queue ordering, steal victim choice, placement search, rate integration —
shows up here immediately, per scheduler, instead of as a silent drift in
the paper-figure benchmarks.

The pinned values are from the incremental-dispatch engine; on the same
workload the pre-refactor scan-everything engine lands within 5% on every
scheduler (FA/FAM-C to the last digit), and the placement *structure*
(FA pinned to Denver, DA/DAM families avoiding the interfered core 0,
DAM-P molding wide) matches the paper's Figs. 4-7 expectations.

If an intentional behavior change shifts these numbers, regenerate with
``python tests/test_golden_schedule.py``.
"""
import json

import pytest

from repro.core import (ALL_SCHEDULERS, SpeedProfile, corun_chain,
                        make_scheduler, matmul_type, simulate, synthetic_dag,
                        tx2)

GOLDEN = {
    "RWS": {
        "makespan": 0.032919298643,
        "places": {"(C0,1)": 39, "(C2,1)": 50, "(C3,1)": 40, "(C1,1)": 54,
                   "(C5,1)": 36, "(C4,1)": 21},
        "high_places": {"(C2,1)": 25, "(C3,1)": 20, "(C1,1)": 27,
                        "(C0,1)": 19, "(C5,1)": 18, "(C4,1)": 11},
    },
    "RWSM-C": {
        "makespan": 0.034431414253,
        "places": {"(C0,1)": 43, "(C2,1)": 39, "(C2,2)": 1, "(C4,1)": 60,
                   "(C0,2)": 7, "(C4,2)": 21, "(C3,1)": 33, "(C1,1)": 34,
                   "(C2,4)": 1, "(C5,1)": 1},
        "high_places": {"(C2,1)": 20, "(C4,1)": 21, "(C0,2)": 3, "(C3,1)": 17,
                        "(C1,1)": 17, "(C0,1)": 22, "(C5,1)": 1, "(C4,2)": 19},
    },
    "FA": {
        "makespan": 0.036449251282,
        "places": {"(C0,1)": 120, "(C1,1)": 119, "(C2,1)": 1},
        "high_places": {"(C0,1)": 60, "(C1,1)": 60},
    },
    "FAM-C": {
        "makespan": 0.036155490674,
        "places": {"(C0,1)": 104, "(C1,1)": 113, "(C2,1)": 1, "(C0,2)": 16,
                   "(C3,1)": 1, "(C5,1)": 1, "(C2,2)": 1, "(C4,1)": 1,
                   "(C4,2)": 1, "(C2,4)": 1},
        "high_places": {"(C0,1)": 52, "(C1,1)": 60, "(C0,2)": 8},
    },
    "DA": {
        "makespan": 0.013368136306,
        "places": {"(C0,1)": 30, "(C2,1)": 24, "(C1,1)": 117, "(C5,1)": 24,
                   "(C4,1)": 23, "(C3,1)": 22},
        "high_places": {"(C2,1)": 1, "(C1,1)": 114, "(C5,1)": 1, "(C4,1)": 1,
                        "(C3,1)": 1, "(C0,1)": 2},
    },
    "DAM-C": {
        "makespan": 0.016532781546,
        "places": {"(C0,1)": 21, "(C2,1)": 23, "(C1,1)": 114, "(C0,2)": 10,
                   "(C2,2)": 1, "(C3,1)": 25, "(C4,1)": 21, "(C2,4)": 1,
                   "(C5,1)": 22, "(C4,2)": 2},
        "high_places": {"(C2,1)": 1, "(C1,1)": 113, "(C3,1)": 1, "(C4,1)": 1,
                        "(C5,1)": 1, "(C4,2)": 1, "(C0,2)": 1, "(C0,1)": 1},
    },
    "DAM-P": {
        "makespan": 0.018024604741,
        "places": {"(C0,1)": 19, "(C2,1)": 17, "(C1,1)": 88, "(C0,2)": 23,
                   "(C2,2)": 6, "(C3,1)": 16, "(C4,1)": 20, "(C2,4)": 31,
                   "(C5,1)": 18, "(C4,2)": 2},
        "high_places": {"(C2,1)": 1, "(C1,1)": 71, "(C3,1)": 1, "(C4,1)": 1,
                        "(C5,1)": 1, "(C4,2)": 1, "(C0,2)": 9, "(C2,2)": 5,
                        "(C2,4)": 30},
    },
}

N_TASKS = 240


def _golden_run(name):
    sched = make_scheduler(name, tx2(), seed=7)
    tt = matmul_type(64)
    dag = synthetic_dag(tt, parallelism=2, total_tasks=N_TASKS)
    speed = SpeedProfile(6).add_square_wave((0, 1), period=0.004, lo=0.17,
                                            t_end=0.2)
    return simulate(dag, sched, background=[corun_chain(tt, core=0)],
                    speed=speed)


@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_golden_makespan_and_throughput(name):
    m = _golden_run(name)
    assert m.n_tasks == N_TASKS
    want = GOLDEN[name]["makespan"]
    assert m.makespan == pytest.approx(want, rel=1e-9), name
    assert m.throughput == pytest.approx(N_TASKS / want, rel=1e-9), name


@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_golden_placement_histogram(name):
    m = _golden_run(name)
    assert m.placement_counts() == GOLDEN[name]["places"], name
    assert m.placement_counts(priority=1) == GOLDEN[name]["high_places"], name


def test_golden_structure_matches_paper():
    """Scheduler-family sanity independent of exact pins: FA binds HIGH to
    the static-fast Denver cores; the dynamic families route HIGH work away
    from the interfered core 0; DAM-P (performance) molds wider than DAM-C
    (cost)."""
    assert set(GOLDEN["FA"]["high_places"]) == {"(C0,1)", "(C1,1)"}
    for fam in ("DA", "DAM-C"):
        high = GOLDEN[fam]["high_places"]
        on_c0 = sum(v for k, v in high.items() if k.startswith("(C0"))
        assert on_c0 / sum(high.values()) < 0.05, fam
    wide = lambda h: sum(v for k, v in h.items() if k.endswith(",4)"))
    assert wide(GOLDEN["DAM-P"]["places"]) > wide(GOLDEN["DAM-C"]["places"])


if __name__ == "__main__":                       # regenerate the pins
    out = {}
    for sched_name in ALL_SCHEDULERS:
        m = _golden_run(sched_name)
        out[sched_name] = {
            "makespan": round(m.makespan, 12),
            "places": m.placement_counts(),
            "high_places": m.placement_counts(priority=1),
        }
    print(json.dumps(out, indent=2))
