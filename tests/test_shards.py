"""Sharded control plane: the flat-kernel equivalence pins, deterministic
cross-engine rebalancer parity, overflow routing, modeled decision
latency, and the rebalancer-starvation regression.

``make_control_plane`` must degenerate to the *plain* flat kernel for
``sharding=None`` and for any one-shard grouping — that structural
degeneracy is the semantics-preservation pin the golden schedules rest
on.  Migration *decisions* (``GlobalRebalancer.plan_round``) are a pure
function of queue state shared verbatim by both engines, so the DES- and
thread-constructed planes must plan identical rounds from identical
states."""
import pytest

from repro.core import (Priority, Simulator, Task, ThreadedRuntime,
                        make_scheduler, matmul_type, simulate, synthetic_dag,
                        tpu_pod_slices)
from repro.core.lifecycle import SchedulingKernel
from repro.core.shards import (ShardedControlPlane, ShardingSpec,
                               make_control_plane)


def _topo():
    return tpu_pod_slices(pods=4, slices_per_pod=4)


def _records(m):
    return [(r.type_name, r.priority, r.leader, r.width, r.t_start, r.t_end)
            for r in m.records]


# -- spec validation ----------------------------------------------------------

def test_sharding_spec_validation():
    with pytest.raises(ValueError):
        ShardingSpec(pods_per_shard=0)
    with pytest.raises(ValueError):
        ShardingSpec(decision_s=-1e-6)
    with pytest.raises(ValueError):
        ShardingSpec(rebalance_period_s=float("inf"))
    with pytest.raises(ValueError):
        ShardingSpec(imbalance_ratio=0.5)
    with pytest.raises(ValueError):
        ShardingSpec(max_migrations_per_round=0)
    # deep-trigger ratios: 0 (off) or >= 1, nothing in between
    with pytest.raises(ValueError):
        ShardingSpec(high_pressure_ratio=0.5)
    with pytest.raises(ValueError):
        ShardingSpec(ptt_divergence_ratio=-1.0)
    with pytest.raises(ValueError):
        ShardingSpec(ptt_divergence_ratio=float("nan"))
    ShardingSpec(high_pressure_ratio=1.5, ptt_divergence_ratio=2.0)


# -- the flat-kernel degeneracy pin ------------------------------------------

def test_one_shard_grouping_is_the_flat_kernel():
    """``sharding=None`` and any grouping that yields one shard must both
    return the *plain* SchedulingKernel instance — the flat code path
    itself, not a 1-shard plane imitating it."""
    sched = make_scheduler("DAM-C", _topo(), seed=1)
    k0 = make_control_plane(sched, now=lambda: 0.0)
    assert type(k0) is SchedulingKernel
    sched2 = make_scheduler("DAM-C", _topo(), seed=1)
    k1 = make_control_plane(sched2, now=lambda: 0.0,
                            sharding=ShardingSpec(pods_per_shard=4))
    assert type(k1) is SchedulingKernel
    sched3 = make_scheduler("DAM-C", _topo(), seed=1)
    k2 = make_control_plane(sched3, now=lambda: 0.0,
                            sharding=ShardingSpec(pods_per_shard=2))
    assert isinstance(k2, ShardedControlPlane)
    assert k2.n_shards == 2


def test_one_shard_zero_overhead_run_bit_identical_to_flat():
    runs = []
    for sharding in (None, ShardingSpec(pods_per_shard=4)):
        sched = make_scheduler("DAM-C", _topo(), seed=7)
        m = simulate(synthetic_dag(matmul_type(1024), parallelism=16,
                                   total_tasks=400), sched,
                     sharding=sharding)
        runs.append(_records(m))
    assert runs[0] == runs[1]


# -- shard construction and routing ------------------------------------------

def _plane(seed=3, **kw):
    spec = ShardingSpec(pods_per_shard=1, **kw)
    sched = make_scheduler("DAM-C", _topo(), seed=seed)
    return make_control_plane(sched, now=lambda: 0.0, sharding=spec)


def test_shard_layout_and_local_wake_routing():
    cp = _plane()
    assert cp.n_shards == 4
    assert cp.shard_cores == (tuple(range(0, 4)), tuple(range(4, 8)),
                              tuple(range(8, 12)), tuple(range(12, 16)))
    # with overflow off, a wake routes inside the waker's shard
    for waker in (0, 5, 10, 15):
        t = Task(matmul_type(1024), priority=Priority.LOW)
        core = cp.wake(t, waker)
        assert cp.shard_of_core[core] == cp.shard_of_core[waker]


def test_wake_overflow_redirects_off_hot_shard():
    cp = _plane(overflow_ratio=2.0)
    # pile queued work onto shard 0 until its load tops 2x the fleet mean
    for _ in range(12):
        t = Task(matmul_type(4096), priority=Priority.LOW)
        cp.queues.push(t, cp.kernels[0].wake(t, 0))
    before = cp.overflow_migrations
    t = Task(matmul_type(4096), priority=Priority.LOW)
    core = cp.wake(t, 0)
    assert cp.shard_of_core[core] != 0
    assert cp.overflow_migrations == before + 1


def test_migrate_in_clears_binding_and_keeps_t_ready():
    cp = _plane()
    t = Task(matmul_type(1024), priority=Priority.HIGH)
    cp.queues.push(t, cp.wake(t, 0))
    t.t_ready = 0.125
    popped = cp.queues.migrate_pop(
        next(c for c in cp.shard_cores[0] if cp.queues.migrate_pop is not None
             and cp.queues.queued_s[c] > 0))
    assert popped is t
    core = cp.migrate_in(t, 2)
    assert cp.shard_of_core[core] == 2
    # the old binding named a shard-0 place; any rebinding is shard 2's
    if t.bound_place is not None:
        assert cp.shard_of_core[t.bound_place.leader] == 2
    assert t.t_ready == 0.125           # migration must not hide queueing
    assert cp.migrations == 1


def test_dead_shard_wake_routing_and_restore():
    cp = _plane()
    cp.set_availability(frozenset(cp.shard_cores[0]))
    assert cp.shard_dead(0) and not cp.shard_dead(1)
    t = Task(matmul_type(1024), priority=Priority.LOW)
    core = cp.wake(t, 0)                # waker's shard is down
    assert cp.shard_of_core[core] != 0
    cp.set_availability(frozenset())
    assert not cp.shard_dead(0)
    t2 = Task(matmul_type(1024), priority=Priority.LOW)
    assert cp.shard_of_core[cp.wake(t2, 0)] == 0


# -- rebalancer ---------------------------------------------------------------

def _loaded_engine_kernel(engine: str, **spec_kw):
    """Identically-seeded sharded plane as each engine constructs it, with
    the same queued-task pile on shard 0 (runtime never started)."""
    kw = dict(pods_per_shard=1, rebalance_period_s=1e-3,
              max_migrations_per_round=6)
    kw.update(spec_kw)
    spec = ShardingSpec(**kw)
    sched = make_scheduler("DAM-C", _topo(), seed=11)
    eng = (Simulator(sched, sharding=spec) if engine == "des"
           else ThreadedRuntime(sched, sharding=spec))
    cp = eng.kernel
    tasks = []
    for i in range(10):
        prio = Priority.HIGH if i % 3 == 0 else Priority.LOW
        t = Task(matmul_type(4096), priority=prio)
        cp.queues.push(t, cp.kernels[0].wake(t, i % 4))
        tasks.append(t)
    return cp, tasks


def test_rebalance_decisions_identical_across_engines():
    """plan_round is a pure function of queue state: the DES-built and
    thread-built planes must plan the same moves (same task indices, same
    destinations, same order) and land them on the same cores."""
    moves = {}
    for engine in ("des", "threaded"):
        cp, tasks = _loaded_engine_kernel(engine)
        idx = {t.tid: i for i, t in enumerate(tasks)}
        round_ = cp.rebalancer.plan_round()
        assert round_, engine
        moves[engine] = [(idx[t.tid], dst, cp.migrate_in(t, dst))
                         for t, dst in round_]
    assert moves["des"] == moves["threaded"]


def test_rebalance_parity_across_engines_with_deep_triggers():
    """The criticality-pressure and PTT-divergence passes stay inside the
    plan_round pure-function contract: the DES- and thread-constructed
    planes, identically loaded and with identically-diverged PTTs, must
    plan the same moves in the same order."""
    tname = matmul_type(4096).name
    moves = {}
    for engine in ("des", "threaded"):
        cp, tasks = _loaded_engine_kernel(
            engine, high_pressure_ratio=1.5, ptt_divergence_ratio=1.2,
            max_migrations_per_round=10)
        topo = cp.sched.topology
        # diverge the learned tables identically: shard 0 learned slow,
        # shard 3 fast, for the one queued task type
        for s, val in ((0, 8e-3), (1, 4e-3), (2, 4e-3), (3, 1e-3)):
            tbl = cp.kernels[s].sched.ptt.for_type(tname)
            tbl.update(topo.place_at(cp.shard_cores[s][0], 1), val)
        idx = {t.tid: i for i, t in enumerate(tasks)}
        round_ = cp.rebalancer.plan_round()
        assert round_, engine
        moves[engine] = [(idx[t.tid], dst, cp.migrate_in(t, dst))
                         for t, dst in round_]
    assert moves["des"] == moves["threaded"]


def test_high_pressure_trigger_moves_high_backlog():
    """Balanced total load but HIGH work piled on one shard: the default
    spec plans nothing (total-load trigger is blind to criticality); the
    criticality-pressure trigger sheds the HIGH pile."""
    def build(**kw):
        cp = _plane(seed=21, **kw)
        for i in range(4):      # shard 0: all HIGH
            t = Task(matmul_type(4096), priority=Priority.HIGH)
            cp.queues.push(t, cp.kernels[0].wake(t, i % 4))
        for s in (1, 2, 3):     # same pile elsewhere, all LOW
            for i in range(4):
                t = Task(matmul_type(4096), priority=Priority.LOW)
                cp.queues.push(t, cp.kernels[s].wake(t, cp.shard_cores[s][i]))
        return cp

    cp = build()
    assert cp.rebalancer.plan_round() == []      # loads balanced -> no-op
    cp = build(high_pressure_ratio=1.5)
    round_ = cp.rebalancer.plan_round()
    assert round_
    assert all(t.priority == Priority.HIGH for t, _ in round_)
    assert all(dst != 0 for _, dst in round_)
    # the HIGH backlog actually left shard 0
    high0 = cp.queues.queued_high_s[list(cp.shard_cores[0])].sum()
    assert high0 < 4 * max(t.load_est for t, _ in round_)


def test_ptt_divergence_trigger_shifts_work_to_faster_shard():
    """Loads below the imbalance trigger, but shard 0's learned estimates
    are uniformly worse than shard 1's: the divergence pass drains
    queued work toward the faster-learned shard (and is off by
    default)."""
    tname = matmul_type(4096).name

    def build(**kw):
        cp = _plane(seed=23, imbalance_ratio=10.0, **kw)
        counts = (4, 1, 2, 2)
        for s, n in enumerate(counts):
            for i in range(n):
                t = Task(matmul_type(4096), priority=Priority.LOW)
                cp.queues.push(t, cp.kernels[s].wake(t, cp.shard_cores[s][i]))
        topo = cp.sched.topology
        for s, val in ((0, 8e-3), (1, 1e-3), (2, 4e-3), (3, 4e-3)):
            tbl = cp.kernels[s].sched.ptt.for_type(tname)
            tbl.update(topo.place_at(cp.shard_cores[s][0], 1), val)
        return cp

    assert build().rebalancer.plan_round() == []     # off by default
    cp = build(ptt_divergence_ratio=1.5)
    round_ = cp.rebalancer.plan_round()
    assert round_
    assert all(dst == 1 for _, dst in round_)        # toward the fast learner
    for t, dst in round_:                            # land the moves
        cp.queues.push(t, cp.migrate_in(t, dst))
    loads = cp.shard_loads()
    assert loads[0] <= loads[1] + 1e-9               # drained, no overshoot


def test_rebalancer_migrates_high_before_low():
    cp, tasks = _loaded_engine_kernel("des")
    round_ = cp.rebalancer.plan_round()
    prios = [t.priority for t, _ in round_]
    assert Priority.HIGH in prios
    first_low = next((i for i, p in enumerate(prios) if p == Priority.LOW),
                     len(prios))
    assert all(p == Priority.LOW for p in prios[first_low:])


def test_rebalancer_starvation_regression():
    """LOW work parked on a hot shard must eventually migrate: repeated
    rounds drain the pile toward idle shards instead of leaving it
    starved behind the hot shard's backlog."""
    cp = _plane(seed=13, rebalance_period_s=1e-3,
                max_migrations_per_round=4)
    for i in range(16):
        t = Task(matmul_type(4096), priority=Priority.LOW)
        cp.queues.push(t, cp.kernels[0].wake(t, i % 4))
    assert cp.shard_loads()[0] > 0
    for _ in range(20):                 # bounded: must converge well before
        round_ = cp.rebalancer.plan_round()
        if not round_:
            break
        for t, dst in round_:
            cp.queues.push(t, cp.migrate_in(t, dst))
    loads = cp.shard_loads()
    assert cp.migrations > 0
    # converged: the hot shard is no longer past the imbalance trigger
    assert loads[0] <= cp.spec.imbalance_ratio * (loads.min() + 1e-9)
    # and the parked LOW work actually spread to other shards
    assert sum(loads[1:]) > 0


def test_rebalancer_noop_when_balanced():
    cp = _plane()
    for s in range(4):
        t = Task(matmul_type(4096), priority=Priority.LOW)
        cp.queues.push(t, cp.kernels[s].wake(t, cp.shard_cores[s][0]))
    assert cp.rebalancer.plan_round() == []
    assert cp.migrations == 0


# -- modeled decision latency (DES) ------------------------------------------

def test_flat_kernel_saturates_at_decision_latency():
    """With one modeled decision server, the flat kernel's makespan is
    bounded below by tasks x decision_s — the saturation the sharded
    plane exists to break (N servers)."""
    d, total = 1e-3, 200
    dag = synthetic_dag(matmul_type(1024), parallelism=16, total_tasks=total)
    sched = make_scheduler("DAM-C", _topo(), seed=5)
    flat = simulate(dag, sched,
                    sharding=ShardingSpec(pods_per_shard=4, decision_s=d))
    assert flat.makespan >= total * d * (1 - 1e-9)
    dag2 = synthetic_dag(matmul_type(1024), parallelism=16, total_tasks=total)
    sched2 = make_scheduler("DAM-C", _topo(), seed=5)
    shard = simulate(dag2, sched2,
                     sharding=ShardingSpec(pods_per_shard=1, decision_s=d,
                                           rebalance_period_s=5e-3,
                                           overflow_ratio=2.0))
    assert shard.n_tasks == flat.n_tasks == total
    assert shard.makespan < flat.makespan


def test_sharded_run_reports_migration_metrics():
    dag = synthetic_dag(matmul_type(4096), parallelism=24, total_tasks=400)
    sched = make_scheduler("DAM-C", _topo(), seed=2)
    m = simulate(dag, sched,
                 sharding=ShardingSpec(pods_per_shard=1, decision_s=5e-5,
                                       rebalance_period_s=1e-3,
                                       overflow_ratio=2.0))
    assert m.n_tasks == 400
    assert m.rebalance_rounds > 0
    assert m.migrations + m.overflow_migrations > 0
    # flat runs keep the counters at their zero defaults
    m0 = simulate(synthetic_dag(matmul_type(4096), parallelism=24,
                                total_tasks=400),
                  make_scheduler("DAM-C", _topo(), seed=2))
    assert (m0.migrations, m0.overflow_migrations, m0.rebalance_rounds,
            m0.migrated_load_s) == (0, 0, 0, 0.0)


def test_threaded_sharded_run_completes_and_migrates():
    spec = ShardingSpec(pods_per_shard=1, rebalance_period_s=2e-3,
                        overflow_ratio=2.0)
    from repro.core import run_threaded
    dag = synthetic_dag(matmul_type(256), parallelism=24, total_tasks=300)
    sched = make_scheduler("DAM-C", _topo(), seed=4)
    m = run_threaded(dag, sched, sharding=spec)
    assert m.n_tasks == 300
    assert not m.errors
    assert m.rebalance_rounds >= 0      # timer-paced: count is wall-timing
