"""Fault injection + criticality-aware recovery (``repro.core.faults``).

Covers the tentpole invariants: a disabled model is bit-identical to no
model at all (the zero-cost claim, beyond the golden pins), fail-stop
retries drive every task to commit, retry budgets exhaust into recorded
permanent failures instead of hangs, fail-slow + hedging beats retry-only
on the same seeds, MMPP storms cluster faults, and the DES preemption
notice window is bit-identical at ``notice=0``.  Threaded-engine
regressions (payload-exception hang, heartbeat wiring) live here too.
"""
import time

import pytest

from repro.core import (DAG, Priority, RecoveryPolicy, SpeedProfile, Task,
                        TaskType, corun_chain, make_scheduler, matmul_type,
                        mmpp_faults, pod_slice_preemption, run_threaded,
                        simulate, synthetic_dag, task_faults, tx2)
from repro.runtime.ft import HeartbeatMonitor, Supervisor

N_TASKS = 240


def _run(name="DAM-C", *, faults=None, recovery=None, preemption=None):
    """The golden-schedule workload (interference + DVFS square wave),
    with optional fault/preemption models on top."""
    sched = make_scheduler(name, tx2(), seed=7)
    tt = matmul_type(64)
    dag = synthetic_dag(tt, parallelism=2, total_tasks=N_TASKS)
    speed = SpeedProfile(6).add_square_wave((0, 1), period=0.004, lo=0.17,
                                            t_end=0.2)
    return simulate(dag, sched, background=[corun_chain(tt, core=0)],
                    speed=speed, faults=faults, recovery=recovery,
                    preemption=preemption)


# -- zero-cost / bit-identity -------------------------------------------------

def test_disabled_model_bit_identical_to_none():
    """A FaultModel with all probabilities zero IS the no-model path: same
    makespan to the last bit, same placement histogram, zero counters —
    attaching the subsystem costs nothing until it injects."""
    base = _run(faults=None)
    off = _run(faults=task_faults(seed=3), recovery=RecoveryPolicy(hedge=True))
    assert off.makespan == base.makespan
    assert off.placement_counts() == base.placement_counts()
    assert off.fault_summary() == base.fault_summary()
    assert off.faults_failstop == 0 and off.hedges_launched == 0


def test_fault_runs_are_deterministic():
    """Same seeds -> identical run, faults and hedges included."""
    kw = dict(faults=task_faults(seed=5, p_fail=0.1, p_slow=0.15,
                                 slow_factor=5.0),
              recovery=RecoveryPolicy(hedge=True, backoff_base=1e-4,
                                      backoff_cap=1e-3))
    a, b = _run(**kw), _run(**kw)
    assert a.makespan == b.makespan
    assert a.placement_counts() == b.placement_counts()
    assert a.fault_summary() == b.fault_summary()


# -- fail-stop + retry --------------------------------------------------------

def test_failstop_retries_to_completion():
    m = _run(faults=task_faults(seed=2, p_fail=0.2),
             recovery=RecoveryPolicy(backoff_base=1e-4, backoff_cap=1e-3))
    assert m.n_tasks == N_TASKS                 # every task still commits
    assert m.faults_failstop > 0
    assert m.retries == m.faults_failstop       # budget never exhausted
    assert m.failed_tasks == 0 and not m.errors
    assert m.work_lost_faults_s > 0.0
    # injected faults cost time: strictly slower than the clean run
    assert m.makespan > _run().makespan


def test_retry_budget_exhausts_into_recorded_failure():
    """max_retries=0: first fail-stop is permanent — the run terminates
    (no hang on the un-commitable task) and reports it honestly."""
    m = _run(faults=task_faults(seed=2, p_fail=0.2),
             recovery=RecoveryPolicy(max_retries=0))
    assert m.failed_tasks > 0
    assert m.n_tasks < N_TASKS                  # failed tasks never commit
    assert any("permanently" in e for e in m.errors)
    assert m.retries == 0


# -- fail-slow + hedging ------------------------------------------------------

def test_failslow_hedging_beats_retry_only():
    """The acceptance claim at test scale: on the same fail-slow seeds
    over a heterogeneous fleet (where a PTT-better alternative place
    exists to duplicate onto), speculative duplicates for flagged HIGH
    stragglers shorten the run.  On a small saturated box hedging can
    *lose* — duplicates compete for scarce cores — which is exactly why
    the benchmark sweeps a clean x hedge column too."""
    from repro.core import tpu_pod_slices

    def run_hetero(hedge):
        sched = make_scheduler("DAM-C",
                               tpu_pod_slices(4, 8, kinds=("pod", "pod_v4",
                                                           "pod_v4",
                                                           "pod_v4")),
                               seed=7)
        dag = synthetic_dag(matmul_type(64), parallelism=8,
                            total_tasks=N_TASKS)
        return simulate(dag, sched,
                        faults=task_faults(seed=4, p_slow=0.3,
                                           slow_factor=8.0),
                        recovery=RecoveryPolicy(hedge=hedge))

    plain = run_hetero(False)
    hedged = run_hetero(True)
    assert plain.faults_failslow > 0 and plain.hedges_launched == 0
    assert hedged.stragglers > 0
    assert hedged.hedges_launched > 0
    assert hedged.hedge_wins > 0
    assert hedged.work_hedged_s > 0.0           # losing copies are accounted
    assert hedged.makespan < plain.makespan
    assert hedged.n_tasks == plain.n_tasks == N_TASKS


def test_mmpp_storms_inject():
    m = _run(faults=mmpp_faults(seed=6, t_end=1.0, mean_calm=0.02,
                                mean_storm=0.01, p_fail=0.02, p_slow=0.03,
                                slow_factor=5.0),
             recovery=RecoveryPolicy(backoff_base=1e-4, backoff_cap=1e-3))
    assert m.n_tasks == N_TASKS
    assert m.faults_failstop + m.faults_failslow > 0


# -- preemption notice window -------------------------------------------------

def test_notice_zero_bit_identical():
    pre = lambda notice: pod_slice_preemption(
        tx2(), seed=11, t_end=0.2, mean_up=0.004, mean_down=0.002,
        notice=notice)
    base = _run(preemption=pre(0.0))
    assert base.preempt_events > 0              # revokes land mid-run
    # the notice=0 path must not even differ in float ops from no-notice
    again = _run(preemption=pod_slice_preemption(
        tx2(), seed=11, t_end=0.2, mean_up=0.004, mean_down=0.002))
    assert base.makespan == again.makespan
    assert base.placement_counts() == again.placement_counts()
    # a real grace window lets running tasks finish instead of dying at
    # the revoke edge: fewer preempted tasks, less discarded work, and
    # the run still completes
    graced = _run(preemption=pre(5e-4))
    assert graced.n_tasks == N_TASKS
    assert graced.makespan != base.makespan
    assert graced.tasks_preempted < base.tasks_preempted
    assert graced.work_lost_s < base.work_lost_s


# -- threaded engine ----------------------------------------------------------

def _threaded_dag(n, boom_at=None):
    tt = TaskType("t", {"denver": 2e-3, "a57": 2e-3})
    tasks = []
    for i in range(n):
        def payload(width, _i=i):
            if boom_at is not None and _i == boom_at:
                raise RuntimeError(f"boom {_i}")
            time.sleep(2e-3)
        tasks.append(Task(type=tt, payload=payload,
                          priority=Priority.HIGH if i % 2 == 0
                          else Priority.LOW))
    return DAG(tasks, n)


def test_payload_exception_does_not_hang():
    """Regression: a raising payload used to kill the leader thread mid-
    barrier — members blocked forever and drain() burned its whole
    timeout before returning silently-partial metrics.  Now the failure
    is caught, recorded, and the run returns promptly."""
    sched = make_scheduler("DAM-C", tx2(), seed=1)
    t0 = time.perf_counter()
    m = run_threaded(_threaded_dag(12, boom_at=3), sched, timeout=60.0)
    assert time.perf_counter() - t0 < 20.0      # nowhere near the timeout
    assert m.n_tasks == 11                      # all but the raising task
    assert m.failed_tasks == 1
    assert any("boom 3" in e for e in m.errors)
    assert m.faults_failstop == 0               # real, not injected
    assert not any("workers" in e and "dead" in e for e in m.errors)


def test_threaded_injected_failstop_retries():
    sched = make_scheduler("DAM-C", tx2(), seed=2)
    m = run_threaded(_threaded_dag(24), sched,
                     faults=task_faults(seed=8, p_fail=0.3),
                     recovery=RecoveryPolicy(backoff_base=1e-3,
                                             backoff_cap=5e-3),
                     timeout=60.0)
    assert m.n_tasks == 24
    assert m.faults_failstop > 0
    assert m.retries == m.faults_failstop
    assert not m.errors


def test_heartbeat_supervisor_wiring():
    """Workers beat through the pull loop: a monitor over the real worker
    ids stays healthy; a phantom worker that can never beat is detected
    and surfaces as a recovery event in the metrics."""
    sup = Supervisor(HeartbeatMonitor(list(range(6)), timeout=30.0))
    sched = make_scheduler("DAM-C", tx2(), seed=3)
    m = run_threaded(_threaded_dag(8), sched, supervisor=sup, timeout=60.0)
    assert m.n_tasks == 8 and m.recovery_events == []

    phantom = Supervisor(HeartbeatMonitor(list(range(7)), timeout=1e-6))
    time.sleep(0.01)                            # let worker 6 "miss" beats
    sched = make_scheduler("DAM-C", tx2(), seed=3)
    m = run_threaded(_threaded_dag(8), sched, supervisor=phantom,
                     timeout=60.0)
    assert m.n_tasks == 8
    assert any(e.startswith("failure@") and "6" in e
               for e in m.recovery_events)


def test_threaded_disabled_model_is_none_path():
    sched_a = make_scheduler("DAM-C", tx2(), seed=4)
    a = run_threaded(_threaded_dag(12), sched_a, timeout=60.0)
    sched_b = make_scheduler("DAM-C", tx2(), seed=4)
    b = run_threaded(_threaded_dag(12), sched_b,
                     faults=task_faults(seed=1), timeout=60.0)
    assert a.n_tasks == b.n_tasks == 12
    assert b.fault_summary() == a.fault_summary()


if __name__ == "__main__":
    pytest.main([__file__, "-v"])
