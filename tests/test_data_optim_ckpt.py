"""Data pipeline, optimizer, compression and checkpoint substrates."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from _ht import given, settings, st

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, Prefetcher, SyntheticStream
from repro.optim import (AdamWConfig, apply_updates, compress_int8,
                         compress_topk, init_error_feedback,
                         init_opt_state, schedule, wire_bytes)


# -- data ------------------------------------------------------------------------

def test_stream_deterministic_and_skippable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    s1 = SyntheticStream(cfg)
    batches = [next(s1) for _ in range(5)]
    s2 = SyntheticStream(cfg)
    s2.skip_to(3)
    np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    b = SyntheticStream(cfg).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)


def test_host_sharding_disjoint():
    kw = dict(vocab=100, seq_len=8, global_batch=8, seed=3, num_hosts=2)
    b0 = SyntheticStream(DataConfig(host_index=0, **kw)).batch_at(0)
    b1 = SyntheticStream(DataConfig(host_index=1, **kw)).batch_at(0)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_zipf_statistics():
    cfg = DataConfig(vocab=1000, seq_len=256, global_batch=8)
    toks = SyntheticStream(cfg).batch_at(0)["tokens"].ravel()
    # power law: token 0 much more frequent than median token
    assert (toks == 0).mean() > 20 * (toks == 500).mean()


def test_prefetcher_preserves_order():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    direct = [SyntheticStream(cfg).batch_at(i) for i in range(4)]
    pf = Prefetcher(iter(direct), depth=2)
    for want in direct:
        np.testing.assert_array_equal(next(pf)["tokens"], want["tokens"])
    pf.close()


# -- optimizer ---------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.3, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, info = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.array(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.array(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(schedule(cfg, jnp.array(100))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, warmup_steps=0)
    _, _, info = apply_updates(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(info["grad_norm"]) == pytest.approx(200.0)


def test_mixed_precision_master_copy():
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init_opt_state(params)
    assert "master" in state
    assert state["master"]["w"].dtype == jnp.float32
    cfg = AdamWConfig(lr=1e-4, warmup_steps=0)
    new_p, new_s, _ = apply_updates(params, {"w": jnp.ones(4, jnp.bfloat16)},
                                    state, cfg)
    assert new_p["w"].dtype == jnp.bfloat16


# -- compression --------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=64), st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_int8_error_feedback_invariant(n, scale):
    """Property: decompressed + residual == original + previous residual."""
    g = {"w": jnp.linspace(-scale, scale, n)}
    err = init_error_feedback(g)
    out, new_err = compress_int8(g, err)
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(new_err["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_error_feedback_accumulates_into_next_round():
    g = {"w": jnp.array([0.001, 1.0])}
    err = init_error_feedback(g)
    total = jnp.zeros(2)
    for _ in range(300):
        out, err = compress_int8(g, err)
        total = total + out["w"]
    # long-run average must converge to the true gradient despite int8
    np.testing.assert_allclose(total / 300, g["w"], rtol=0.05, atol=1e-4)


def test_topk_keeps_largest():
    g = {"w": jnp.array([0.1, -5.0, 0.2, 3.0])}
    err = init_error_feedback(g)
    out, new_err = compress_topk(g, err, frac=0.5)
    np.testing.assert_allclose(out["w"], [0.0, -5.0, 0.0, 3.0])
    np.testing.assert_allclose(new_err["w"], [0.1, 0.0, 0.2, 0.0])


def test_wire_bytes_savings():
    g = {"w": jnp.zeros(1000)}
    assert wire_bytes(g, "int8") < wire_bytes(g, "none") / 3.9
    assert wire_bytes(g, "topk", 0.05) <= wire_bytes(g, "none") / 10


# -- checkpoint --------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.array(3)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree, extra={"data": {"step": 10}})
    restored, manifest = ck.restore(_tree())
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])
    assert manifest["step"] == 10
    assert manifest["extra"]["data"]["step"] == 10


def test_checkpoint_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    assert ck.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, _tree())
    ck.wait()
    assert ck.latest_step() == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    bad = {"params": {"w": jnp.zeros((3, 3))}, "opt": {"step": jnp.array(0)}}
    with pytest.raises(ValueError):
        ck.restore(bad)


def test_checkpoint_atomicity_tmp_never_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree())
    # a stale .tmp dir must not be considered a checkpoint
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ck.latest_step() == 5
