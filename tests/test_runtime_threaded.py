"""Threaded (real-execution) runtime: completion, PTT learning, priority
dequeue, seeded steal streams, and wall-clock preemption — the feature-
parity surface of the unified scheduling kernel on the threaded driver."""
import time

import numpy as np

from repro.core import (DAG, PreemptionModel, Priority, ResourcePartition,
                        Task, TaskType, ThreadedRuntime, Topology,
                        make_scheduler, matmul_type, run_threaded,
                        synthetic_dag, tpu_pod_slices, tx2)


def _payload_factory():
    a = np.random.rand(48, 48).astype(np.float32)
    b = np.random.rand(48, 48).astype(np.float32)

    def payload(width):
        (a @ b).sum()

    return payload


def test_completes_all_tasks():
    sched = make_scheduler("DAM-P", tx2(), seed=0)
    dag = synthetic_dag(matmul_type(64), parallelism=3, total_tasks=120)
    for t in dag.all_tasks():
        t.payload = _payload_factory()
    m = run_threaded(dag, sched, timeout=60)
    assert m.n_tasks == 120


def test_ptt_learns_injected_slowdown():
    """With core 0 slowed 5x, the dynamic scheduler's PTT must learn that
    width-1 on core 0 is slower than elsewhere, and route HIGH tasks away."""
    sched = make_scheduler("DAM-P", tx2(), seed=0)
    dag = synthetic_dag(matmul_type(64), parallelism=2, total_tasks=300)
    for t in dag.all_tasks():
        t.payload = _payload_factory()
    m = run_threaded(dag, sched, slowdown={0: 5.0}, timeout=120)
    assert m.n_tasks == 300
    tbl = sched.ptt.for_type("matmul64")
    from repro.core import ExecutionPlace
    slow = tbl.get(ExecutionPlace(0, 1))
    others = [tbl.get(ExecutionPlace(c, 1)) for c in range(1, 6)
              if tbl.visited(ExecutionPlace(c, 1))]
    assert others and slow > 2.0 * min(others)
    pp = m.priority_placement()
    on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
    assert on_c0 < 0.25            # HIGH tasks steered away from slow core


# -- priority dequeue (regression: LOW pushed after HIGH used to run first) --

def _solo_core():
    return Topology([ResourcePartition("solo", "pod", 0, 1, (1,))])


def _sleep_type():
    return TaskType("tiny", {"pod": 1e-3})


def test_pull_serves_high_before_low():
    """With ``priority_dequeue`` set, a worker must serve the oldest HIGH
    from its own queue even when a LOW task was pushed after it (the old
    threaded ``_pull`` popped plain LIFO, so the LOW ran first)."""
    order = []

    def logger(name):
        return lambda width: order.append(name)

    tt = _sleep_type()
    high = Task(tt, priority=Priority.HIGH, payload=logger("high"))
    low = Task(tt, priority=Priority.LOW, payload=logger("low"))
    sched = make_scheduler("DAM-C", _solo_core(), seed=0)
    assert sched.priority_dequeue
    m = run_threaded(DAG([high, low], 2), sched, timeout=30)
    assert m.n_tasks == 2
    assert order == ["high", "low"]


def test_rws_family_keeps_lifo_order():
    """RWS is priority-oblivious: the newest task pops first regardless of
    priority (single mixed-LIFO deque semantics, as in the DES)."""
    order = []

    def logger(name):
        return lambda width: order.append(name)

    tt = _sleep_type()
    high = Task(tt, priority=Priority.HIGH, payload=logger("high"))
    low = Task(tt, priority=Priority.LOW, payload=logger("low"))
    sched = make_scheduler("RWS", _solo_core(), seed=0)
    m = run_threaded(DAG([high, low], 2), sched, timeout=30)
    assert m.n_tasks == 2
    assert order == ["low", "high"]


# -- seeded decision streams ------------------------------------------------

def test_threaded_uses_seeded_tiebreak_stream():
    """``ptt_tiebreak="seeded"`` must give the threaded engine a dedicated
    placement tie-break stream, decoupled from the steal-victim RNG."""
    sched = make_scheduler("DAM-P", tx2(), seed=3, ptt_tiebreak="seeded",
                           ptt_revisit=0.05)
    rt = ThreadedRuntime(sched)
    assert rt.sched.tiebreak_rng is not None
    assert rt.sched.revisit_rng is not None
    # the kernel's victim selection draws from the scheduler's main stream
    rt.queues.push(Task(matmul_type(64)), 2)
    rt.queues.push(Task(matmul_type(64)), 3)
    before = sched.rng.getstate()
    victim = rt.queues.pick_victim(0, sched.rng)
    assert victim in (2, 3)
    assert sched.rng.getstate() != before        # tie-break drew from it


# -- wall-clock preemption ---------------------------------------------------

def _sleep_dag(tt, n, parallelism, dur):
    dag = synthetic_dag(tt, parallelism=parallelism, total_tasks=n)
    for t in dag.all_tasks():
        t.payload = lambda width, _d=dur: time.sleep(_d)
    return dag


def test_threaded_revocation_drains_and_completes():
    """A mid-run pod revocation: everything still completes, and no task
    *starts* on the revoked pod during the outage window (running payloads
    get a grace window instead)."""
    topo = tpu_pod_slices(pods=2, slices_per_pod=2)
    tt = _sleep_type()
    pre = PreemptionModel(((0, 0.06, 0.95),))
    sched = make_scheduler("DAM-C", topo, seed=1)
    dag = _sleep_dag(tt, 80, parallelism=4, dur=4e-3)
    m = run_threaded(dag, sched, preemption=pre, timeout=60)
    assert m.n_tasks == 80
    assert m.preempt_events == 1
    pod0 = set(topo.partitions[0].cores)
    # margin for the timer thread's 10 ms firing granularity
    started_in_outage = [r for r in m.records
                         if r.leader in pod0 and 0.08 < r.t_start < 0.9]
    assert not started_in_outage
    # scheduler live view must not leak out of the run
    assert sched.live is None


def test_threaded_restore_reuses_pod():
    topo = tpu_pod_slices(pods=2, slices_per_pod=2)
    tt = _sleep_type()
    pre = PreemptionModel(((0, 0.02, 0.1),))
    sched = make_scheduler("RWS", topo, seed=2)
    dag = _sleep_dag(tt, 120, parallelism=4, dur=4e-3)
    m = run_threaded(dag, sched, preemption=pre, timeout=60)
    assert m.n_tasks == 120
    pod0 = set(topo.partitions[0].cores)
    assert any(r.leader in pod0 and r.t_start > 0.12 for r in m.records)


def _resumable_payload(task, slices, slice_s, log):
    """Cooperative payload: polls ``task.revoke_signal``, checkpoints by
    returning the completed fraction of its *outstanding* work, and honors
    ``task.resume_frac`` on re-execution by skipping completed work."""

    def payload(width, _t=task):
        todo = max(1, round(slices * _t.resume_frac))
        for i in range(todo):
            time.sleep(slice_s)
            log.append(1)
            if (_t.revoke_signal is not None and _t.revoke_signal.is_set()
                    and i + 1 < todo):
                return (i + 1) / todo
        return None

    return payload


def test_checkpoint_payload_resumes_from_fraction():
    """Checkpoint semantics end-to-end on the threaded engine: a revoked
    cooperative payload keeps its progress (``resume_frac`` shrinks) and
    the re-execution does only the outstanding slice count, vs restart
    which re-runs everything."""
    executed = {}
    for mode in ("checkpoint", "restart"):
        topo = tpu_pod_slices(pods=2, slices_per_pod=1)
        tt = _sleep_type()
        log = []
        task = Task(tt, priority=Priority.LOW)
        task.payload = _resumable_payload(task, slices=10, slice_s=8e-3,
                                          log=log)
        # revoke pod0 (where RWS runs the root) mid-payload
        pre = PreemptionModel(((0, 0.03, 1.0),), preempt=mode,
                              resume_penalty=0.1)
        sched = make_scheduler("RWS", topo, seed=1)
        rt = ThreadedRuntime(sched, preemption=pre)
        rt.submit(DAG([task], 1))
        m = rt.run(timeout=30)
        assert m.n_tasks == 1
        assert m.tasks_preempted == 1
        assert task.preempt_count == 1
        executed[mode] = len(log)
        if mode == "checkpoint":
            # progress kept, plus the 0.1 resume penalty folded in as
            # extra outstanding work (DES parity)
            assert 0.1 < task.resume_frac < 1.0
            assert m.work_lost_s == 0.0
        else:
            assert task.resume_frac == 1.0
            assert m.work_lost_s > 0.0
    # restart re-runs the full 10 slices after the partial attempt;
    # checkpoint only the outstanding remainder
    assert executed["restart"] > executed["checkpoint"]
    assert executed["restart"] >= 10


def test_open_loop_start_drain():
    """start()/drain(): workers stay alive while submissions trickle in
    (outstanding hits 0 between requests), batch totals still complete."""
    topo = tx2()
    tt = _sleep_type()
    sched = make_scheduler("DAM-C", topo, seed=0)
    rt = ThreadedRuntime(sched)
    rt.start()
    for _ in range(5):
        dag = DAG([Task(tt, payload=lambda width: time.sleep(1e-3))], 1)
        rt.submit(dag)
        time.sleep(5e-3)        # long enough for outstanding to reach 0
    m = rt.drain(timeout=30)
    assert m.n_tasks == 5
