"""Threaded (real-execution) runtime: completion + PTT learning."""
import numpy as np

from repro.core import (Priority, make_scheduler, matmul_type, run_threaded,
                        synthetic_dag, tx2)


def _payload_factory():
    a = np.random.rand(48, 48).astype(np.float32)
    b = np.random.rand(48, 48).astype(np.float32)

    def payload(width):
        (a @ b).sum()

    return payload


def test_completes_all_tasks():
    sched = make_scheduler("DAM-P", tx2(), seed=0)
    dag = synthetic_dag(matmul_type(64), parallelism=3, total_tasks=120)
    for t in dag.all_tasks():
        t.payload = _payload_factory()
    m = run_threaded(dag, sched, timeout=60)
    assert m.n_tasks == 120


def test_ptt_learns_injected_slowdown():
    """With core 0 slowed 5x, the dynamic scheduler's PTT must learn that
    width-1 on core 0 is slower than elsewhere, and route HIGH tasks away."""
    sched = make_scheduler("DAM-P", tx2(), seed=0)
    dag = synthetic_dag(matmul_type(64), parallelism=2, total_tasks=300)
    for t in dag.all_tasks():
        t.payload = _payload_factory()
    m = run_threaded(dag, sched, slowdown={0: 5.0}, timeout=120)
    assert m.n_tasks == 300
    tbl = sched.ptt.for_type("matmul64")
    from repro.core import ExecutionPlace
    slow = tbl.get(ExecutionPlace(0, 1))
    others = [tbl.get(ExecutionPlace(c, 1)) for c in range(1, 6)
              if tbl.visited(ExecutionPlace(c, 1))]
    assert others and slow > 2.0 * min(others)
    pp = m.priority_placement()
    on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
    assert on_c0 < 0.25            # HIGH tasks steered away from slow core
