"""Sharding rules + a small-scale dry-run executed in a subprocess (the
device-count flag must not leak into this test process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models import init_params
from repro.parallel.sharding import sanitize


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_sanitize_drops_nondivisible():
    mesh = _FakeMesh({"data": 4, "model": 8})
    assert sanitize(("model", None), (16, 3), mesh) == P("model", None)
    assert sanitize(("model", None), (12, 3), mesh) == P(None, None)
    assert sanitize((("data", "model"), None), (32, 3), mesh) == \
        P(("data", "model"), None)
    assert sanitize((("data", "model"), None), (16, 3), mesh) == P(None, None)


def test_sanitize_pads_rank():
    mesh = _FakeMesh({"data": 2, "model": 2})
    assert sanitize(("model",), (4, 6, 8), mesh) == P("model", None, None)


SUBPROCESS_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.models import init_params, init_decode_state
    from repro.optim import init_opt_state, AdamWConfig
    from repro.parallel import (param_specs, opt_moment_specs, batch_specs,
                                decode_state_specs, to_named, sharding_ctx)
    from repro.train import make_train_step, make_decode_step

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = dataclasses.replace(ARCHS["{arch}"].reduced(), dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    p_shape = jax.eval_shape(lambda: init_params(cfg, key))
    p_spec = param_specs(p_shape, mesh)
    results = {{}}

    # train step
    opt_shape = jax.eval_shape(init_opt_state, p_shape)
    moments = opt_moment_specs(p_shape, mesh)
    o_spec = {{"m": moments, "v": moments, "step": jax.sharding.PartitionSpec()}}
    if "master" in opt_shape:
        o_spec["master"] = moments
    batch = {{"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}}
    if cfg.frontend != "none":
        batch["frontend"] = jax.ShapeDtypeStruct(
            (8, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    b_spec = batch_specs(batch, mesh)
    step = make_train_step(cfg, AdamWConfig(), remat=True)
    with mesh, sharding_ctx(mesh):
        c = jax.jit(step, in_shardings=to_named((p_spec, o_spec, b_spec), mesh)
                    ).lower(p_shape, opt_shape, batch).compile()
    ca = c.cost_analysis()          # dict (jax>=0.5) or list of dicts (older)
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else dict()
    results["train_flops"] = ca.get("flops", 0.0)

    # decode step
    st_shape = jax.eval_shape(lambda: init_decode_state(cfg, 8, 64))
    st_spec = decode_state_specs(st_shape, mesh)
    toks = jax.ShapeDtypeStruct((8,), jnp.int32)
    dstep = make_decode_step(cfg)
    with mesh, sharding_ctx(mesh):
        c2 = jax.jit(dstep, in_shardings=to_named(
            (p_spec, st_spec, batch_specs(toks, mesh)), mesh)
        ).lower(p_shape, st_shape, toks).compile()
    results["decode_ok"] = True
    print(json.dumps(results))
""")


@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-30b-a3b",
                                  "zamba2-1.2b", "xlstm-125m",
                                  "internvl2-76b"])
def test_reduced_dryrun_on_16_fake_devices(arch):
    """lower+compile of train AND decode for a reduced config on a real
    (4,4) mesh — the shape-divisibility/sharding logic must hold end to
    end, not just on the production mesh."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_DRYRUN.format(arch=arch)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["decode_ok"]


def test_param_specs_cover_all_leaves():
    """Every param leaf of every arch gets a spec whose rank matches."""
    from repro.parallel import param_specs
    mesh = _FakeMesh({"data": 4, "model": 4})
    for name, cfg in ARCHS.items():
        r = cfg.reduced()
        p_shape = jax.eval_shape(lambda r=r: init_params(r, jax.random.PRNGKey(0)))
        specs = param_specs(p_shape, mesh)
        leaves_p = jax.tree.leaves(p_shape)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        for lp, ls in zip(leaves_p, leaves_s):
            assert len(ls) <= len(lp.shape), (name, lp.shape, ls)
