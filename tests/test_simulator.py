"""Discrete-event simulator: conservation, determinism, and the paper's
headline interference results."""

from repro.core import (ALL_SCHEDULERS, SpeedProfile, copy_type, corun_chain,
                        dvfs_denver, make_scheduler, matmul_type, simulate,
                        synthetic_dag, tx2)


def _run(name, *, P=2, total=800, background=(), speed=None, seed=1):
    sched = make_scheduler(name, tx2(), seed=seed)
    dag = synthetic_dag(matmul_type(64), parallelism=P, total_tasks=total)
    return simulate(dag, sched, background=list(background), speed=speed)


def test_all_tasks_run_exactly_once():
    for name in ALL_SCHEDULERS:
        m = _run(name)
        assert m.n_tasks == 800, name
        assert m.makespan > 0


def test_deterministic_given_seed():
    a = _run("DAM-C", seed=7)
    b = _run("DAM-C", seed=7)
    assert a.makespan == b.makespan
    assert a.priority_placement() == b.priority_placement()


def test_high_tasks_respect_binding():
    """Non-RWS schedulers: HIGH tasks execute exactly at their bound place
    (paper: stealing of high-priority tasks is disabled)."""
    sched = make_scheduler("DA", tx2(), seed=3)
    dag = synthetic_dag(matmul_type(64), parallelism=2, total_tasks=400)
    m = simulate(dag, sched)
    assert all(r.width == 1 for r in m.records if r.priority == 1)


def test_no_time_travel_and_no_overlap():
    m = _run("DAM-P", total=400)
    busy = {}
    for r in m.records:
        assert r.t_end >= r.t_start >= r.t_ready >= 0
        for c in range(r.leader, r.leader + r.width):
            busy.setdefault(c, []).append((r.t_start, r.t_end))
    for intervals in busy.values():
        intervals.sort()
        for (s1, e1), (s2, e2) in zip(intervals, intervals[1:]):
            assert s2 >= e1 - 1e-9          # no core runs two tasks at once


def test_noncanonical_flags_respect_may_steal():
    """A scheduler outside the 7 canonical configs (no priority dequeue AND
    no HIGH stealing) must still honor may_steal: HIGH tasks execute exactly
    at their binding decision (a steal would have cleared/changed it)."""
    import random

    from repro.core import PTTBank
    from repro.core.schedulers import Scheduler

    topo = tx2()
    sched = Scheduler("X", topo, PTTBank(topo), random.Random(5),
                      dynamic=True, priority_dequeue=False, steal_high=False)
    dag = synthetic_dag(matmul_type(64), parallelism=4, total_tasks=400)
    m = simulate(dag, sched)
    assert m.n_tasks == 400
    for t in dag.all_tasks():
        if t.priority == 1:
            assert t.bound_place is not None and t.place == t.bound_place


def test_corun_interference_ordering():
    """Paper Fig. 4: dynamic schedulers > fixed > random under co-running
    interference, and DA-family avoids the interfered core."""
    bg = [corun_chain(matmul_type(64), core=0)]
    rws = _run("RWS", total=2000, background=bg)
    fa = _run("FA", total=2000, background=bg)
    dam = _run("DAM-C", total=2000, background=bg)
    assert dam.throughput > fa.throughput > rws.throughput
    assert dam.throughput / rws.throughput > 2.0   # paper: up to 3.5x
    pp = dam.priority_placement()
    on_c0 = sum(v for k, v in pp.items() if k.startswith("(C0"))
    assert on_c0 < 0.02                            # paper Fig 5: ~0-2%


def test_dvfs_resilience():
    """Paper Fig. 7: DAM-family beats RWS under DVFS square waves."""
    def run(name):
        sched = make_scheduler(name, tx2(), seed=1)
        dag = synthetic_dag(copy_type(1024), parallelism=2, total_tasks=4000)
        return simulate(dag, sched, speed=dvfs_denver())
    rws = run("RWS")
    dam = run("DAM-P")
    assert dam.throughput > 1.3 * rws.throughput


def test_speed_profile_square_wave():
    prof = SpeedProfile(2).add_square_wave((0,), period=10.0, lo=0.2)
    assert prof.speed(0, 1.0) == 1.0
    assert prof.speed(0, 6.0) == 0.2
    assert prof.speed(0, 11.0) == 1.0
    assert prof.speed(1, 6.0) == 1.0
    bps = prof.breakpoints(30.0)
    assert bps[:3] == [5.0, 10.0, 15.0]


def test_windowed_throughput_reacts_to_interference():
    bg = [corun_chain(matmul_type(64), core=0, t_start=0.0)]
    m = _run("RWS", total=3000, background=bg)
    series = m.windowed_throughput(m.makespan / 10)
    assert len(series) >= 5
