"""Cross-engine agreement: the serve docstring's "byte-identical scheduler
logic" claim, actually pinned.

The same DAG shape and the same injected slowdown go through both drivers
of the unified scheduling kernel — the DES (slowdown as a SpeedProfile)
and the threaded runtime (slowdown as the ``slowdown=`` map) — and the
*placement structure* the scheduler produces must agree within tolerance.
The engines measure different clocks (virtual cost-model time vs noisy
wall time on a shared-cache container), so the pinned quantities are
behavioral aggregates, not exact histograms: where HIGH tasks go, and how
much of the load lands on the interfered core.
"""
import time
from collections import Counter

import pytest

from repro.core import (Priority, RecoveryPolicy, ResourcePartition,
                        Simulator, SpeedProfile, Task, TaskType,
                        ThreadedRuntime, Topology, make_scheduler,
                        matmul_type, run_threaded, simulate, synthetic_dag,
                        task_faults, tx2)
from repro.core.dag import DAG

SLOW_CORE = 0
FACTOR = 5.0
N_TASKS = 300
PAR = 2


def _dag(payload_s=None):
    dag = synthetic_dag(matmul_type(64), parallelism=PAR,
                        total_tasks=N_TASKS)
    if payload_s is not None:
        for t in dag.all_tasks():
            t.payload = lambda width, _d=payload_s: time.sleep(_d)
    return dag


def _des_run(name):
    sched = make_scheduler(name, tx2(), seed=0)
    speed = SpeedProfile(6).add_window([SLOW_CORE], 0.0, float("inf"),
                                       1.0 / FACTOR)
    return simulate(_dag(), sched, speed=speed)


def _threaded_run(name):
    sched = make_scheduler(name, tx2(), seed=0)
    return run_threaded(_dag(payload_s=1.5e-3), sched,
                        slowdown={SLOW_CORE: FACTOR}, timeout=120)


def _high_fraction_on(m, core):
    high = [r for r in m.records if r.priority == 1]
    return sum(1 for r in high if core in
               range(r.leader, r.leader + r.width)) / len(high)


def _work_fraction_on(m, core):
    tot = on = 0
    for r in m.records:
        w = r.duration
        tot += w
        if r.leader <= core < r.leader + r.width:
            on += w
    return on / tot


@pytest.mark.parametrize("name", ["DAM-C", "FA"])
def test_placement_histograms_agree(name):
    des = _des_run(name)
    thr = _threaded_run(name)
    assert des.n_tasks == thr.n_tasks == N_TASKS

    # HIGH placement structure must agree between engines
    des_high = _high_fraction_on(des, SLOW_CORE)
    thr_high = _high_fraction_on(thr, SLOW_CORE)
    if name == "FA":
        # FA is static: HIGH binds to the Denver partition in both engines,
        # interference notwithstanding (that is FA's defining failure mode)
        for m in (des, thr):
            high = [r for r in m.records if r.priority == 1]
            assert all(r.leader in (0, 1) for r in high)
        assert abs(des_high - thr_high) < 0.2
    else:
        # DAM-C steers HIGH tasks off the interfered core in both engines
        assert des_high < 0.1
        assert thr_high < 0.1
    # overall load on the interfered core agrees within tolerance
    assert abs(_work_fraction_on(des, SLOW_CORE)
               - _work_fraction_on(thr, SLOW_CORE)) < 0.25


def test_fault_draw_parity():
    """Constant-rate fault draws are a pure function of (model seed, BFS
    fault_seq, attempt count) — the clock never enters — so both engines
    must inject the exact same fail-stops and perform the same retries
    on the same DAG shape."""
    des = simulate(_dag(), make_scheduler("DAM-C", tx2(), seed=0),
                   faults=task_faults(seed=3, p_fail=0.25),
                   recovery=RecoveryPolicy(backoff_base=1e-5,
                                           backoff_cap=1e-4))
    thr = run_threaded(_dag(payload_s=1e-3),
                       make_scheduler("DAM-C", tx2(), seed=0),
                       faults=task_faults(seed=3, p_fail=0.25),
                       recovery=RecoveryPolicy(backoff_base=1e-3,
                                               backoff_cap=5e-3),
                       timeout=120)
    assert des.n_tasks == thr.n_tasks == N_TASKS
    assert des.faults_failstop == thr.faults_failstop > 0
    assert des.retries == thr.retries == des.faults_failstop
    assert des.failed_tasks == thr.failed_tasks == 0
    assert not des.errors and not thr.errors


def test_dam_c_learns_same_relative_speeds():
    """Both engines' PTTs must rank the interfered core as slow relative
    to its partition peers (same table, different measurement sources)."""
    from repro.core import ExecutionPlace
    ratios = []
    for m_run in (_des_run, _threaded_run):
        sched_name = "DAM-C"
        if m_run is _des_run:
            sched = make_scheduler(sched_name, tx2(), seed=0)
            speed = SpeedProfile(6).add_window([SLOW_CORE], 0.0,
                                               float("inf"), 1.0 / FACTOR)
            simulate(_dag(), sched, speed=speed)
        else:
            sched = make_scheduler(sched_name, tx2(), seed=0)
            run_threaded(_dag(payload_s=1.5e-3), sched,
                         slowdown={SLOW_CORE: FACTOR}, timeout=120)
        tbl = sched.ptt.for_type("matmul64")
        slow = tbl.get(ExecutionPlace(SLOW_CORE, 1))
        peer = tbl.get(ExecutionPlace(1, 1))
        assert slow > 0 and peer > 0
        ratios.append(slow / peer)
    # interfered core measured several-x slower than its peer in both
    assert all(r > 2.0 for r in ratios)


# -- load-aware placement: the herding regression ------------------------------
# Four single-core partitions of distinct kinds with strictly ordered
# priors, so a primed PTT has a *unique* argmin: without a queue penalty
# every simultaneous HIGH wake binds to that one place (herding — the
# failure mode behind the old serve slow_fast_pod loss); with the
# penalty, each wake sees the charges of the previous ones and spreads.
_BURST_PRIORS = {"denver": 1.0e-3, "a57": 1.2e-3,
                 "haswell": 1.4e-3, "pod": 1.6e-3}
_N_BURST = 8


def _burst_fleet():
    return Topology([
        ResourcePartition(f"s{i}", kind, i, 1, (1,), static_rank=i)
        for i, kind in enumerate(_BURST_PRIORS)])


def _burst_dag(payload_s=None):
    tt = TaskType("hburst", serial_time=dict(_BURST_PRIORS))
    root_t = TaskType("hroot",
                      serial_time={k: 1e-4 for k in _BURST_PRIORS})
    highs = [Task(tt, priority=Priority.HIGH) for _ in range(_N_BURST)]
    root = Task(root_t, priority=Priority.LOW)
    if payload_s is not None:
        root.payload = lambda width: None
        for t in highs:
            t.payload = lambda width, _d=payload_s: time.sleep(_d)
    root.on_commit = lambda _t: highs
    return tt, DAG([root], 1 + _N_BURST)


def _burst_leaders(engine: str, queue_penalty: float) -> Counter:
    sched = make_scheduler("DAM-C", _burst_fleet(), seed=0,
                           queue_penalty=queue_penalty, track_load=True)
    tt, dag = _burst_dag(payload_s=None if engine == "des" else 1e-3)
    if engine == "des":
        sim = Simulator(sched)
        sim.kernel.prime_ptt(tt)
        sim.submit(dag)
        m = sim.run()
    else:
        rt = ThreadedRuntime(sched)
        rt.kernel.prime_ptt(tt)
        rt.submit(dag)
        m = rt.run(timeout=60)
    assert m.n_tasks == 1 + _N_BURST
    return Counter(r.leader for r in m.records if r.type_name == "hburst")


@pytest.mark.parametrize("engine", ["des", "threaded"])
def test_simultaneous_high_wakes_spread_with_queue_penalty(engine):
    herd = _burst_leaders(engine, queue_penalty=0.0)
    spread = _burst_leaders(engine, queue_penalty=1.0)
    # penalty off: the unique primed argmin swallows the whole burst
    assert herd == {0: _N_BURST}
    # penalty on: the burst spreads across most of the fleet
    assert len(spread) >= 3
    assert max(spread.values()) <= _N_BURST // 2


def test_burst_spread_agrees_across_engines():
    """Wake-time binding happens before any burst task executes in both
    engines, so the load-aware placement multiset must agree exactly."""
    assert (_burst_leaders("des", 1.0)
            == _burst_leaders("threaded", 1.0))
