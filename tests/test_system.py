"""End-to-end behaviour tests for the paper's system.

1. The full interference experiment (paper Fig. 4/5, in miniature):
   all 7 schedulers over the same synthetic DAG under a co-runner —
   ordering and placement must reproduce the paper's findings.
2. A complete train->checkpoint->restore->serve round trip on a reduced
   architecture using only the public API.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import (ALL_SCHEDULERS, corun_chain, make_scheduler,
                        matmul_type, simulate, synthetic_dag, tx2)
from repro.data import DataConfig
from repro.models import decode_step
from repro.models.transformer import prefill
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_paper_experiment_end_to_end():
    results = {}
    for name in ALL_SCHEDULERS:
        sched = make_scheduler(name, tx2(), seed=1)
        dag = synthetic_dag(matmul_type(64), parallelism=2, total_tasks=2000)
        m = simulate(dag, sched,
                     background=[corun_chain(matmul_type(64), core=0)])
        results[name] = m
    tput = {k: m.throughput for k, m in results.items()}
    # paper ordering: dynamic > fixed > random
    assert tput["DAM-C"] > tput["FA"] > tput["RWS"]
    assert tput["DA"] > tput["FAM-C"]
    # paper Fig 5: FA pins 50% of criticals on the interfered core,
    # the dynamic schedulers essentially none
    fa_pp = results["FA"].priority_placement()
    dam_pp = results["DAM-C"].priority_placement()
    assert sum(v for k, v in fa_pp.items() if k.startswith("(C0")) > 0.45
    assert sum(v for k, v in dam_pp.items() if k.startswith("(C0")) < 0.02


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = ARCHS["musicgen-large"].reduced()
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=5)
    trainer = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6),
                      data, TrainerConfig(total_steps=6, checkpoint_every=3,
                                          log_every=100),
                      str(tmp_path))
    hist = trainer.run()
    assert len(hist) == 6
    assert all(np.isfinite(h["loss"]) for h in hist)

    # restore into a fresh model and serve one request from it
    fresh = Trainer(cfg, AdamWConfig(total_steps=6), data,
                    TrainerConfig(total_steps=6), str(tmp_path))
    assert fresh.try_restore()
    params = fresh.params
    fe = jnp.zeros((1, cfg.frontend_len, cfg.d_model))
    prompt = jnp.arange(8, dtype=jnp.int32)[None, :]
    logits, state = prefill(params, cfg, prompt, max_len=32, frontend=fe)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = []
    for _ in range(4):
        logits, state = decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    assert len(out) == 4
    assert all(0 <= t < cfg.vocab for t in out)
