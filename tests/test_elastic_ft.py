"""Elastic runtime + fault tolerance: straggler detection, rescale plans,
heartbeats, and exact checkpoint-restart resume."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import HeartbeatMonitor, PodMonitor, Supervisor
from repro.train.trainer import Trainer, TrainerConfig


# -- PodMonitor (the paper's PTT applied to the fleet) ---------------------------

def test_straggler_detected_with_hysteresis():
    mon = PodMonitor(n_pods=4)
    for _ in range(5):
        for p in range(4):
            mon.observe(p, 1.0)
    assert mon.plan().kind == "none"
    # pod 2 degrades 1.6x: one bad reading must NOT trigger (1:4 weighting)
    mon.observe(2, 1.6)
    assert mon.plan().kind == "none"
    for _ in range(4):
        mon.observe(2, 1.6)
    plan = mon.plan()
    assert plan.kind == "rebalance"
    # slower pod gets fewer microbatches
    mb = mon.microbatches_per_pod(32, plan)
    assert sum(mb) == 32
    assert mb[2] == min(mb)


def test_drain_and_restore():
    mon = PodMonitor(n_pods=4)
    for _ in range(5):
        for p in range(4):
            mon.observe(p, 1.0)
    for _ in range(10):
        mon.observe(1, 5.0)              # way past drain_ratio x median
    plan = mon.plan()
    assert plan.kind == "drain"
    assert 1 not in plan.active_pods
    # pod recovers
    for _ in range(30):
        mon.observe(1, 1.0)
    plan2 = mon.plan()
    assert plan2.kind == "restore"
    assert 1 in plan2.active_pods


def test_rebalance_shares_inverse_to_time():
    mon = PodMonitor(n_pods=2)
    for _ in range(10):
        mon.observe(0, 1.0)
        mon.observe(1, 2.0)
    plan = mon.plan()
    assert plan.kind == "rebalance"
    s0, s1 = plan.microbatch_share
    assert s0 == pytest.approx(2 * s1, rel=1e-6)


# -- heartbeats --------------------------------------------------------------------

def test_heartbeat_failure_and_recovery():
    t = [0.0]
    hb = HeartbeatMonitor([0, 1], timeout=5.0, clock=lambda: t[0])
    t[0] = 4.0
    hb.beat(0)
    t[0] = 7.0
    assert hb.failed_workers() == {1}
    hb.beat(1)
    assert hb.healthy() is False or hb.failed_workers() == set()
    sup = Supervisor(heartbeat=hb)
    t[0] = 20.0
    assert sup.check(step=10) == "restart"
    assert sup.events and sup.events[0].kind == "failure"


# -- checkpoint/restart exactness ---------------------------------------------------

def _mk_trainer(tmp_path, steps, seed=0, horizon=8):
    """``steps`` is where this trainer STOPS; ``horizon`` is the schedule's
    total_steps — it must be identical across crash/resume runs or the
    cosine LR (and therefore the losses) would legitimately differ."""
    cfg = ARCHS["xlstm-125m"].reduced()
    return Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2,
                                    total_steps=horizon),
                   DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2,
                              seed=11),
                   TrainerConfig(total_steps=steps, checkpoint_every=4,
                                 log_every=100, seed=seed),
                   str(tmp_path))


def test_restart_resumes_exactly(tmp_path):
    # uninterrupted run
    t_full = _mk_trainer(tmp_path / "a", steps=8)
    full = t_full.run()
    # interrupted: run 8 but pretend the process died after the step-4 ckpt
    t_crash = _mk_trainer(tmp_path / "b", steps=4)
    t_crash.run()
    t_resume = _mk_trainer(tmp_path / "b", steps=8)
    assert t_resume.try_restore()
    assert t_resume.step == 4
    resumed = t_resume.run()
    # losses of steps 5..8 must match the uninterrupted run exactly
    want = [r["loss"] for r in full if r["step"] > 4]
    got = [r["loss"] for r in resumed]
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_trainer_detects_injected_straggler(tmp_path):
    def pod_time(step, pod):
        return 3.0 if (pod == 1 and step > 5) else 1.0

    t = _mk_trainer(tmp_path, steps=14)
    t.pod_time_fn = pod_time
    t.run()
    kinds = [e.kind for e in t.supervisor.events]
    assert "rescale" in kinds


def test_pod_monitor_live_view_bridges_to_scheduler():
    """A drained pod is expressed as the same interned LiveView a revoked
    pod-slice produces, and ``apply_to`` hands it to a scheduler driving
    either engine."""
    from repro.core import Priority, Task, make_scheduler, matmul_type

    mon = PodMonitor(n_pods=4, slices_per_pod=4)
    assert mon.live_view() is None
    for _ in range(5):
        for p in range(4):
            mon.observe(p, 1.0)
    for _ in range(10):
        mon.observe(1, 5.0)
    assert mon.plan().kind == "drain"
    view = mon.live_view()
    assert view is not None
    assert view is mon.topology.live_view(frozenset({1}))   # interned
    assert [p.name for p in view.partitions] == ["pod0", "pod2", "pod3"]

    sched = make_scheduler("DAM-C", mon.topology, seed=0)
    mon.apply_to(sched)
    assert sched.live is view
    down = set(mon.topology.partitions[1].cores)
    for _ in range(10):
        t = Task(matmul_type(512), priority=Priority.HIGH)
        sched.place_on_wake(t, 0)
        assert not set(t.bound_place.cores) & down

    # the mask must survive engine construction (begin_run) and hold for
    # a whole run: no HIGH (bound-placement) work lands on the drained
    # pod.  LOW tasks may still be *stolen* by its idle cores — drain
    # masks placement; removing cores outright is the preemption
    # subsystem's job.
    from repro.core import simulate, synthetic_dag
    mon.apply_to(sched)
    m = simulate(synthetic_dag(matmul_type(512), parallelism=8,
                               total_tasks=200), sched)
    assert m.n_tasks == 200
    assert not any(r.leader in down for r in m.records if r.priority == 1)
    assert sched.live is None          # engines clear the mask at run end

    other = make_scheduler("DAM-C", PodMonitor(n_pods=2).topology, seed=0)
    with pytest.raises(ValueError):
        mon.apply_to(other)
