"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _ht import given, settings, st

from repro.kernels import ref
from repro.kernels.copy import copy_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.stencil import stencil_pallas

KEY = jax.random.PRNGKey(0)


def _k(i):
    return jax.random.fold_in(KEY, i)


# -- matmul -------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (512, 256, 256), (128, 512, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a = jax.random.normal(_k(1), (m, k), dtype)
    b = jax.random.normal(_k(2), (k, n), dtype)
    got = matmul_pallas(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(got.astype(np.float32), want.astype(np.float32),
                               rtol=tol, atol=tol)


def test_matmul_rejects_unaligned():
    a = jax.random.normal(_k(1), (100, 128))
    b = jax.random.normal(_k(2), (128, 128))
    with pytest.raises(ValueError):
        matmul_pallas(a, b, interpret=True)


# -- copy ----------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(512, 1024), (1024, 2048), (64, 128)])
def test_copy_sweep(shape):
    x = jax.random.normal(_k(3), shape)
    np.testing.assert_array_equal(copy_pallas(x, interpret=True),
                                  ref.copy_ref(x))


# -- stencil -------------------------------------------------------------------

@pytest.mark.parametrize("b,h,w,bh,bw", [(1, 256, 256, 128, 128),
                                         (2, 512, 256, 256, 128),
                                         (1, 128, 128, 128, 128)])
def test_stencil_sweep(b, h, w, bh, bw):
    u = jax.random.normal(_k(4), (b, h, w))
    got = stencil_pallas(u, bh=bh, bw=bw, interpret=True)
    np.testing.assert_allclose(got, ref.stencil_ref(u), rtol=1e-5, atol=1e-5)


def test_stencil_boundary_is_dirichlet():
    u = jnp.ones((1, 128, 128))
    out = stencil_pallas(u, interpret=True)
    # interior average of 4 ones = 1; corners see two zero neighbors
    assert out[0, 0, 0] == pytest.approx(0.5)
    assert out[0, 64, 64] == pytest.approx(1.0)


# -- flash attention -------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("s,t", [(256, 256), (128, 512)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(hq, hkv, s, t, causal):
    q = jax.random.normal(_k(5), (2, hq, s, 64))
    k = jax.random.normal(_k(6), (2, hkv, t, 64))
    v = jax.random.normal(_k(7), (2, hkv, t, 64))
    got = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jax.random.normal(_k(8), (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(_k(9), (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(_k(10), (1, 2, 128, 64), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, bq=128, bk=128, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), rtol=3e-2, atol=3e-2)


def test_chunked_xla_attention_matches():
    q = jax.random.normal(_k(11), (2, 4, 256, 32))
    k = jax.random.normal(_k(12), (2, 2, 384, 32))
    v = jax.random.normal(_k(13), (2, 2, 384, 32))
    for causal in (True, False):
        got = ref.attention_chunked_ref(q, k, v, causal=causal, q_chunk=64)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# -- SSD scan --------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(128, 64), (256, 128), (256, 64)])
@pytest.mark.parametrize("h,d,n", [(4, 32, 16), (2, 64, 32)])
def test_ssd_sweep(s, chunk, h, d, n):
    x = jax.random.normal(_k(14), (2, s, h, d)) * 0.5
    a = -jnp.abs(jax.random.normal(_k(15), (2, s, h))) * 0.1
    b = jax.random.normal(_k(16), (2, s, n)) * 0.5
    c = jax.random.normal(_k(17), (2, s, n)) * 0.5
    got = ssd_scan_pallas(x, a, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, a, b, c)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


@given(st.integers(min_value=1, max_value=3).map(lambda i: 64 * i),
       st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_property_random_shapes(s, h, n):
    """Property: chunked kernel == sequential oracle across random shapes."""
    x = jax.random.normal(_k(s + h), (1, s, h, 16)) * 0.3
    a = -jnp.abs(jax.random.normal(_k(s + h + 1), (1, s, h))) * 0.2
    b = jax.random.normal(_k(s + h + 2), (1, s, n)) * 0.4
    c = jax.random.normal(_k(s + h + 3), (1, s, n)) * 0.4
    got = ssd_scan_pallas(x, a, b, c, chunk=64, interpret=True)
    want = ref.ssd_ref(x, a, b, c)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


# -- ops dispatch ----------------------------------------------------------------

def test_ops_force_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    from repro.kernels import ops
    a = jax.random.normal(_k(20), (256, 256))
    b = jax.random.normal(_k(21), (256, 256))
    np.testing.assert_allclose(ops.matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


def test_ops_cpu_falls_back_to_ref():
    from repro.kernels import ops
    q = jax.random.normal(_k(22), (1, 2, 64, 32))
    k = jax.random.normal(_k(23), (1, 2, 64, 32))
    v = jax.random.normal(_k(24), (1, 2, 64, 32))
    out = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                               rtol=1e-5, atol=1e-5)
