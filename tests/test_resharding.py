"""Online re-sharding (DESIGN.md §"Control plane"): regroup the fleet's
pods into shards mid-run — pods joining or leaving — without pausing the
engines.  Pinned here: the DES event path (``reshard_at``), the threaded
runtime's ``reshard()``, scalar/cohort event-loop parity across a reshard
event, plane bookkeeping, and the flat-kernel guard rails."""
import pytest

from repro.core import (ShardingSpec, make_scheduler, matmul_type, simulate,
                        synthetic_dag, tpu_pod_slices)


def _topo():
    return tpu_pod_slices(pods=4, slices_per_pod=4)


def _dag(total=400):
    return synthetic_dag(matmul_type(1024), parallelism=16,
                         total_tasks=total)


def _run(*, sharding, reshard_at=(), event_mode="cohort", seed=3):
    sched = make_scheduler("DAM-C", _topo(), seed=seed)
    return simulate(_dag(), sched, sharding=sharding,
                    reshard_at=reshard_at, event_mode=event_mode)


def test_des_reshard_event_completes_and_counts():
    """A mid-run regroup from 2-pod shards to per-pod shards: every task
    still commits exactly once, the round is counted, and the schedule
    past the event keeps making progress on all four pods."""
    spec = ShardingSpec(pods_per_shard=2)
    base = _run(sharding=spec)
    assert base.reshard_rounds == 0
    t_evt = 0.4 * base.makespan
    m = _run(sharding=spec, reshard_at=((t_evt, 1),))
    assert m.reshard_rounds == 1
    assert m.n_tasks == base.n_tasks == 400
    assert not m.errors
    # post-event commits exist and land across the regrouped fleet
    late = [r for r in m.records if r.t_start >= t_evt]
    assert late and len({r.leader // 4 for r in late}) >= 2


def test_des_reshard_scalar_cohort_parity():
    """The reshard event fires identically on both event loops — the
    cohort loop's golden-schedule guarantee extends across regrouping."""
    spec = ShardingSpec(pods_per_shard=2)
    t_evt = 0.4 * _run(sharding=spec).makespan
    runs = [_run(sharding=spec, reshard_at=((t_evt, 1),), event_mode=mode)
            for mode in ("scalar", "cohort")]
    a, b = runs
    assert a.makespan == b.makespan
    assert [(r.type_name, r.leader, r.width, r.t_start, r.t_end)
            for r in a.records] == \
        [(r.type_name, r.leader, r.width, r.t_start, r.t_end)
         for r in b.records]
    assert a.reshard_rounds == b.reshard_rounds == 1


def test_des_multiple_reshards_grow_and_shrink():
    """Grow (2-pod shards -> per-pod) then consolidate back: stale shard
    ids from the wider grouping must stay harmless after the shrink."""
    spec = ShardingSpec(pods_per_shard=2)
    mk = _run(sharding=spec).makespan
    m = _run(sharding=spec,
             reshard_at=((0.3 * mk, 1), (0.6 * mk, 2)))
    assert m.reshard_rounds == 2
    assert m.n_tasks == 400 and not m.errors


def test_des_reshard_requires_sharded_plane():
    with pytest.raises(ValueError, match="sharded control plane"):
        _run(sharding=None, reshard_at=((0.1, 1),))


def test_plane_reshard_validation_and_bookkeeping():
    from repro.core import make_control_plane
    sched = make_scheduler("DAM-C", _topo(), seed=0)
    plane = make_control_plane(sched, now=lambda: 0.0,
                               sharding=ShardingSpec(pods_per_shard=2))
    assert plane.n_shards == 2
    with pytest.raises(ValueError):
        plane.reshard(0)
    with pytest.raises(ValueError, match="single shard"):
        plane.reshard(4)                 # would collapse to 1 shard
    moves = plane.reshard(1)             # empty plane: nothing to migrate
    assert moves == [] and plane.n_shards == 4
    assert plane.reshard_rounds == 1


def test_threaded_reshard_mid_run():
    """The threaded runtime regroups under its own lock mid-drain: all
    tasks commit, the plane reports the round, and the run ends clean."""
    import time

    from repro.core import ThreadedRuntime
    sched = make_scheduler("DAM-C", _topo(), seed=5)
    rt = ThreadedRuntime(sched, sharding=ShardingSpec(pods_per_shard=2))
    dag = _dag(total=600)
    for t in dag.all_tasks():
        t.payload = lambda width: time.sleep(2e-4)
    rt.submit(dag)
    rt.start()
    time.sleep(0.02)                     # let the fleet get mid-schedule
    rt.reshard(1)
    m = rt.drain(timeout=120)
    assert m.n_tasks == 600 and not m.errors
    assert m.reshard_rounds == 1
    assert rt.kernel.n_shards == 4


def test_threaded_reshard_requires_sharded_plane():
    from repro.core import ThreadedRuntime
    rt = ThreadedRuntime(make_scheduler("DAM-C", _topo(), seed=0))
    with pytest.raises(ValueError, match="sharded control plane"):
        rt.reshard(1)
