"""Unit tests for the engine-agnostic scheduling kernel (core/queues.py +
core/lifecycle.py) — the structure both execution engines drive."""
import random
import threading

import pytest

from repro.core import (Priority, SchedulingKernel, SplitWSQ, Task, WorkQueues,
                        make_scheduler, matmul_type, split_by_priority, tx2)


def _task(prio=Priority.LOW):
    return Task(matmul_type(64), priority=prio)


# -- WorkQueues --------------------------------------------------------------

def test_routing_priority_aware():
    """Priority-dequeue schedulers route HIGH to the split HIGH FIFO."""
    q = WorkQueues(2, priority_dequeue=True, steal_high=False)
    assert q.route_high
    h, low = _task(Priority.HIGH), _task()
    q.push(h, 0)
    q.push(low, 0)
    assert list(q.wsq[0].high) == [h]
    assert list(q.wsq[0].low) == [low]
    # HIGH is not stealable and does not count as stealable
    assert not q.stealable(h) and q.stealable(low)
    assert q.stealable_count(0) == 1


def test_routing_priority_oblivious():
    """The RWS family (steal HIGH, no priority dequeue) keeps one mixed
    LIFO deque so its ordering is exactly the classic work-stealing one."""
    q = WorkQueues(2, priority_dequeue=False, steal_high=True)
    assert not q.route_high
    h, low = _task(Priority.HIGH), _task()
    q.push(h, 0)
    q.push(low, 0)
    assert list(q.wsq[0].low) == [h, low]
    assert q.stealable_count(0) == 2
    assert q.pop_local(0) is low               # newest first (LIFO)
    assert q.pop_local(0) is h
    assert q.pop_local(0) is None


def test_pop_local_priority_order():
    q = WorkQueues(1, priority_dequeue=True, steal_high=False)
    h1, h2, l1, l2 = (_task(Priority.HIGH), _task(Priority.HIGH),
                      _task(), _task())
    for t in (l1, h1, l2, h2):
        q.push(t, 0)
    # oldest HIGH first, then LOW LIFO (newest first)
    assert [q.pop_local(0) for _ in range(4)] == [h1, h2, l2, l1]


def test_steal_pop_oldest_stealable():
    q = WorkQueues(2, priority_dequeue=True, steal_high=False)
    l1, l2 = _task(), _task()
    q.push(l1, 0)
    q.push(l2, 0)
    assert q.steal_pop(0) is l1                # FIFO end feeds thieves


def test_pick_victim_max_and_seeded_tiebreak():
    q = WorkQueues(4, priority_dequeue=True, steal_high=False)
    q.push(_task(), 1)
    q.push(_task(), 2)
    q.push(_task(), 2)
    assert q.pick_victim(0, random.Random(0)) == 2     # strictly most loaded
    q.push(_task(), 1)
    # 1 and 2 tie at 2 stealable: the pick is a seeded draw — deterministic
    # for a given stream, covering both candidates across streams
    picks = {q.pick_victim(0, random.Random(s)) for s in range(16)}
    assert picks == {1, 2}
    r1, r2 = random.Random(7), random.Random(7)
    assert q.pick_victim(0, r1) == q.pick_victim(0, r2)
    # HIGH tasks don't attract thieves when not stealable
    q2 = WorkQueues(2, priority_dequeue=True, steal_high=False)
    q2.push(_task(Priority.HIGH), 1)
    assert q2.pick_victim(0, random.Random(0)) == -1


def test_drain_wsq_steal_order():
    q = WorkQueues(2, priority_dequeue=True, steal_high=False)
    h1, h2, l1, l2 = (_task(Priority.HIGH), _task(Priority.HIGH),
                      _task(), _task())
    for t in (l1, h1, l2, h2):
        q.push(t, 0)
    q.push(_task(), 1)                         # other cores untouched
    drained = q.drain_wsq([0])
    assert drained == [h1, h2, l1, l2]         # HIGH FIFO, then LOW oldest
    assert len(q.wsq[0]) == 0
    assert len(q.wsq[1]) == 1


def test_split_wsq_len():
    w = SplitWSQ()
    w.high.append(_task(Priority.HIGH))
    w.low.append(_task())
    assert len(w) == 2


# -- SchedulingKernel --------------------------------------------------------

def test_kernel_resets_run_state_on_construction():
    sched = make_scheduler("FA", tx2(), seed=0)
    sched.place_on_wake(_task(Priority.HIGH), 0)
    assert sched._fa_rr == 1
    view = object()
    sched.live = view
    SchedulingKernel(sched, now=lambda: 0.0)
    assert sched._fa_rr == 0
    # a pre-applied availability mask (PodMonitor.apply_to) must survive
    # engine construction — only end_run clears it
    assert sched.live is view


def test_kernel_wake_stamps_and_routes():
    now = [2.5]
    sched = make_scheduler("DA", tx2(), seed=0)
    kern = SchedulingKernel(sched, now=lambda: now[0])
    low = _task()
    assert kern.wake(low, waker_core=3) == 3   # LOW stays with the waker
    assert low.t_ready == 2.5
    high = _task(Priority.HIGH)
    core = kern.wake(high, waker_core=3)
    assert core == high.bound_place.leader


def test_kernel_commit_successors_order_and_dynamic_growth():
    sched = make_scheduler("RWS", tx2(), seed=0)
    kern = SchedulingKernel(sched, now=lambda: 0.0)
    parent, c1, c2 = _task(), _task(), _task()
    parent.add_child(c1)
    parent.add_child(c2)
    other = _task()
    other.add_child(c2)                        # c2 has a second parent
    dyn = _task()
    parent.on_commit = lambda t: [dyn]
    assert list(kern.commit_successors(parent)) == [c1, dyn]
    assert c2.n_deps == 1                      # not ready yet
    assert list(kern.commit_successors(other)) == [c2]


def test_kernel_commit_successors_locked_decrement():
    """The threaded engine passes a lock guarding each n_deps decrement;
    concurrent committers sharing a child must release it exactly once."""
    sched = make_scheduler("RWS", tx2(), seed=0)
    kern = SchedulingKernel(sched, now=lambda: 0.0)
    child = _task()
    parents = [_task() for _ in range(8)]
    for p in parents:
        p.add_child(child)
    lock = threading.Lock()
    ready = []
    threads = [threading.Thread(
        target=lambda p=p: ready.extend(kern.commit_successors(p, lock=lock)))
        for p in parents]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert ready == [child]


def test_kernel_requeue_uses_live_view():
    from repro.core import tpu_pod_slices
    topo = tpu_pod_slices(pods=2, slices_per_pod=4)
    sched = make_scheduler("RWS", topo, seed=5)
    kern = SchedulingKernel(sched, now=lambda: 1.0)
    assert kern.live_cores() == tuple(range(8))
    sched.live = topo.live_view(frozenset({0}))
    assert kern.live_cores() == topo.partitions[1].cores
    t = _task()
    t.bound_place = object()
    core = kern.requeue_displaced(t)
    assert core in topo.partitions[1].cores
    assert t.bound_place is None
    assert t.t_ready == 1.0
    kern.end_run()
    assert sched.live is None


def test_split_by_priority_stable():
    h1, h2 = _task(Priority.HIGH), _task(Priority.HIGH)
    l1, l2 = _task(), _task()
    high, low = split_by_priority([l1, h1, l2, h2])
    assert high == [h1, h2]
    assert low == [l1, l2]


def test_simulated_observation_matches_des_model():
    """Noise draw sequence: gauss (clamped), then spike — and no draw at
    all for noiseless types (the DES golden pins depend on this)."""
    sched = make_scheduler("RWS", tx2(), seed=9)
    kern = SchedulingKernel(sched, now=lambda: 0.0)
    from repro.core import TaskType
    silent = TaskType("silent", {"denver": 1.0, "a57": 1.0})
    state = sched.rng.getstate()
    assert kern.observe_simulated(silent, 2.0) == 2.0
    assert sched.rng.getstate() == state       # no draws for noiseless types
    noisy = matmul_type(64)
    obs = kern.observe_simulated(noisy, 2.0)
    assert 1.0 <= obs <= 2.0 * 2.0 * noisy.spike_mag
    assert sched.rng.getstate() != state


def test_observation_clamp():
    """The multiplicative noise clamp [0.5, 2.0] bounds any observation."""
    sched = make_scheduler("RWS", tx2(), seed=1)
    kern = SchedulingKernel(sched, now=lambda: 0.0)
    from repro.core import TaskType
    tt = TaskType("wild", {"denver": 1.0, "a57": 1.0}, noise=50.0)
    for _ in range(200):
        assert 0.5 <= kern.observe_simulated(tt, 1.0) <= 2.0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
