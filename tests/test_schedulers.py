"""Scheduler behaviors (paper Table 1 + Algorithm 1)."""
import pytest

from repro.core import (ExecutionPlace, Priority, Task, make_scheduler,
                        matmul_type, tx2)


def _warm(sched, task_type, times):
    """Seed the PTT with place -> time."""
    tbl = sched.ptt.for_type(task_type.name)
    for pl, t in times.items():
        for _ in range(10):
            tbl.update(pl, t)


def _all_places_warm(sched, tt, default=1.0, overrides=None):
    times = {pl: default for pl in sched.topology.places()}
    times.update(overrides or {})
    _warm(sched, tt, times)


def test_fa_pins_high_to_static_fast_cores():
    sched = make_scheduler("FA", tx2())
    tt = matmul_type()
    leaders = set()
    for _ in range(8):
        t = Task(tt, priority=Priority.HIGH)
        target = sched.place_on_wake(t, waker_core=4)
        leaders.add(target)
        assert t.bound_place.width == 1
    assert leaders == {0, 1}                     # round-robin over Denver


def test_da_follows_ptt_not_static():
    sched = make_scheduler("DA", tx2())
    tt = matmul_type()
    # core 0 (statically fastest) is perturbed; core 5 currently fastest
    _all_places_warm(sched, tt, default=1.0,
                     overrides={ExecutionPlace(0, 1): 3.0,
                                ExecutionPlace(5, 1): 0.5})
    t = Task(tt, priority=Priority.HIGH)
    sched.place_on_wake(t, waker_core=0)
    assert t.bound_place == ExecutionPlace(5, 1)
    assert t.bound_place.width == 1              # DA never molds


def test_dam_c_minimizes_cost_dam_p_minimizes_time():
    tt = matmul_type()
    times = {ExecutionPlace(2, 4): 0.4,          # fastest, cost 1.6
             ExecutionPlace(1, 1): 0.8}          # cheapest, cost 0.8
    c = make_scheduler("DAM-C", tx2())
    _all_places_warm(c, tt, default=1.0, overrides=times)
    p = make_scheduler("DAM-P", tx2())
    _all_places_warm(p, tt, default=1.0, overrides=times)

    tc = Task(tt, priority=Priority.HIGH)
    c.place_on_wake(tc, 0)
    tp = Task(tt, priority=Priority.HIGH)
    p.place_on_wake(tp, 0)
    assert tc.bound_place == ExecutionPlace(1, 1)
    assert tp.bound_place == ExecutionPlace(2, 4)


def test_low_priority_local_width_search():
    sched = make_scheduler("DAM-C", tx2())
    tt = matmul_type()
    _all_places_warm(sched, tt, default=1.0,
                     overrides={ExecutionPlace(2, 4): 0.2})  # cost 0.8 < 1.0
    t = Task(tt)                                  # LOW
    assert sched.place_on_wake(t, waker_core=3) is None  # stays local
    place = sched.place_on_dequeue(t, worker_core=3)
    assert 3 in place.cores                       # local search keeps core
    assert place == ExecutionPlace(2, 4)


def test_steal_rules():
    tt = matmul_type()
    high = Task(tt, priority=Priority.HIGH)
    low = Task(tt, priority=Priority.LOW)
    for name, expect_high in [("RWS", True), ("RWSM-C", True), ("FA", False),
                              ("FAM-C", False), ("DA", False),
                              ("DAM-C", False), ("DAM-P", False)]:
        s = make_scheduler(name, tx2())
        assert s.may_steal(low)
        assert s.may_steal(high) == expect_high, name


def test_rws_has_no_priority_machinery():
    sched = make_scheduler("RWS", tx2())
    t = Task(matmul_type(), priority=Priority.HIGH)
    assert sched.place_on_wake(t, waker_core=2) is None
    assert t.bound_place is None
    assert sched.place_on_dequeue(t, 2) == ExecutionPlace(2, 1)
    assert not sched.priority_dequeue


def test_unknown_scheduler():
    with pytest.raises(ValueError):
        make_scheduler("NOPE", tx2())


# -- per-run state reset (regression: _fa_rr leaked across runs) -------------

def test_fa_round_robin_resets_per_run():
    """``begin_run`` rewinds the FA/FAM-C round-robin cursor: a reused
    scheduler must not start round-robin where the last run left off."""
    sched = make_scheduler("FA", tx2(), seed=0)
    first = [sched.place_on_wake(Task(matmul_type(), priority=Priority.HIGH),
                                 0) for _ in range(3)]
    assert first == [0, 1, 0]                  # round-robin over Denver
    sched.begin_run()
    again = [sched.place_on_wake(Task(matmul_type(), priority=Priority.HIGH),
                                 0) for _ in range(3)]
    assert again == first                      # cursor rewound, not at 1


def test_fa_reused_scheduler_reproducible_across_engine_runs():
    """Back-to-back runs on one FA scheduler object place the critical
    chain identically in both engines (an odd task count would flip the
    round-robin parity if the cursor leaked)."""
    import time as _time

    from repro.core import simulate, synthetic_dag

    def chain_leaders_des(sched):
        dag = synthetic_dag(matmul_type(64), parallelism=1, total_tasks=3)
        m = simulate(dag, sched)
        return [r.leader for r in m.records]

    sched = make_scheduler("FA", tx2(), seed=1)
    assert chain_leaders_des(sched) == chain_leaders_des(sched)

    from repro.core import run_threaded

    def chain_leaders_threaded(sched):
        dag = synthetic_dag(matmul_type(64), parallelism=1, total_tasks=3)
        for t in dag.all_tasks():
            t.payload = lambda width: _time.sleep(1e-4)
        m = run_threaded(dag, sched, timeout=30)
        recs = sorted(m.records, key=lambda r: r.t_start)
        return [r.leader for r in recs]

    sched_t = make_scheduler("FA", tx2(), seed=1)
    assert chain_leaders_threaded(sched_t) == chain_leaders_threaded(sched_t)


# -- placement backends -------------------------------------------------------

def _records_fingerprint(sched_name, backend, *, queue_penalty=0.0, seed=7):
    from repro.core import corun_chain, simulate, synthetic_dag

    topo = tx2()
    sched = make_scheduler(sched_name, topo, seed=seed,
                           queue_penalty=queue_penalty,
                           track_load=queue_penalty > 0.0,
                           placement_backend=backend)
    tt = matmul_type(64)
    dag = synthetic_dag(tt, parallelism=4, total_tasks=600)
    m = simulate(dag, sched, background=[corun_chain(tt, core=0)])
    return (m.makespan, [(r.type_name, r.leader, r.width, r.t_start, r.t_end)
                         for r in m.records])


def test_placement_backend_rejects_unknown():
    with pytest.raises(ValueError, match="placement_backend"):
        make_scheduler("DAM-C", tx2(), placement_backend="tpu")


def test_jax_backend_bit_identical_without_queue_penalty():
    """With queue-aware placement off the jax score is the identity map,
    so the jitted backend must reproduce the numpy schedule exactly —
    this is the pin ``repro/core/placement_jax.py`` documents."""
    pytest.importorskip("jax")
    for sched_name in ("DAM-C", "RWSM-C"):
        assert (_records_fingerprint(sched_name, "jax")
                == _records_fingerprint(sched_name, "numpy")), sched_name


def test_jax_backend_queue_penalty_smoke():
    """With a live penalty the jax kernel computes in float32 (x64 is a
    process-global flag we never flip), so bit-identity is NOT promised;
    the run must still complete with a sane schedule."""
    pytest.importorskip("jax")
    mk, recs = _records_fingerprint("DAM-C", "jax", queue_penalty=0.05)
    assert mk > 0 and len(recs) == 600
