"""DAG builder structure tests."""
from repro.core import (Priority, heat_dag, kmeans_dag, matmul_type,
                        synthetic_dag)


def test_synthetic_structure():
    dag = synthetic_dag(matmul_type(), parallelism=4, total_tasks=40)
    tasks = dag.all_tasks()
    assert len(tasks) == 40
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    assert len(highs) == 10                        # one per layer
    # only the critical task releases the next layer
    for h in highs:
        assert len(h.children) in (0, 4)
    lows = [t for t in tasks if t.priority == Priority.LOW]
    assert all(not t.children for t in lows)
    # DAG parallelism = total / longest path = 4
    assert len(dag.roots) == 4


def test_kmeans_dynamic_growth():
    seen = []
    dag = kmeans_dag(n_points=1000, dims=4, k=2, n_chunks=4, iterations=3,
                     on_iteration=seen.append)
    # static portion = first iteration only (maps + reduce)
    assert len(dag.all_tasks()) == 5
    # simulate commits to trigger growth
    reduce_t = dag.roots[0].children[0]
    new = reduce_t.on_commit(reduce_t)
    assert len(new) == 4                           # next iteration's maps
    assert seen == [0]
    assert dag.expected_total == 3 * 5


def test_heat_wiring():
    dag = heat_dag(nodes=3, tiles_per_node=2, iterations=2)
    tasks = dag.all_tasks()
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    # per iteration: node0 1 exch, node1 2, node2 1 = 4 HIGH
    assert len(highs) == 2 * 4
    # exchange tasks gate the next iteration's compute
    it0_ex = [t for t in highs if not any(c.priority == Priority.HIGH
                                          for c in t.children)]
    assert all(len(t.children) >= 2 for t in it0_ex
               if t.children)                      # releases >= own node tiles
