"""DAG builder structure tests."""
import pytest

from repro.core import (Priority, Task, copy_type, heat_dag, kmeans_dag,
                        make_scheduler, matmul_type, mixed_dag, simulate,
                        stencil_type, synthetic_dag, tx2)


def test_synthetic_structure():
    dag = synthetic_dag(matmul_type(), parallelism=4, total_tasks=40)
    tasks = dag.all_tasks()
    assert len(tasks) == 40
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    assert len(highs) == 10                        # one per layer
    # only the critical task releases the next layer
    for h in highs:
        assert len(h.children) in (0, 4)
    lows = [t for t in tasks if t.priority == Priority.LOW]
    assert all(not t.children for t in lows)
    # DAG parallelism = total / longest path = 4
    assert len(dag.roots) == 4


def test_synthetic_partial_final_layer():
    """Regression: non-divisible totals used to silently drop the
    remainder tasks while expected_total reported the truncated count.
    The builder now emits a final partial layer and the counts agree."""
    dag = synthetic_dag(matmul_type(), parallelism=4, total_tasks=10)
    tasks = dag.all_tasks()
    assert len(tasks) == 10
    assert dag.expected_total == 10
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    assert len(highs) == 3                         # layers of 4, 4, 2
    # the partial layer still has its critical task and is released by
    # the previous layer's critical task
    layer_sizes = sorted(len(h.children) for h in highs)
    assert layer_sizes == [0, 2, 4]
    # and the DES runs every one of them
    m = simulate(dag, make_scheduler("DAM-C", tx2(), seed=1))
    assert m.n_tasks == 10


def test_synthetic_divisible_unchanged():
    dag = synthetic_dag(matmul_type(), parallelism=4, total_tasks=12)
    assert len(dag.all_tasks()) == 12 == dag.expected_total
    with pytest.raises(ValueError):
        synthetic_dag(matmul_type(), parallelism=4, total_tasks=3)


def test_all_tasks_bfs_order_and_diamond_dedup():
    """all_tasks is breadth-first and deduplicates: a diamond's join node
    appears exactly once, at its first-discovered depth."""
    tt = matmul_type()
    a = Task(tt)
    b, c = a.add_child(Task(tt)), a.add_child(Task(tt))
    d = Task(tt)
    b.add_child(d)
    c.add_child(d)
    e = d.add_child(Task(tt))
    from repro.core import DAG
    dag = DAG([a], 5)
    tasks = dag.all_tasks()
    assert tasks == [a, b, c, d, e]                # BFS order, d once
    assert len({t.tid for t in tasks}) == 5


def test_mixed_dag_structure():
    """Layers cycle through the task types; every layer keeps its own
    critical HIGH task gating the next layer."""
    types = [matmul_type(512), copy_type(512), stencil_type(2048)]
    dag = mixed_dag(types, parallelism=4, total_tasks=22)
    tasks = dag.all_tasks()
    assert len(tasks) == 22 == dag.expected_total
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    assert len(highs) == 6                         # 5 full layers + 2-task tail
    # per-layer type cycling: walk the critical chain from the roots
    layer_types = []
    crit = next(t for t in dag.roots if t.priority == Priority.HIGH)
    while crit is not None:
        layer_types.append(crit.type.name)
        crit = next((t for t in crit.children
                     if t.priority == Priority.HIGH), None)
    expect = [types[i % 3].name for i in range(6)]
    assert layer_types == expect
    # each type's task count matches its share of the layers
    from collections import Counter
    by_type = Counter(t.type.name for t in tasks)
    assert by_type == {types[0].name: 8, types[1].name: 8,
                       types[2].name: 6}
    with pytest.raises(ValueError):
        mixed_dag([], parallelism=2, total_tasks=10)
    # single-type mix is exactly the synthetic DAG shape
    m = simulate(mixed_dag(types, parallelism=4, total_tasks=120),
                 make_scheduler("DAM-C", tx2(), seed=3))
    assert m.n_tasks == 120


def test_heat_cross_node_edges():
    """Structural audit of the neighbor-exchange gating, with true node
    identity recovered from the deterministic creation (tid) order —
    iteration-major, node-major, exchanges keyed (toward prev, toward
    next).  Direction-sensitive: swapping which neighbor exchange gates a
    node's next iteration changes the expected child sets and fails."""
    nodes, tiles, iters = 4, 2, 3
    dag = heat_dag(nodes=nodes, tiles_per_node=tiles, iterations=iters)
    tasks = sorted(dag.all_tasks(), key=lambda t: t.tid)
    n_ex = 2 * (nodes - 1)                       # directed neighbor pairs
    per_iter = nodes * tiles + n_ex
    assert len(tasks) == iters * per_iter == dag.expected_total
    base = tasks[0].tid
    assert [t.tid - base for t in tasks] == list(range(len(tasks)))

    # rebuild (kind, node, iter[, target]) identity from creation order
    stencils: dict[tuple, list] = {}             # (iter, node) -> tasks
    exchanges: dict[tuple, object] = {}          # (iter, node, target) -> task
    i = 0
    for it in range(iters):
        for n in range(nodes):
            stencils[(it, n)] = tasks[i:i + tiles]
            i += tiles
        for n in range(nodes):
            for nb in (n - 1, n + 1):
                if 0 <= nb < nodes:
                    exchanges[(it, n, nb)] = tasks[i]
                    i += 1
    for (it, n), sts in stencils.items():
        assert all(t.priority == Priority.LOW for t in sts)
    for ex in exchanges.values():
        assert ex.priority == Priority.HIGH

    # each node's stencils gate exactly its own exchanges
    for (it, n, nb), ex in exchanges.items():
        for st in stencils[(it, n)]:
            assert ex in st.children
    # gating: node n's iter i+1 stencils are gated by n's own exchanges
    # plus exactly the neighbors' exchanges *directed at n*
    for it in range(iters - 1):
        for n in range(nodes):
            expect = {id(ex) for (i2, m, nb), ex in exchanges.items()
                      if i2 == it and (m == n                  # own, both
                                       or (m == n - 1 and nb == n)
                                       or (m == n + 1 and nb == n))}
            for (i2, m, nb), ex in exchanges.items():
                if i2 != it:
                    continue
                gated = {id(c) for c in ex.children} & {
                    id(s) for s in stencils[(it + 1, n)]}
                if id(ex) in expect:
                    assert len(gated) == tiles, (it, n, m, nb)
                else:
                    assert not gated, (it, n, m, nb)
    # cross-node gating edges per iteration boundary: `tiles` per
    # directed neighbor pair
    cross = sum(
        1
        for (it, m, nb), ex in exchanges.items() if it < iters - 1
        for c in ex.children
        if c.priority == Priority.LOW and c not in stencils[(it + 1, m)])
    assert cross == (iters - 1) * n_ex * tiles
    # final-iteration exchanges gate nothing
    assert all(not ex.children for (it, _, _), ex in exchanges.items()
               if it == iters - 1)


def test_kmeans_dynamic_growth():
    seen = []
    dag = kmeans_dag(n_points=1000, dims=4, k=2, n_chunks=4, iterations=3,
                     on_iteration=seen.append)
    # static portion = first iteration only (maps + reduce)
    assert len(dag.all_tasks()) == 5
    # simulate commits to trigger growth
    reduce_t = dag.roots[0].children[0]
    new = reduce_t.on_commit(reduce_t)
    assert len(new) == 4                           # next iteration's maps
    assert seen == [0]
    assert dag.expected_total == 3 * 5


def test_heat_wiring():
    dag = heat_dag(nodes=3, tiles_per_node=2, iterations=2)
    tasks = dag.all_tasks()
    highs = [t for t in tasks if t.priority == Priority.HIGH]
    # per iteration: node0 1 exch, node1 2, node2 1 = 4 HIGH
    assert len(highs) == 2 * 4
    # exchange tasks gate the next iteration's compute
    it0_ex = [t for t in highs if not any(c.priority == Priority.HIGH
                                          for c in t.children)]
    assert all(len(t.children) >= 2 for t in it0_ex
               if t.children)                      # releases >= own node tiles
