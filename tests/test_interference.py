"""Interference layer: add_window correctness, closed-form periodic
profiles (bit-equivalence vs materialized segments), trace replay, bursty
episodes, per-partition governors, and the lazy next_breakpoint pull model.
"""
import math

import pytest

from repro.core import (BackgroundApp, PeriodicProfile, SpeedProfile,
                        SpeedProfileBase, TraceProfile, burst_episodes,
                        dvfs_denver, governor_profile, make_scheduler,
                        matmul_type, random_walk_trace, simulate,
                        synthetic_dag, tx2)

INF = float("inf")


# -- add_window (the tail-restore bug) --------------------------------------

def test_add_window_tail_restore_over_infinite_segment():
    """Regression: an episode applied over the final (infinite) segment
    must be lifted at t1.  The pre-fix overlap logic dropped the tail
    restore (its ``te != inf`` clause), so the episode speed stayed in
    force forever."""
    prof = SpeedProfile(2).add_window((0,), 2.0, 5.0, 0.5)
    assert prof.speed(0, 1.0) == 1.0
    assert prof.speed(0, 2.0) == 0.5
    assert prof.speed(0, 4.999) == 0.5
    assert prof.speed(0, 5.0) == 1.0        # was 0.5 before the fix
    assert prof.speed(0, 100.0) == 1.0      # ... forever
    assert prof.speed(1, 3.0) == 1.0        # other cores untouched


def test_add_window_past_last_square_wave_breakpoint():
    """A window entirely beyond the last materialized breakpoint sits on
    the persisted final phase and must restore *that* speed at t1."""
    prof = SpeedProfile(1).add_square_wave((0,), period=2.0, lo=0.3,
                                           t_end=4.0)
    assert prof.speed(0, 50.0) == 0.3       # last phase (lo) persists
    prof.add_window((0,), 10.0, 20.0, 0.8)
    assert prof.speed(0, 9.0) == 0.3
    assert prof.speed(0, 15.0) == 0.8
    assert prof.speed(0, 20.0) == 0.3       # restored to the lo tail
    assert prof.speed(0, 1e6) == 0.3


def test_add_window_at_t0_zero():
    prof = SpeedProfile(1).add_window((0,), 0.0, 1.0, 0.6)
    assert prof.speed(0, 0.0) == 0.6
    assert prof.speed(0, 0.999) == 0.6
    assert prof.speed(0, 1.0) == 1.0


def test_add_window_nested():
    prof = SpeedProfile(1).add_window((0,), 1.0, 9.0, 0.5)
    prof.add_window((0,), 3.0, 5.0, 0.25)
    for t, want in ((0.5, 1.0), (2.0, 0.5), (4.0, 0.25), (7.0, 0.5),
                    (9.0, 1.0), (50.0, 1.0)):
        assert prof.speed(0, t) == want, t


def test_add_window_unbounded_episode():
    prof = SpeedProfile(1).add_window((0,), 2.0, INF, 0.4)
    assert prof.speed(0, 1.0) == 1.0
    assert prof.speed(0, 1e9) == 0.4        # no restore for t1 = inf


def test_add_window_aligned_with_existing_breakpoints():
    prof = SpeedProfile(1).add_square_wave((0,), period=2.0, lo=0.3,
                                           t_end=8.0)
    prof.add_window((0,), 1.0, 3.0, 0.9)    # t0/t1 on existing edges
    assert prof.speed(0, 0.5) == 1.0
    assert prof.speed(0, 1.5) == 0.9
    assert prof.speed(0, 2.5) == 0.9
    assert prof.speed(0, 3.0) == 0.3        # the (3.0, lo) segment resumes
    assert prof.speed(0, 4.5) == 1.0


def test_add_window_rejects_bad_bounds():
    with pytest.raises(ValueError):
        SpeedProfile(1).add_window((0,), 5.0, 5.0, 0.5)
    with pytest.raises(ValueError):
        SpeedProfile(1).add_window((0,), -1.0, 5.0, 0.5)


def test_add_window_updates_breakpoints():
    prof = SpeedProfile(1)
    assert prof.next_breakpoint(0.0) is None
    prof.add_window((0,), 2.0, 5.0, 0.5)
    assert prof.breakpoints(10.0) == [2.0, 5.0]


# -- the lazy pull model ----------------------------------------------------

def test_pull_model_matches_eager_breakpoints():
    prof = SpeedProfile(3).add_square_wave((0, 2), period=2.0, lo=0.5,
                                           t_end=21.0)
    prof.add_window((1,), 3.25, 7.75, 0.9)
    eager = prof.breakpoints(15.0)
    walk, t = [], 0.0
    while True:
        nb = prof.next_breakpoint(t)
        if nb is None or nb > 15.0:
            break
        walk.append(nb)
        t = nb
    assert walk == eager
    # the base-class eager helper is the same pull loop
    assert SpeedProfileBase.breakpoints(prof, 15.0) == eager
    assert prof.next_breakpoint(21.0) is None


# -- PeriodicProfile: closed form vs materialized segments ------------------

def test_dvfs_denver_is_closed_form_with_zero_materialization():
    """Acceptance: fig7-class periodic profiles must not materialize
    square-wave segments (the old form built ~200k per core)."""
    prof = dvfs_denver()
    assert isinstance(prof, PeriodicProfile)
    assert not hasattr(prof, "_segs")


def test_periodic_dvfs_denver_bit_identical_to_materialized():
    """The Denver 5 s + 5 s phase boundaries are exact in floating point,
    so the closed form must reproduce the materialized profile bit-for-bit:
    same breakpoint sequence over the full 1e6 s horizon, same speeds at
    every breakpoint."""
    per = dvfs_denver()
    mat = SpeedProfile(6).add_square_wave((0, 1), period=10.0,
                                          lo=345.0 / 2035.0)
    bm = mat.breakpoints(1e6)
    assert per.breakpoints(1e6) == bm
    assert len(bm) == 199999
    for t in bm:
        assert per.speed(0, t) == mat.speed(0, t)
        assert per.speed(1, t) == mat.speed(1, t)
    # off-pattern cores and mid-phase instants
    for t in (0.0, 2.5, 7.5, 12.5, 999997.5, 1.5e6):
        for c in range(6):
            assert per.speed(c, t) == mat.speed(c, t), (c, t)


def test_periodic_square_wave_matches_materialized_dyadic():
    """Any dyadic period (phase boundaries exact in fp) is bit-identical
    between the two representations, including the persisted final phase
    beyond t_end."""
    period, t_end = 0.25, 3.3
    per = PeriodicProfile.square_wave(1, (0,), period=period, lo=0.4,
                                      hi_first=False, t_end=t_end)
    mat = SpeedProfile(1).add_square_wave((0,), period=period, lo=0.4,
                                          hi_first=False, t_end=t_end)
    assert per.breakpoints(100.0) == mat.breakpoints(100.0)
    probes = [k * period / 2 for k in range(30)] + [3.2, 3.3, 7.0, 1e4]
    for t in probes:
        assert per.speed(0, t) == mat.speed(0, t), t


def test_periodic_multiphase_pattern():
    prof = PeriodicProfile(2).set_pattern(
        (0,), ((1.0, 1.0), (0.5, 0.3), (0.5, 0.6)), t_end=INF)
    for t, want in ((0.5, 1.0), (1.2, 0.3), (1.7, 0.6), (2.5, 1.0),
                    (7.25, 0.3), (103.75, 0.6)):
        assert prof.speed(0, t) == want, t
    assert prof.speed(1, 5.0) == 1.0            # core without a pattern
    assert prof.next_breakpoint(0.0) == 1.0
    assert prof.next_breakpoint(1.0) == 1.5
    assert prof.next_breakpoint(1.5) == 2.0
    assert prof.next_breakpoint(1e6) is not None    # unbounded pattern


def test_periodic_t_end_final_phase_persists():
    per = PeriodicProfile.square_wave(1, (0,), period=2.0, lo=0.3, t_end=3.5)
    assert per.breakpoints(100.0) == [1.0, 2.0, 3.0]
    assert per.next_breakpoint(3.0) is None
    assert per.speed(0, 3.2) == 0.3
    assert per.speed(0, 1e9) == 0.3


def test_periodic_speed_consistent_with_next_breakpoint_nondyadic():
    """Regression: at non-dyadic periods the pulled breakpoint instants
    round differently from an arithmetically reconstructed within-period
    remainder, and speed() at the pulled instant used to return the
    *pre*-flip phase — the simulator then silently lost most flips.  A
    square wave must alternate at every one of its own breakpoints."""
    per = PeriodicProfile.square_wave(1, (0,), period=0.0042, lo=0.17,
                                      t_end=0.5)
    bps = per.breakpoints(0.5)
    assert len(bps) > 200
    speeds = [per.speed(0, t) for t in bps]
    assert speeds[0] == 0.17                    # first flip is hi -> lo
    assert all(a != b for a, b in zip(speeds, speeds[1:]))
    # the two representations place each flip instant one ulp apart at
    # non-dyadic periods (closed form vs accumulation — documented), but
    # away from the boundaries, i.e. mid-phase, they must agree exactly
    mat = SpeedProfile(1).add_square_wave((0,), period=0.0042, lo=0.17,
                                          t_end=0.5)
    for t in mat.breakpoints(0.5):
        mid = t + 0.0042 / 4
        assert per.speed(0, mid) == mat.speed(0, mid), mid


def test_governor_patterns_deduped_by_value():
    """governor_profile with zero spread builds one _Pattern per
    partition; value equality must collapse them so next_breakpoint scans
    O(distinct waves), not O(partitions)."""
    from repro.core import haswell_cluster
    gov = governor_profile(haswell_cluster(), period=2.0, lo=0.5,
                           t_end=100.0, period_spread=0.0)
    assert len(gov._distinct) == 2              # hi-first + lo-first


def test_breakpoints_rejects_infinite_horizon():
    """An unbounded periodic profile has infinitely many breakpoints; the
    eager helper must refuse rather than loop forever."""
    prof = PeriodicProfile(1).set_pattern((0,), ((1.0, 1.0), (1.0, 0.5)),
                                          t_end=INF)
    with pytest.raises(ValueError, match="finite horizon"):
        prof.breakpoints(INF)


def test_periodic_rejects_bad_patterns():
    with pytest.raises(ValueError):
        PeriodicProfile(1).set_pattern((0,), ())
    with pytest.raises(ValueError):
        PeriodicProfile(1).set_pattern((0,), ((0.0, 1.0),))


def test_periodic_schedule_bit_identical_to_materialized():
    """Acceptance: swapping a materialized square wave for its closed-form
    periodic equivalent must leave the produced *schedule* bit-identical
    (dyadic period so every breakpoint is exact)."""
    period = 1 / 256

    def run(speed):
        sched = make_scheduler("DAM-C", tx2(), seed=3)
        dag = synthetic_dag(matmul_type(64), parallelism=4, total_tasks=1200)
        return simulate(dag, sched, speed=speed)

    mat = run(SpeedProfile(6).add_square_wave((0, 1), period=period, lo=0.17,
                                              t_end=0.5))
    per = run(PeriodicProfile.square_wave(6, (0, 1), period=period, lo=0.17,
                                          t_end=0.5))
    assert mat.makespan > 8 * period    # the wave actually fired, many times
    assert per.makespan == mat.makespan
    assert per.placement_counts() == mat.placement_counts()
    assert per.placement_counts(priority=1) == mat.placement_counts(priority=1)


# -- TraceProfile -----------------------------------------------------------

def test_trace_profile_replay():
    prof = TraceProfile(3, {1: [(0.0, 0.8), (1.0, 0.5), (2.5, 1.2)]})
    for t, want in ((0.0, 0.8), (0.9, 0.8), (1.0, 0.5), (2.49, 0.5),
                    (2.5, 1.2), (1e6, 1.2)):
        assert prof.speed(1, t) == want, t
    assert prof.speed(0, 1.5) == 1.0            # untraced core
    assert prof.breakpoints(10.0) == [1.0, 2.5]


def test_trace_profile_implicit_head():
    prof = TraceProfile(1, {0: [(2.0, 0.5)]})
    assert prof.speed(0, 1.0) == 1.0            # 1.0 before the first point
    assert prof.speed(0, 3.0) == 0.5


def test_trace_profile_validation():
    with pytest.raises(ValueError):
        TraceProfile(1, {2: [(0.0, 1.0)]})      # core out of range
    with pytest.raises(ValueError):
        TraceProfile(1, {0: [(1.0, 1.0), (1.0, 0.5)]})  # non-increasing t
    with pytest.raises(ValueError):
        TraceProfile(1, {0: [(0.0, -0.5)]})     # non-positive speed


def test_random_walk_trace_reproducible_and_bounded():
    a = random_walk_trace(4, (0, 2), seed=9, dt=0.01, t_end=0.3, lo=0.2,
                          hi=0.9, step=0.3)
    b = random_walk_trace(4, (0, 2), seed=9, dt=0.01, t_end=0.3, lo=0.2,
                          hi=0.9, step=0.3)
    assert a._segs == b._segs
    for c in (0, 2):
        assert len(a._segs[c]) == 30
        assert all(0.2 <= sp <= 0.9 for _, sp in a._segs[c])
    assert a.speed(1, 0.1) == 1.0               # unlisted core untouched
    c = random_walk_trace(4, (0, 2), seed=10, dt=0.01, t_end=0.3)
    assert c._segs != a._segs                   # seed matters
    with pytest.raises(ValueError):
        random_walk_trace(4, seed=1, dt=0.01, t_end=INF)


# -- bursty background episodes ---------------------------------------------

def test_burst_episodes_seeded_and_bounded():
    tt = matmul_type(64)
    eps = burst_episodes(tt, (0, 1), seed=4, t_end=1.0,
                         mean_on=0.05, mean_off=0.1)
    assert eps == burst_episodes(tt, (0, 1), seed=4, t_end=1.0,
                                 mean_on=0.05, mean_off=0.1)
    assert len(eps) > 0
    prev_end = 0.0
    for e in eps:
        assert isinstance(e, BackgroundApp)
        assert e.cores == (0, 1)
        assert prev_end <= e.t_start < e.t_end <= 1.0
        assert e.active((e.t_start + e.t_end) / 2)
        assert not e.active(e.t_end)
        prev_end = e.t_end
    other = burst_episodes(tt, (0, 1), seed=5, t_end=1.0,
                           mean_on=0.05, mean_off=0.1)
    assert other != eps


def test_burst_episodes_validation():
    with pytest.raises(ValueError):
        burst_episodes(matmul_type(64), (0,), seed=1, t_end=INF,
                       mean_on=0.1, mean_off=0.1)
    with pytest.raises(ValueError):
        burst_episodes(matmul_type(64), (0,), seed=1, t_end=1.0,
                       mean_on=0.0, mean_off=0.1)


def test_burst_episodes_interfere():
    """Bounded bursts slow the run down, but less than a persistent
    co-runner on the same cores."""
    tt = matmul_type(64)

    def run(background):
        sched = make_scheduler("RWS", tx2(), seed=2)
        dag = synthetic_dag(tt, parallelism=4, total_tasks=300)
        return simulate(dag, sched, background=list(background)).makespan

    clean = run(())
    bursts = burst_episodes(tt, (0, 1, 2), seed=3, t_end=1.0,
                            mean_on=0.005, mean_off=0.005)
    persistent = [BackgroundApp(tt, (0, 1, 2))]
    assert clean < run(bursts) < run(persistent)


# -- per-partition governors ------------------------------------------------

def test_governor_staggers_partitions():
    topo = tx2()            # denver (cores 0-1), a57 (cores 2-5)
    gov = governor_profile(topo, period=2.0, lo=0.5, t_end=100.0)
    assert isinstance(gov, PeriodicProfile)
    # partition 0 starts hi, partition 1 starts lo (staggered phases)
    assert gov.speed(0, 0.5) == 1.0 and gov.speed(1, 0.5) == 1.0
    assert gov.speed(2, 0.5) == 0.5 and gov.speed(5, 0.5) == 0.5
    assert gov.speed(0, 1.5) == 0.5 and gov.speed(2, 1.5) == 1.0


def test_governor_period_spread_detunes():
    topo = tx2()
    gov = governor_profile(topo, period=2.0, lo=0.5, t_end=1e6,
                           period_spread=0.25, stagger=False)
    # partition 1's period is 2.0*(1+0.25) = 2.5: first edges at 1.0, 1.25
    assert gov.next_breakpoint(0.0) == 1.0
    assert gov.next_breakpoint(1.0) == 1.25
    assert gov.speed(0, 1.1) == 0.5             # denver flipped at 1.0
    assert gov.speed(2, 1.1) == 1.0             # a57 flips only at 1.25


def test_governor_kinds_filter_still_staggers():
    """Stagger/detune index over *governed* partitions: filtering to one
    kind on an alternating topology must not put the governed clusters
    back in lockstep."""
    from repro.core import tx2_xl
    topo = tx2_xl(2)        # denver0, a57_0, denver1, a57_1
    gov = governor_profile(topo, period=2.0, lo=0.5, t_end=100.0,
                           kinds=("denver",))
    # the two denver clusters (cores 0-1 and 6-7) are phase-opposed
    assert gov.speed(0, 0.5) == 1.0 and gov.speed(6, 0.5) == 0.5
    assert gov.speed(0, 1.5) == 0.5 and gov.speed(6, 1.5) == 1.0
    assert gov.speed(2, 0.5) == 1.0          # a57s ungoverned


def test_governor_kinds_filter():
    topo = tx2()
    gov = governor_profile(topo, period=2.0, lo=0.5, t_end=100.0,
                           kinds=("denver",))
    assert gov.speed(0, 1.5) == 0.5
    assert gov.speed(2, 1.5) == 1.0             # a57 ungoverned
    with pytest.raises(ValueError):
        governor_profile(topo, kinds=("pod",))


def test_governor_drives_the_simulator():
    tt = matmul_type(64)

    def run(speed):
        sched = make_scheduler("DAM-C", tx2(), seed=1)
        dag = synthetic_dag(tt, parallelism=4, total_tasks=300)
        return simulate(dag, sched, speed=speed).makespan

    plain = run(None)
    governed = run(governor_profile(tx2(), period=0.004, lo=0.2, t_end=1.0))
    assert governed > plain                     # the governor costs time
    assert math.isfinite(governed)


# -- MMPP-correlated co-runner bursts ----------------------------------------

def test_mmpp_burst_episodes_seeded_and_bounded():
    import random as _random

    from repro.core import mmpp_burst_episodes
    tt = matmul_type(64)
    groups = ((0, 1), (3, 4))
    kw = dict(seed=6, t_end=1.0, mean_on=0.005, mean_calm=0.05,
              mean_storm=0.02, mean_off_calm=0.02, mean_off_storm=0.004)
    apps = mmpp_burst_episodes(tt, groups, **kw)
    assert apps == mmpp_burst_episodes(tt, groups, **kw)
    assert len(apps) > 0
    for a in apps:
        assert isinstance(a, BackgroundApp)
        assert a.cores in groups
        assert 0.0 <= a.t_start < a.t_end <= 1.0
        assert a.active((a.t_start + a.t_end) / 2)
    # per-group streams: dropping a group leaves the other's episodes
    # untouched
    solo = mmpp_burst_episodes(tt, groups[:1], **kw)
    assert solo == tuple(a for a in apps if a.cores == groups[0])


def test_mmpp_burst_episodes_cluster_in_storms():
    """The shared calm/storm chain is the whole point: every group's
    per-second episode-start rate must be higher inside storm windows
    than outside them."""
    import random as _random

    from repro.core import mmpp_burst_episodes
    from repro.core.interference import mmpp_state_timeline
    tt = matmul_type(64)
    groups = ((0,), (6,), (12,))
    kw = dict(seed=2, t_end=20.0, mean_on=0.01, mean_calm=1.0,
              mean_storm=0.5, mean_off_calm=0.5, mean_off_storm=0.02)
    apps = mmpp_burst_episodes(tt, groups, **kw)
    timeline = mmpp_state_timeline(_random.Random("burst-mmpp-state:2"),
                                   t_end=20.0, mean_calm=1.0, mean_storm=0.5)
    spans = []
    for (t, s), nxt in zip(timeline, timeline[1:] + [(20.0, -1)]):
        spans.append((t, nxt[0], s))
    storm_s = sum(t1 - t0 for t0, t1, s in spans if s == 1)
    calm_s = sum(t1 - t0 for t0, t1, s in spans if s == 0)
    assert storm_s > 0 and calm_s > 0
    for g in groups:
        starts = [a.t_start for a in apps if a.cores == g]
        in_storm = sum(1 for t in starts if any(
            t0 <= t < t1 for t0, t1, s in spans if s == 1))
        rate_storm = in_storm / storm_s
        rate_calm = (len(starts) - in_storm) / calm_s
        assert rate_storm > rate_calm, g


def test_mmpp_burst_episodes_validation():
    from repro.core import mmpp_burst_episodes
    tt = matmul_type(64)
    with pytest.raises(ValueError):
        mmpp_burst_episodes(tt, ((0,),), seed=1, t_end=INF, mean_on=0.01,
                            mean_calm=1.0, mean_storm=0.5,
                            mean_off_calm=0.5, mean_off_storm=0.02)
    with pytest.raises(ValueError):
        mmpp_burst_episodes(tt, ((0,),), seed=1, t_end=-1.0, mean_on=0.01,
                            mean_calm=1.0, mean_storm=0.5,
                            mean_off_calm=0.5, mean_off_storm=0.02)


def test_speeds_at_matches_per_core_speed_loop():
    """The bulk query every profile serves the DES speed-breakpoint
    handler through must be element-wise identical to looping
    ``speed(core, t)`` — including SpeedProfile's constant-core fast
    path and the closed-form/default implementations."""
    profiles = [
        SpeedProfile(6).add_square_wave((1, 3), period=0.004, lo=0.2,
                                        t_end=0.1).add_window([5], 0.01,
                                                              0.03, 0.5),
        dvfs_denver(6),
        random_walk_trace(6, (0, 2), seed=3, dt=0.002, t_end=0.05),
    ]
    probes = [0.0, 0.001, 0.002, 0.0101, 0.03, 0.05, 0.2, 1.0]
    for prof in profiles:
        for t in probes:
            assert prof.speeds_at(t) == \
                [prof.speed(c, t) for c in range(prof.n_cores)], (prof, t)
