"""Optional-hypothesis shim.

``hypothesis`` powers the property-based tests but is not always present
(see requirements.txt).  Importing ``given``/``settings``/``st`` from this
module instead of from ``hypothesis`` lets a module's example-based tests
keep running when the library is missing: property tests turn into
skipped zero-argument stubs instead of killing collection of the whole
file (the moral equivalent of ``pytest.importorskip`` at test rather than
module granularity).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed (see requirements.txt)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _InertStrategy:
        """Absorbs any chained strategy API (.map, .filter, ...)."""
        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    st = _InertStrategy()
