"""PTT unit + property tests (paper §4.1.1)."""
import pytest

from _ht import given, settings, st

from repro.core import ExecutionPlace, PTT, PTTBank, tx2


def test_update_rule_1_to_4():
    ptt = PTT(tx2(), first_visit_direct=True)
    p = ExecutionPlace(0, 1)
    ptt.update(p, 10.0)
    assert ptt.get(p) == 10.0                      # first visit: direct
    ptt.update(p, 20.0)
    assert ptt.get(p) == pytest.approx((4 * 10 + 20) / 5)


def test_hysteresis_three_measurements():
    """Paper: 'at least three measurements need to be taken before the PTT
    value becomes closer to the new value' — with 1:4 weighting the value
    is still closer to the old regime after 3 observations and flips by
    the 4th."""
    ptt = PTT(tx2())
    p = ExecutionPlace(1, 1)
    for _ in range(20):
        ptt.update(p, 1.0)
    vals = []
    for _ in range(5):
        ptt.update(p, 3.0)
        vals.append(ptt.get(p))
    for i in range(3):      # after <=3 updates still closer to 1.0
        assert abs(vals[i] - 1.0) < abs(vals[i] - 3.0)
    assert abs(vals[3] - 3.0) < abs(vals[3] - 1.0)


def test_zero_init_explored_first():
    ptt = PTT(tx2())
    ptt.update(ExecutionPlace(0, 1), 5.0)
    best = ptt.global_search(cost=False)
    assert ptt.get(best) == 0.0                    # some unexplored place wins


def test_local_search_keeps_core():
    ptt = PTT(tx2())
    for pl in tx2().places():
        ptt.update(pl, 1.0)
    place = ptt.local_search(3, cost=True)
    assert 3 in place.cores                        # paper: core stays fixed


def test_global_search_cost_vs_perf():
    topo = tx2()
    ptt = PTT(topo)
    # width-4 place is fastest but costly; core 1 is best width-1
    for pl in topo.places():
        ptt.update(pl, 1.0)
    ptt.update(ExecutionPlace(2, 4), 0.4)          # t*w = 1.6
    for _ in range(9):
        ptt.update(ExecutionPlace(2, 4), 0.4)
    ptt.update(ExecutionPlace(1, 1), 0.8)
    for _ in range(9):
        ptt.update(ExecutionPlace(1, 1), 0.8)
    perf = ptt.global_search(cost=False)
    cost = ptt.global_search(cost=True)
    assert perf == ExecutionPlace(2, 4)            # DAM-P choice
    assert cost == ExecutionPlace(1, 1)            # DAM-C choice


def test_invalid_place_rejected():
    ptt = PTT(tx2())
    with pytest.raises(KeyError):
        ptt.update(ExecutionPlace(0, 4), 1.0)      # width 4 invalid on denver
    with pytest.raises(ValueError):
        ptt.update(ExecutionPlace(0, 1), float("nan"))


def test_vectorized_searches_agree_with_generic_best():
    """The masked-argmin searches must keep the exact semantics of the
    generic ``best`` path (value, then width, then the same random draw)
    on every candidate set, explored or not."""
    import random

    topo = tx2()
    ptt = PTT(topo)
    rng = random.Random(0)
    for step in range(60):
        cands = list(topo.places())
        for cost in (True, False):
            assert ptt.global_search(cost=cost) == ptt.best(cands, cost=cost)
            r1, r2 = random.Random(step), random.Random(step)
            assert ptt.global_search(cost=cost, rng=r1) == \
                ptt.best(cands, cost=cost, rng=r2)
        core = rng.randrange(topo.n_cores)
        assert ptt.local_search(core) == \
            ptt.best(topo.local_places(core), cost=True)
        assert ptt.width1_search() == \
            ptt.best([p for p in cands if p.width == 1], cost=False)
        ptt.update(cands[rng.randrange(len(cands))], rng.uniform(0.5, 2.0))


def test_bank_one_table_per_type():
    bank = PTTBank(tx2())
    a = bank.for_type("matmul64")
    b = bank.for_type("copy1024")
    assert a is not b
    assert bank.for_type("matmul64") is a


@given(st.lists(st.floats(min_value=0.01, max_value=100.0),
                min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_ema_bounded_by_observations(observations):
    """Property: the EMA always stays within [min, max] of observations."""
    ptt = PTT(tx2())
    p = ExecutionPlace(0, 1)
    for o in observations:
        ptt.update(p, o)
    v = ptt.get(p)
    assert min(observations) - 1e-9 <= v <= max(observations) + 1e-9


@given(st.floats(min_value=0.01, max_value=10.0),
       st.floats(min_value=0.01, max_value=10.0))
@settings(max_examples=30, deadline=None)
def test_ema_converges(old, new):
    """Property: repeated observations converge to the observed value."""
    ptt = PTT(tx2())
    p = ExecutionPlace(2, 2)
    ptt.update(p, old)
    for _ in range(200):
        ptt.update(p, new)
    assert ptt.get(p) == pytest.approx(new, rel=1e-3)
