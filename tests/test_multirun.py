"""Batched multi-run engine: spec resolution, deterministic seeding,
bit-identical results across worker counts / chunk layouts, and the
cached worker pool."""
import dataclasses

import pytest

from repro.core import RunSpec, run_cell, run_cells, shutdown_pool
from repro.core import multirun

_TT = ("matmul", {"tile": 64})


def _grid(total=120, seeds=(1,), scheds=("RWS", "DAM-C")):
    return [RunSpec(
        key=f"{s}/seed{seed}",
        dag=("synthetic", {"task_type": _TT, "parallelism": 2,
                           "total_tasks": total}),
        scheduler=s,
        topology=("tx2", {}),
        seed=seed,
        background=(("chain", {"task_type": _TT, "core": 0}),),
        collect=("placement_counts", "high_placement_counts"),
    ) for s in scheds for seed in seeds]


def test_run_cell_result_shape():
    res = run_cell(_grid()[0])
    assert res["n_tasks"] == 120
    assert res["makespan_s"] > 0
    assert res["throughput_tps"] == pytest.approx(120 / res["makespan_s"])
    assert sum(res["placement_counts"].values()) == 120
    assert sum(res["high_placement_counts"].values()) == 60  # P=2: half HIGH
    assert "wall_s" not in res                    # measure_wall off


def test_measure_wall():
    res = run_cell(dataclasses.replace(_grid()[0], measure_wall=True))
    assert res["wall_s"] >= 0
    assert res["sim_tasks_per_s"] > 0


def test_in_process_deterministic():
    specs = _grid(seeds=(3,))
    a = run_cells(specs, workers=1)
    b = run_cells(specs, workers=1)
    assert a == b


def test_seed_changes_result():
    a, b = (run_cell(s) for s in _grid(scheds=("DAM-C",), seeds=(1, 2)))
    assert a["makespan_s"] != b["makespan_s"]


def test_bit_identical_across_worker_counts_and_chunking():
    """The acceptance contract: per-cell results must be bit-identical for
    any worker count (spawned subprocesses vs in-process) and chunk size."""
    specs = _grid(seeds=(1, 2), scheds=("RWS", "DAM-C", "FA"))
    serial = run_cells(specs, workers=1)
    spawned = run_cells(specs, workers=2)
    assert serial == spawned
    rechunked = run_cells(specs, workers=2, chunksize=3)
    assert serial == rechunked
    assert list(serial) == [s.key for s in specs]  # spec order preserved


def test_more_workers_than_cells():
    specs = _grid()[:1]
    assert run_cells(specs, workers=8) == run_cells(specs, workers=1)


def test_duplicate_keys_rejected():
    specs = _grid() + _grid()
    with pytest.raises(ValueError, match="duplicate"):
        run_cells(specs, workers=1)


def test_empty_grid():
    assert run_cells([], workers=2) == {}


def test_unknown_registry_names_rejected():
    bad_topo = dataclasses.replace(_grid()[0], topology=("cray1", {}))
    with pytest.raises(KeyError, match="topology"):
        run_cell(bad_topo)
    bad_collect = dataclasses.replace(_grid()[0], collect=("vibes",))
    with pytest.raises(KeyError, match="collector"):
        run_cell(bad_collect)


def test_speed_and_sched_kwargs_specs():
    spec = RunSpec(
        key="dvfs",
        dag=("synthetic", {"task_type": _TT, "parallelism": 2,
                           "total_tasks": 120}),
        scheduler="DAM-C",
        seed=1,
        sched_kwargs={"ptt_new_weight": 2, "ptt_old_weight": 3,
                      "ptt_tiebreak": "seeded"},
        speed=("dvfs_denver", {}),
    )
    res = run_cell(spec)
    assert res["n_tasks"] == 120


def test_scenario_speed_and_background_builders():
    """The scenario registry entries: bursty episode tuples are flattened
    into the background list; governor / trace_walk / periodic_square
    speed builders resolve against the cell's topology."""
    base = dict(
        dag=("synthetic", {"task_type": _TT, "parallelism": 4,
                           "total_tasks": 160}),
        scheduler="DAM-C", topology=("tx2_xl", {"clusters": 2}), seed=2)
    bursty = RunSpec(key="bursty", background=(
        ("bursty", {"task_type": _TT, "cores": (0, 1), "seed": 2,
                    "t_end": 0.5, "mean_on": 0.002, "mean_off": 0.004}),),
        **base)
    gov = RunSpec(key="gov", speed=("governor", {"period": 0.004, "lo": 0.3,
                                                 "t_end": 0.5}), **base)
    trace = RunSpec(key="trace", speed=("trace_walk", {"seed": 7, "dt": 0.002,
                                                       "t_end": 0.5}), **base)
    periodic = RunSpec(key="per", speed=("periodic_square",
                                         {"cores": (0, 1), "period": 0.004,
                                          "lo": 0.2, "t_end": 0.5}), **base)
    for spec in (bursty, gov, trace, periodic):
        res = run_cell(spec)
        assert res["n_tasks"] == 160, spec.key
        assert res == run_cell(spec), spec.key      # deterministic


def test_pool_reused_across_calls():
    """The spawn pool survives run_cells calls (the ~1.3 s fixed spawn
    cost is paid once per worker count), without changing any result."""
    specs = _grid(seeds=(1, 2))
    serial = run_cells(specs, workers=1)
    assert multirun._pool is None or multirun._pool_workers  # sanity
    a = run_cells(specs, workers=2)
    pool = multirun._pool
    assert pool is not None
    b = run_cells(specs, workers=2)
    assert multirun._pool is pool                   # same pool object
    assert a == b == serial
    shutdown_pool()
    assert multirun._pool is None
    shutdown_pool()                                 # idempotent
    c = run_cells(specs, workers=2)                 # respawns on demand
    assert c == serial
    shutdown_pool()


def test_pool_worker_count_change_respawns():
    specs = _grid(seeds=(1, 2, 3))
    a = run_cells(specs, workers=2)
    pool2 = multirun._pool
    b = run_cells(specs, workers=3)
    assert multirun._pool is not pool2
    assert multirun._pool_workers == 3
    assert a == b
    shutdown_pool()


def test_dynamic_dag_builders():
    km = RunSpec(key="km", dag=("kmeans", {"n_points": 4000, "dims": 4,
                                           "k": 2, "n_chunks": 4,
                                           "iterations": 3}),
                 scheduler="DAM-C", topology=("haswell", {}), seed=1)
    res = run_cell(km)
    assert res["n_tasks"] == 3 * (4 + 1)
    heat = RunSpec(key="heat", dag=("heat", {"nodes": 2, "tiles_per_node": 2,
                                             "iterations": 2}),
                   scheduler="DA", topology=("haswell_cluster", {"nodes": 2}),
                   seed=1)
    res = run_cell(heat)
    assert res["n_tasks"] == 2 * (2 * 2 + 2)      # compute + exchanges


def test_sim_kwargs_event_mode_passthrough():
    """``sim_kwargs`` reaches ``simulate`` verbatim: a cell re-run on the
    scalar reference loop is bit-identical to the default cohort cell,
    and a bad knob surfaces as the simulator's own TypeError."""
    base = _grid(scheds=("DAM-C",), seeds=(5,))[0]
    cohort = run_cell(base)
    scalar = run_cell(dataclasses.replace(
        base, sim_kwargs=(("event_mode", "scalar"),)))
    assert scalar == cohort
    compacted = run_cell(dataclasses.replace(
        base, sim_kwargs=(("compact_min_stale", 0),
                          ("compact_heap_frac", 0.05))))
    assert compacted == cohort
    with pytest.raises(TypeError):
        run_cell(dataclasses.replace(base, sim_kwargs=(("no_such_knob", 1),)))
