"""Topology / execution-place invariants."""
import pytest

from _ht import given, settings, st

from repro.core import (ExecutionPlace, ResourcePartition, Topology, haswell,
                        haswell_cluster, tpu_pod_slices, tx2)


def test_tx2_matches_paper():
    topo = tx2()
    assert topo.n_cores == 6
    denver = topo.partition_of(0)
    a57 = topo.partition_of(2)
    assert denver.widths == (1, 2)
    assert a57.widths == (1, 2, 4)
    assert topo.fastest_static_partition() is denver
    assert denver.domain == a57.domain == "lpddr4"


def test_places_aligned_and_within_partition():
    for topo in (tx2(), haswell(), haswell_cluster(2), tpu_pod_slices()):
        for pl in topo.places():
            part = topo.partition_of(pl.leader)
            assert (pl.leader - part.start) % pl.width == 0
            assert set(pl.cores) <= set(part.cores)


def test_local_places_contain_core():
    topo = tx2()
    for core in range(topo.n_cores):
        for pl in topo.local_places(core):
            assert core in pl.cores


def test_place_containing():
    part = tx2().partition_of(2)
    assert part.place_containing(5, 4) == ExecutionPlace(2, 4)
    assert part.place_containing(5, 2) == ExecutionPlace(4, 2)
    with pytest.raises(ValueError):
        part.place_containing(5, 3)


def test_partitions_must_tile():
    with pytest.raises(ValueError):
        Topology([ResourcePartition("a", "x", 0, 2, (1,)),
                  ResourcePartition("b", "x", 3, 2, (1,))])   # gap at core 2


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=16))
@settings(max_examples=40, deadline=None)
def test_pod_topology_properties(pods, slices):
    topo = tpu_pod_slices(pods, slices)
    assert topo.n_cores == pods * slices
    # every core belongs to exactly one partition, widths divide size
    for p in topo.partitions:
        for w in p.widths:
            assert p.size % w == 0
    # place count: per partition sum_w size/w
    expected = sum(p.size // w for p in topo.partitions for w in p.widths)
    assert len(topo.places()) == expected
