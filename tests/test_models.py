"""Per-architecture smoke tests (reduced configs) + model invariants.

Required by the task: every assigned arch instantiates a reduced config,
runs one forward/train step on CPU, asserts output shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, layer_plan, loss_and_metrics)
from repro.models.transformer import prefill

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                                     cfg.vocab),
    }
    if cfg.frontend != "none":
        batch["frontend"] = jax.random.normal(
            jax.random.fold_in(KEY, 2), (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b["tokens"],
                                               b.get("frontend")))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert jnp.isfinite(logits).all()

    def loss_fn(p):
        return loss_and_metrics(p, cfg, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    state = init_decode_state(cfg, batch=2, max_len=16)
    logits, state2 = jax.jit(
        lambda p, s, t: decode_step(p, cfg, s, t))(
            params, state, jnp.array([1, 2], jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all()
    assert jax.tree.structure(state2) == jax.tree.structure(state)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), capacity_factor=16.0)
    params = init_params(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    fe = batch.get("frontend")
    logits_full, _ = forward(params, cfg, batch["tokens"], fe)
    _, state = prefill(params, cfg, batch["tokens"][:, :-1],
                       max_len=S + cfg.frontend_len + 8, frontend=fe)
    logits_dec, _ = decode_step(params, cfg, state, batch["tokens"][:, -1])
    rel = float(jnp.abs(logits_dec - logits_full[:, -1]).max()) / \
        float(jnp.abs(logits_full[:, -1]).max())
    assert rel < 5e-3, f"{arch}: rel err {rel}"


def test_layer_plans():
    assert layer_plan(ARCHS["granite-8b"]) == ["attn"] * 36
    assert layer_plan(ARCHS["qwen3-moe-30b-a3b"]) == ["attn_moe"] * 48
    zp = layer_plan(ARCHS["zamba2-1.2b"])
    assert zp.count("mamba2") == 38
    assert zp.count("shared_attn") == 38 // 6
    xp = layer_plan(ARCHS["xlstm-125m"])
    assert xp.count("slstm") == 3 and xp.count("mlstm") == 9


def test_param_counts_match_published_sizes():
    expect = {"qwen2.5-14b": 14.8, "granite-8b": 8.3, "nemotron-4-15b": 15.6,
              "stablelm-3b": 2.8, "zamba2-1.2b": 1.2,
              "qwen3-moe-30b-a3b": 30.1, "internvl2-76b": 70.6}
    for name, bn in expect.items():
        got = ARCHS[name].n_params / 1e9
        assert abs(got - bn) / bn < 0.1, f"{name}: {got:.2f}B vs {bn}B"
    # MoE active params
    assert ARCHS["qwen3-moe-30b-a3b"].n_active_params / 1e9 == pytest.approx(
        2.9, rel=0.15)


def test_moe_aux_loss_uniform_router_is_one():
    """Property: with perfectly uniform routing, the Switch aux loss -> 1."""
    from repro.models.moe import init_moe, moe_block
    p = init_moe(KEY, 32, 8, 64)
    p["router"] = jnp.zeros_like(p["router"])       # uniform probs
    x = jax.random.normal(KEY, (2, 64, 32))
    _, aux = moe_block(p, x, top_k=2)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_shape_applicability_matrix():
    """40 cells: long_500k only for sub-quadratic archs."""
    n_ok = n_skip = 0
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            n_ok += ok
            n_skip += not ok
            if shape.name == "long_500k":
                assert ok == cfg.sub_quadratic
    assert n_ok + n_skip == 40
    assert n_skip == 8                              # 8 full-attention archs


def test_vlm_frontend_changes_logits():
    cfg = ARCHS["internvl2-76b"].reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = forward(params, cfg, batch["tokens"], batch["frontend"])
    l2, _ = forward(params, cfg, batch["tokens"],
                    jnp.zeros_like(batch["frontend"]))
    assert not jnp.allclose(l1, l2)
    assert l1.shape == l2.shape == (2, 32, cfg.vocab)
