"""Event-queue hygiene: lazy-deletion compaction, lazy DVFS breakpoints,
and the vectorized rate-refresh path.

All three are required to be *behavior-invisible*: they may only change
wall time and heap size, never a makespan or a placement."""
import pytest

from repro.core import (SpeedProfile, copy_type, corun_chain, dvfs_denver,
                        haswell, make_scheduler, matmul_type, synthetic_dag,
                        tx2, tx2_xl)
from repro.core.simulator import _COMPACT_MIN_STALE, Simulator

NEVER = 10 ** 9


def _bw_heavy_run(compact_min_stale, *, total=800, P=20):
    """Bandwidth-heavy copy DAG under a harsh all-core DVFS square wave:
    every recovery edge makes rates jump ~50x, so the rescheduled (earlier)
    finish events leave the old ones stranded far in the future — the
    worst case for stale-event accumulation."""
    tt = copy_type(2048)
    topo = haswell()
    sched = make_scheduler("RWS", topo, seed=5)
    dag = synthetic_dag(tt, parallelism=P, total_tasks=total)
    speed = SpeedProfile(topo.n_cores).add_square_wave(
        range(topo.n_cores), period=0.002, lo=0.02, t_end=10.0)
    sim = Simulator(sched, speed=speed)
    sim._compact_min_stale = compact_min_stale
    sim.submit(dag)
    return sim.run(), sim


def test_compaction_is_behavior_invisible():
    m_raw, s_raw = _bw_heavy_run(NEVER)
    m_cmp, s_cmp = _bw_heavy_run(_COMPACT_MIN_STALE)
    assert s_cmp.compactions > 0            # the workload provokes it
    assert s_raw.compactions == 0
    assert m_cmp.makespan == m_raw.makespan
    assert m_cmp.placement_counts() == m_raw.placement_counts()
    assert m_cmp.placement_counts(priority=1) == \
        m_raw.placement_counts(priority=1)


def test_compaction_bounds_heap():
    _, s_raw = _bw_heavy_run(NEVER)
    _, s_cmp = _bw_heavy_run(_COMPACT_MIN_STALE)
    # compaction triggers once stale > max(threshold, heap/2), so the heap
    # never exceeds ~2x threshold + live events (one finish per running
    # task + the single outstanding speed breakpoint)
    n_cores = haswell().n_cores
    assert s_cmp.heap_peak <= 2 * _COMPACT_MIN_STALE + n_cores + 16
    # and the uncompacted run really did bloat (this is the regression
    # guard: if rate-refresh churn stops staling events, or compaction
    # silently stops firing, one of these trips)
    assert s_raw.heap_peak > 4 * s_cmp.heap_peak


def test_stale_counter_never_goes_negative():
    _, sim = _bw_heavy_run(_COMPACT_MIN_STALE)
    assert sim._stale >= 0


def test_lazy_speed_breakpoints():
    """dvfs_denver() carries ~200k breakpoints up to the 1e6 s horizon; the
    engine must schedule them one at a time, not flood the heap upfront."""
    tt = matmul_type(64)
    sched = make_scheduler("DAM-C", tx2(), seed=1)
    dag = synthetic_dag(tt, parallelism=4, total_tasks=200)
    sim = Simulator(sched, speed=dvfs_denver())
    sim.submit(dag)
    m = sim.run()
    assert m.n_tasks == 200
    assert sim.heap_peak < 100


def _xl_run(vec_min, *, seed=3, total=900):
    """tx2_xl(8) = 48 cores with DVFS + co-runners: refresh batches large
    enough to cross the numpy path when vec_min is the default."""
    tt = copy_type(1024)
    topo = tx2_xl(8)
    sched = make_scheduler("DAM-C", topo, seed=seed)
    dag = synthetic_dag(tt, parallelism=24, total_tasks=total)
    sim = Simulator(sched, speed=dvfs_denver(topo.n_cores),
                    background=[corun_chain(tt, core=0),
                                corun_chain(tt, core=7)])
    sim._vec_min = vec_min
    sim.submit(dag)
    return sim.run()


def test_vectorized_refresh_matches_scalar_bitwise():
    m_py = _xl_run(NEVER)       # always the Python loop
    m_np = _xl_run(1)           # always the numpy path
    assert m_np.makespan == m_py.makespan
    assert m_np.placement_counts() == m_py.placement_counts()
    mix = _xl_run(32)           # default crossover: mixed paths
    assert mix.makespan == m_py.makespan


@pytest.mark.parametrize("sched_name", ("RWS", "DA", "DAM-P"))
def test_vectorized_refresh_other_schedulers(sched_name):
    tt = copy_type(1024)
    topo = tx2_xl(8)

    def go(vec_min):
        sched = make_scheduler(sched_name, topo, seed=2)
        dag = synthetic_dag(tt, parallelism=30, total_tasks=600)
        sim = Simulator(sched, background=[corun_chain(tt, core=2)])
        sim._vec_min = vec_min
        sim.submit(dag)
        return sim.run()

    a, b = go(NEVER), go(1)
    assert a.makespan == b.makespan
    assert a.placement_counts() == b.placement_counts()
