"""Continuous batching for the decode path (DESIGN.md §"Continuous
batching"): the max_batch=1 degeneracy pin (bit-identical to the
unbatched path), deterministic batch formation and its four triggers,
queue-level coalescing in both engines with composition parity,
shed-at-commit membership semantics, and determinism across multirun
worker counts."""
import numpy as np
import pytest

from repro.core import (BatchingConfig, ResourcePartition, RunSpec, TaskType,
                        ThreadedRuntime, Topology, batch_bucket,
                        decode_pool_dag, make_scheduler, run_cells,
                        run_threaded, simulate, task_faults, tx2)
from repro.serve import BrownoutConfig, DecodeBatcher, ServingEngine, \
    form_batches
from repro.serve.batching import BatchSlot

# tx2-kind synthetic types: a heavy HIGH prefill + a light LOW decode
PRE = TaskType("prefill", {"denver": 4e-4, "a57": 8e-4})
DEC = TaskType("decode", {"denver": 1e-4, "a57": 2e-4})


def _one_core():
    """Single-slice fleet: both engines serialize, so batch formation is
    fully determined by the DAG (prefills drain HIGH-first, then each
    decode layer coalesces whole)."""
    return Topology([ResourcePartition("pod0", "pod", 0, 1, (1,))])


def _pod_types():
    return (TaskType("prefill", {"pod": 4e-4}),
            TaskType("decode", {"pod": 1e-4}))


def _rec_tuple(r):
    return (r.type_name, r.priority, r.leader, r.width,
            r.t_ready, r.t_start, r.t_end)


# -- config + type algebra ---------------------------------------------------

def test_batching_config_validation():
    assert not BatchingConfig(max_batch=1).enabled
    assert BatchingConfig(max_batch=2).enabled
    with pytest.raises(ValueError):
        BatchingConfig(max_batch=0)
    with pytest.raises(ValueError):
        BatchingConfig(delay_s=-1e-3)
    with pytest.raises(ValueError):
        BatchingConfig(member_cost=1.5)


def test_batch_bucket_power_of_two():
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16)] == \
        [1, 2, 4, 4, 8, 8, 16, 16]
    with pytest.raises(ValueError):
        batch_bucket(0)


def test_tasktype_batched_degeneracy_and_cache():
    assert DEC.batched(1, 0.05) is DEC           # n=1 IS the base type
    b3 = DEC.batched(3, 0.05)
    assert b3.name == "decode@b4" and b3.batch_base == "decode"
    # cost model: memory-bound fill, not serial repeat
    assert b3.serial_time["denver"] == pytest.approx(1e-4 * 1.1)
    assert DEC.batched(3, 0.05) is b3            # cached per (n, cost)
    assert DEC.batched(4, 0.05) is not b3        # same bucket, own cost
    assert DEC.batched(4, 0.05).name == "decode@b4"


# -- formation triggers (pure function + batcher) ----------------------------

def _slot(t_enq, tier="low", deadline_s=0.0, t_submit=0.0):
    req = type("R", (), {"tier": tier, "deadline_s": deadline_s,
                         "t_submit": t_submit})()
    return BatchSlot(req, {}, t_enq)


def test_form_batches_quorum_and_age():
    cfg = BatchingConfig(max_batch=4, delay_s=5e-3)
    pending = [_slot(0.0) for _ in range(9)]
    groups, rest = form_batches(pending, now=1e-3, cfg=cfg)
    assert [len(g) for g in groups] == [4, 4]    # quorum, oldest first
    assert len(rest) == 1                        # young partial waits
    groups, rest = form_batches(rest, now=6e-3, cfg=cfg)
    assert [len(g) for g in groups] == [1] and not rest   # aged out


def test_form_batches_high_tier_flushes_immediately():
    """The HIGH-flush latency bound: a critical member never waits on
    batch fill — its arrival flushes the whole pending set at once."""
    cfg = BatchingConfig(max_batch=8, delay_s=1.0)
    pending = [_slot(0.0) for _ in range(3)]
    groups, rest = form_batches(pending, now=1e-6, cfg=cfg)
    assert not groups and len(rest) == 3         # nothing due on its own
    pending.append(_slot(1e-6, tier="high"))
    groups, rest = form_batches(pending, now=2e-6, cfg=cfg)
    assert [len(g) for g in groups] == [4] and not rest


def test_form_batches_deadline_slack_flushes():
    cfg = BatchingConfig(max_batch=8, delay_s=1.0, flush_slack_s=5e-3)
    pending = [_slot(0.0), _slot(0.0, deadline_s=0.1, t_submit=0.0)]
    groups, _ = form_batches(pending, now=0.01, cfg=cfg)
    assert not groups                            # slack 90 ms: waits
    groups, rest = form_batches(pending, now=0.097, cfg=cfg)
    assert [len(g) for g in groups] == [2] and not rest   # slack <= 5 ms


def test_decode_batcher_add_readd_drain_telemetry():
    b = DecodeBatcher(BatchingConfig(max_batch=2, delay_s=1.0))
    assert b.add(_slot(0.0).req, {}, 0.0) == []
    (grp,) = b.add(_slot(0.0).req, {}, 1e-3)     # quorum of 2
    assert len(grp) == 2 and len(b) == 0
    assert b.readd(grp[0], 2e-3) == []           # survivor re-parks
    (grp2,) = b.poll(3e-3, drain=True)           # drain flushes partials
    assert len(grp2) == 1
    assert b.batches_formed == 2 and b.members_dispatched == 3
    with pytest.raises(ValueError):
        DecodeBatcher(BatchingConfig(max_batch=1))


# -- DES: degeneracy + coalescing --------------------------------------------

def test_des_batch1_bit_identical_to_unbatched():
    """The degeneracy pin: max_batch=1 must take the exact unbatched code
    path — schedules compare bitwise, not approximately."""
    runs = []
    for batching in (None, BatchingConfig(max_batch=1)):
        dag = decode_pool_dag(PRE, DEC, n_requests=8, steps=5)
        sched = make_scheduler("DAM-C", tx2(), seed=0)
        runs.append(simulate(dag, sched, batching=batching))
    a, b = runs
    assert a.makespan == b.makespan
    assert [_rec_tuple(r) for r in a.records] == \
        [_rec_tuple(r) for r in b.records]
    assert not b.batches


def test_des_golden_dags_unaffected_by_batch1():
    """Non-serving DAGs (no batch_key anywhere) under a max_batch=1
    config reproduce the unbatched schedule exactly — the goldens'
    guarantee that PR 9 behavior survives the batching rollout."""
    from repro.core import matmul_type, synthetic_dag
    runs = []
    for batching in (None, BatchingConfig(max_batch=1)):
        dag = synthetic_dag(matmul_type(64), parallelism=4, total_tasks=60)
        sched = make_scheduler("DAM-C", tx2(), seed=1)
        runs.append(simulate(dag, sched, batching=batching))
    a, b = runs
    assert [_rec_tuple(r) for r in a.records] == \
        [_rec_tuple(r) for r in b.records]


def test_des_coalesces_and_accounts_every_token():
    n_req, steps = 12, 4
    dag = decode_pool_dag(PRE, DEC, n_requests=n_req, steps=steps)
    sched = make_scheduler("DAM-C", tx2(), seed=0)
    m = simulate(dag, sched, batching=BatchingConfig(max_batch=8))
    assert m.batches                               # fused dispatches formed
    assert any("@b" in r.type_name for r in m.records)
    # every decode token executes exactly once: members ride fused
    # dispatches, the rest run solo
    fused = sum(len(comp) for _name, comp in m.batches)
    solo = sum(1 for r in m.records if r.type_name == "decode")
    assert fused + solo == n_req * steps
    assert sum(1 for r in m.records if r.type_name == "prefill") == n_req
    # and it is faster than one-dispatch-per-token on the same DAG
    dag2 = decode_pool_dag(PRE, DEC, n_requests=n_req, steps=steps)
    m0 = simulate(dag2, make_scheduler("DAM-C", tx2(), seed=0))
    assert m.makespan < m0.makespan


def test_batching_with_faults_rejected():
    cfg = BatchingConfig(max_batch=4)
    fm = task_faults(seed=0, p_fail=0.1)
    dag = decode_pool_dag(PRE, DEC, n_requests=2, steps=2)
    with pytest.raises(ValueError, match="fault injection"):
        simulate(dag, make_scheduler("DAM-C", tx2(), seed=0),
                 batching=cfg, faults=fm)
    with pytest.raises(ValueError, match="fault injection"):
        ThreadedRuntime(make_scheduler("DAM-C", tx2(), seed=0),
                        batching=cfg, faults=fm)


# -- cross-engine parity -----------------------------------------------------

def test_cross_engine_batch_composition_multiset_parity():
    """On a single-slice fleet both engines serialize, so the multiset of
    fused-dispatch compositions is determined by the DAG alone and must
    agree exactly between the DES and the threaded runtime."""
    pre, dec = _pod_types()
    cfg = BatchingConfig(max_batch=8)

    dag = decode_pool_dag(pre, dec, n_requests=6, steps=3)
    m_des = simulate(dag, make_scheduler("DAM-C", _one_core(), seed=0),
                     batching=cfg)
    dag2 = decode_pool_dag(pre, dec, n_requests=6, steps=3)
    m_thr = run_threaded(dag2, make_scheduler("DAM-C", _one_core(), seed=0),
                         batching=cfg, timeout=60)
    assert sorted(m_des.batches) == sorted(m_thr.batches)
    # serialized layer-at-a-time drain: each decode layer fuses whole
    assert sorted(len(c) for _n, c in m_des.batches) == [6, 6, 6]


# -- serving engine ----------------------------------------------------------

def _pod_fleet():
    from repro.core import tpu_pod_slices
    return tpu_pod_slices(2, 2)


def test_engine_batched_e2e_all_tokens_via_batcher():
    eng = ServingEngine(None, _pod_fleet(), scheduler="DAM-C",
                        batching=BatchingConfig(max_batch=4, delay_s=1e-3,
                                                member_cost=0.02),
                        prefill_s=2e-3, decode_s=1e-3)
    reqs = [eng.submit(np.zeros(8, np.int32), max_new_tokens=4)
            for _ in range(8)]
    m = eng.run(timeout=120)
    assert not m.errors
    s = eng.latency_stats()
    assert s["completed"] == 8 and s["shed"] == 0
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert r.t_done >= r.t_first_token >= r.t_submit
    # every decode step went through the batcher, none ran as a bare task
    assert eng.batcher.members_dispatched == 8 * 3
    assert eng.batcher.batches_formed >= 1
    assert any("@b" in rec.type_name for rec in m.records) \
        or eng.batcher.batches_formed == eng.batcher.members_dispatched


def test_engine_max_batch1_normalizes_to_unbatched():
    eng = ServingEngine(None, _pod_fleet(), scheduler="DAM-C",
                        batching=BatchingConfig(max_batch=1))
    assert eng.batching is None and eng.batcher is None
    assert eng.runtime.batching is None
    eng.submit(np.zeros(8, np.int32), max_new_tokens=2)
    eng.run(timeout=60)
    assert eng.latency_stats()["completed"] == 1


def test_shed_member_at_commit_removes_members_not_dispatches():
    """Rung-2 brownout shedding under batched overload: shed requests
    leave their dispatches (membership re-checked at dispatch/commit),
    surviving members keep decoding, every request finalizes."""
    eng = ServingEngine(None, _pod_fleet(), scheduler="DAM-C",
                        max_pending=24,
                        brownout=BrownoutConfig(enter=(0.02, 0.05, 0.10),
                                                exit=(0.01, 0.02, 0.05),
                                                min_tokens=1),
                        batching=BatchingConfig(max_batch=8, delay_s=1e-3,
                                                member_cost=0.02),
                        prefill_s=20e-3, decode_s=5e-3)
    prompts = [np.zeros(8, np.int32)] * 80
    m = eng.run_open_loop(prompts, rate_rps=400.0, max_new_tokens=5,
                          timeout=120)
    assert not m.errors
    s = eng.latency_stats()
    assert s["completed"] + s["rejected"] == 80    # nothing lost
    assert s["brownout_max_rung"] >= 2
    assert s["shed_brownout"] + s["tokens_clamped"] > 0
    for r in eng.requests.values():
        if r.shed:
            assert 1 <= len(r.out_tokens) < 5      # truncated, not empty
    # batching stayed live through the overload
    assert eng.batcher.batches_formed > 0


def test_engine_batching_faults_rejected():
    with pytest.raises(ValueError, match="fault injection"):
        ServingEngine(None, _pod_fleet(), scheduler="DAM-C",
                      batching=BatchingConfig(max_batch=4),
                      faults=task_faults(seed=0, p_fail=0.1))


# -- determinism across multirun workers -------------------------------------

def test_batch_formation_deterministic_across_workers():
    """The same batched cells, fanned across 1 vs 2 worker processes,
    must produce bitwise-equal results — composition multisets included
    (BatchingConfig rides RunSpec.sim_kwargs verbatim)."""
    cfg = BatchingConfig(max_batch=4)
    specs = [RunSpec(
        key=f"b{seed}",
        dag=("decode_pool", {"task_types": (("matmul", {"tile": 64}),
                                            ("copy", {"tile": 256})),
                             "n_requests": 8, "steps": 4}),
        scheduler="DAM-C", topology=("tx2", {}), seed=seed,
        sim_kwargs=(("batching", cfg),), collect=("batching",))
        for seed in (1, 2)]
    r1 = run_cells(specs, workers=1)
    r2 = run_cells(specs, workers=2)
    assert r1 == r2
    assert all(r["batching"]["n_batches"] > 0 for r in r1.values())
