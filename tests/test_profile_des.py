"""Smoke the DES phase-timer tool (tools/profile_des.py): buckets
populate, the instrumentation stays instance-local, and the JSON shape
the trajectory tooling reads (``_meta.kinds_s`` / ``_meta.phases_s``)
is stable."""
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_profile_des_smoke(tmp_path):
    out = tmp_path / "profile.json"
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "profile_des.py"),
         "--tasks", "300", "-o", str(out)],
        capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr
    meta = json.loads(out.read_text())["_meta"]
    assert meta["sim_tasks_per_s"] > 0
    kinds = meta["kinds_s"]
    assert kinds["finish"]["calls"] == 300      # every task commits once
    phases = meta["phases_s"]
    for bucket in ("dispatch", "refresh", "advance"):
        assert phases[bucket]["calls"] > 0
        assert phases[bucket]["wall_s"] >= 0.0
    # instrumentation must not change simulation results: the makespan is
    # the uninstrumented pass's and both passes ran the same workload
    assert meta["makespan_s"] > 0
